"""Training substrate: optimizers, schedule, checkpointing (incl. elastic
restore), failure injection, straggler detection, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (MemmapTokens, Prefetcher, SyntheticTokens,
                                 make_batch)
from repro.train import checkpoint as CKPT
from repro.train import ft
from repro.train.optim import OptConfig, Optimizer, lr_at


def test_lr_schedule():
    cfg = OptConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, jnp.int32(0))) < 2e-4
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1e-3, rel=0.1)
    assert float(lr_at(cfg, jnp.int32(100))) < 1e-5 + 1e-9


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    opt = Optimizer(OptConfig(name=name, lr_peak=0.1, warmup_steps=1,
                              total_steps=200, weight_decay=0.0))
    params = {"w": jnp.ones((8, 16), jnp.bfloat16) * 2.0,
              "b": jnp.ones((16,), jnp.bfloat16)}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"].astype(jnp.float32) ** 2) + \
            jnp.sum(p["b"].astype(jnp.float32) ** 2)

    l0 = float(loss_fn(params))
    for _ in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss_fn(params)) < 0.2 * l0
    if name == "adafactor":   # factored stats really are factored
        assert state["stats"]["w"]["vr"].shape == (8,)
        assert state["stats"]["w"]["vc"].shape == (16,)
        assert "v" in state["stats"]["b"]


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                        "b": jnp.linspace(-2, 2, 8, dtype=jnp.bfloat16)},
             "opt": {"step": np.int32(7)}}
    CKPT.save(str(tmp_path), 7, state, {"arch": "x"})
    flat, meta, step = CKPT.load(str(tmp_path))
    assert step == 7 and meta["arch"] == "x"
    rebuilt = CKPT.restore_tree(state, flat)
    np.testing.assert_array_equal(rebuilt["params"]["w"],
                                  state["params"]["w"])
    # bf16 survives the npy round trip (ml_dtypes view serialization)
    assert rebuilt["params"]["b"].dtype.name == "bfloat16"
    np.testing.assert_array_equal(np.asarray(rebuilt["params"]["b"]),
                                  np.asarray(state["params"]["b"]))


def test_checkpoint_retention_and_latest(tmp_path):
    for s in [1, 2, 3, 4, 5]:
        CKPT.save(str(tmp_path), s, {"x": np.zeros(2)}, keep=3)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 3
    assert CKPT.latest_step(str(tmp_path)) == 5


def test_async_checkpointer(tmp_path):
    ck = CKPT.AsyncCheckpointer(str(tmp_path))
    for s in [10, 20]:
        ck.submit(s, {"w": jnp.ones((4,)) * s})
    ck.finish()
    flat, _, step = CKPT.load(str(tmp_path))
    assert step == 20
    np.testing.assert_array_equal(flat["w"], np.ones(4) * 20)


def test_elastic_restore_reshards(tmp_path):
    """Save from a '1-device layout', restore onto a different sharding --
    global shapes are the contract."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    w = np.arange(64, dtype=np.float32).reshape(8, 8)
    CKPT.save(str(tmp_path), 1, {"w": w})
    flat, _, _ = CKPT.load(str(tmp_path))
    out = CKPT.restore_sharded({"w": jnp.zeros((8, 8), jnp.float32)}, flat,
                               mesh, {"w": P("data", None)})
    np.testing.assert_array_equal(np.asarray(out["w"]), w)


def test_failure_injector_fires_once():
    inj = ft.FailureInjector(frozenset([3]))
    inj.check(2)
    with pytest.raises(ft.SimulatedFailure):
        inj.check(3)
    inj.check(3)   # second pass after restart does not re-fire


def test_straggler_detector():
    det = ft.StragglerDetector(alpha=0.5, threshold=3.0, warmup=2)
    flags = [det.observe(i, 1.0) for i in range(6)]
    assert not any(flags)
    assert det.observe(6, 10.0)            # 10x the EWMA
    assert not det.observe(7, 1.0)         # EWMA not poisoned
    assert len(det.events) == 1


def test_supervisor_degrade_cycle():
    pol = ft.RecoveryPolicy(degrade_backend="linear", recovery_steps=4,
                            max_restarts=2)
    sup = ft.SupervisorState()
    be = sup.on_failure(10, pol)
    assert be == "linear"
    assert sup.backend_for(12, "native", pol) == "linear"
    assert sup.backend_for(15, "native", pol) == "native"
    sup.on_failure(20, pol)
    with pytest.raises(RuntimeError):
        sup.on_failure(30, pol)


def test_synthetic_data_deterministic():
    src = SyntheticTokens(vocab=100, seq=16, global_batch=4, seed=3)
    a, b = src.batch(5), src.batch(5)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(src.batch(5), src.batch(6))
    assert a.shape == (4, 16) and a.min() >= 0 and a.max() < 100


def test_memmap_source(tmp_path):
    path = str(tmp_path / "tokens.bin")
    np.arange(10_000, dtype=np.int32).tofile(path)
    src = MemmapTokens(path, vocab=50_000, seq=32, global_batch=4)
    b1, b2 = src.batch(0), src.batch(0)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (4, 32)


def test_prefetcher_orders_batches():
    src = SyntheticTokens(vocab=10, seq=4, global_batch=2, seed=0)
    pf = Prefetcher(lambda s: {"tokens": src.batch(s)}, start_step=3,
                    depth=2)
    steps = [pf.get()[0] for _ in range(4)]
    pf.stop()
    assert steps == [3, 4, 5, 6]


def test_grad_compression_int8_error_feedback():
    """Quantize-allreduce with EF: the *accumulated* update over many steps
    converges to the true sum (error telescopes)."""
    from repro.train.compress import quantize_int8
    rng = np.random.default_rng(0)
    g = rng.standard_normal(256).astype(np.float32)
    ef = np.zeros_like(g)
    acc_q, acc_true = np.zeros_like(g), np.zeros_like(g)
    for step in range(50):
        gf = g + ef
        q, s = quantize_int8(jnp.asarray(gf))
        sent = np.asarray(q, np.float32) * float(s)
        ef = gf - sent
        acc_q += sent
        acc_true += g
    # relative error of the accumulated signal is tiny vs one-shot error
    rel = np.linalg.norm(acc_q - acc_true) / np.linalg.norm(acc_true)
    assert rel < 1e-3

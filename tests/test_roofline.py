"""Roofline term derivation + artifact plumbing."""

import pytest

from repro.launch.roofline import HW, MOVE_NOTE, table, terms


ART = {
    "arch": "x", "shape": "train_4k", "path": "mpignite",
    "backend": "native", "mesh": "single", "skip": None,
    "n_devices": 256,
    "model_flops": 6.0 * 2.7e9 * 1.05e6,
    "hlo": {"flops": 1.0e14, "mem_bytes": 3.0e12,
            "mem_bytes_fused": 1.0e12, "coll_wire_bytes": 1.0e11,
            "coll_bytes": {}, "coll_count": {}},
    "memory": {"peak_bytes_est": 12 * 2 ** 30, "argument_bytes": 0,
               "output_bytes": 0, "temp_bytes": 0, "alias_bytes": 0},
}


def test_terms_math():
    t = terms(ART)
    assert t["compute_s"] == pytest.approx(1.0e14 / HW["peak_flops"])
    assert t["memory_s"] == pytest.approx(1.0e12 / HW["hbm_bw"])
    assert t["collective_s"] == pytest.approx(1.0e11 / HW["ici_bw"])
    assert t["bottleneck"] == "collective"
    assert t["memory_upper_s"] == pytest.approx(3.0e12 / HW["hbm_bw"])
    # ratio: model flops over total HLO flops across chips
    assert t["model_flops_ratio"] == pytest.approx(
        ART["model_flops"] / (1.0e14 * 256))
    # fraction: ideal time over bound time
    ideal = ART["model_flops"] / 256 / HW["peak_flops"]
    assert t["roofline_fraction"] == pytest.approx(ideal / t["collective_s"])
    assert t["bottleneck"] in MOVE_NOTE


def test_table_renders_md_and_csv():
    md = table([ART, {"arch": "y", "shape": "s", "skip": "because"}])
    assert "collective" in md and "SKIP: because" in md
    csv = table([ART], fmt="csv")
    assert csv.splitlines()[0].startswith("arch,shape")
    assert "collective" in csv

"""Drives the multi-device checks in a subprocess: the forced 8-device
XLA flag must not leak into this pytest process (smoke tests and benches
are required to see exactly 1 device)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(900)
def test_distributed_checks_subprocess():
    script = os.path.join(os.path.dirname(__file__), "_dist_checks.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, script], capture_output=True,
                       text=True, timeout=850, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in r.stdout

"""Multi-device checks run in a subprocess with 8 forced host devices
(tests/test_distributed.py drives this; conftest must NOT set XLA_FLAGS
globally, so the isolation lives here)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"


import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import NamedSharding                        # noqa: E402

from repro.core import parallelize_func                       # noqa: E402
from repro.core import compat                                 # noqa: E402
from repro.configs import get_config                          # noqa: E402
from repro.models.model import Model                          # noqa: E402
from repro.parallel import axes as A                          # noqa: E402
from repro.parallel.ops import ParallelConfig                 # noqa: E402
from repro.launch.mesh import make_test_mesh                  # noqa: E402


def check_spmd_matches_local_runtime():
    """The same closure on the thread runtime (paper local mode) and on
    the SPMD mesh, across all three backends."""
    def local_closure(world):
        return world.allreduce(float(world.get_rank()), lambda a, b: a + b)
    want = parallelize_func(local_closure).execute(8)

    for backend in ["native", "ring", "linear"]:
        def spmd_closure(world):
            return world.allreduce(jnp.float32(world.rank()), "add")
        got = parallelize_func(spmd_closure, backend=backend).execute(
            8, mode="spmd")
        assert [float(g) for g in got] == want, (backend, got, want)
    print("ok: spmd matches local runtime (3 backends)")


def check_split_collectives_on_mesh():
    """2-D split (rows/cols of a 2x4 grid) + allreduce/broadcast/alltoall
    against numpy oracles."""
    n = 8
    for backend in ["native", "ring", "linear"]:
        def closure(world):
            r = world.rank()
            row = world.split([i // 4 for i in range(8)], list(range(8)))
            col = world.split([i % 4 for i in range(8)], list(range(8)))
            a = row.allreduce(jnp.float32(r), "add")      # sum over row
            b = col.allreduce(jnp.float32(r), "max")      # max over col
            c = world.broadcast(jnp.float32(r) + 5.0, root=3)
            return a, b, c
        out = parallelize_func(closure, backend=backend).execute(
            8, mode="spmd")
        for r in range(8):
            a, b, c = [float(x) for x in out[r]]
            row = [i for i in range(8) if i // 4 == r // 4]
            col = [i for i in range(8) if i % 4 == r % 4]
            assert a == sum(row), (backend, r, a)
            assert b == max(col), (backend, r, b)
            assert c == 8.0, (backend, r, c)
    print("ok: split/allreduce/broadcast on mesh (3 backends)")


def check_train_step_on_mesh():
    """Full train step (fwd+bwd+opt) on a 2x4 mesh: loss decreases and
    matches the gspmd path."""
    import dataclasses
    from repro.train.optim import OptConfig, Optimizer
    from repro.train.step import init_opt_state, make_train_step

    mesh = make_test_mesh(data=2, model=4)
    axes = A.MeshAxes.from_mesh(mesh)
    cfg = dataclasses.replace(get_config("qwen3-4b", smoke=True),
                              dtype=jnp.float32)
    B, S = 4, 32
    losses, gnorms = {}, {}
    for path in ["mpignite", "gspmd"]:
        pcfg = ParallelConfig(path=path, backend="native",
                              sequence_parallel=True, remat="block")
        model = Model(cfg, axes, pcfg)
        opt = Optimizer(OptConfig(lr_peak=2e-3, warmup_steps=1,
                                  total_steps=50, weight_decay=0.0))
        step, ps = make_train_step(model, opt, mesh, B)
        params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        state = init_opt_state(model, opt, params)
        sh = lambda t, s: jax.device_put(t, jax.tree.map(
            lambda spec: NamedSharding(mesh, spec), s))
        params = sh(params, ps["params"])
        state = sh(state, ps["opt"])
        tokens = np.asarray(
            jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab))
        batch = {"tokens": jax.device_put(
            tokens, NamedSharding(mesh, ps["batch"]["tokens"]))}
        ls, gn = [], []
        with compat.set_mesh(mesh):
            for _ in range(5):
                params, state, metrics = step(params, state, batch)
                ls.append(float(metrics["loss"]))
                gn.append(float(metrics["gnorm"]))
        losses[path] = ls
        gnorms[path] = gn
        assert ls[-1] < ls[0] - 0.02, (path, ls)
    assert abs(losses["mpignite"][0] - losses["gspmd"][0]) < 1e-2, losses
    # explicit-comm gradients must match the compiler path (this catches
    # the psum-transpose seeding bug: a tp-x inflated gnorm)
    rel = abs(gnorms["mpignite"][0] - gnorms["gspmd"][0]) / gnorms["gspmd"][0]
    assert rel < 0.02, (gnorms, "grad mismatch mpignite vs gspmd")
    print("ok: train step on mesh, mpignite vs gspmd loss AND gnorm agree:",
          [round(l, 4) for l in losses["mpignite"]],
          round(gnorms["mpignite"][0], 4), round(gnorms["gspmd"][0], 4))


def check_decode_on_mesh():
    """Sharded prefill+decode matches the single-device decode logits."""
    import dataclasses
    from repro.train.step import make_decode_step, make_prefill_step

    cfg = dataclasses.replace(get_config("qwen3-4b", smoke=True),
                              dtype=jnp.float32)
    mesh = make_test_mesh(data=2, model=4)
    axes = A.MeshAxes.from_mesh(mesh)
    pcfg = ParallelConfig(path="mpignite", sequence_parallel=False)
    model = Model(cfg, axes, pcfg)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    B, S, s_max = 4, 16, 24
    tokens = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (B, S), 0, cfg.vocab))
    prefill = make_prefill_step(model, mesh, B, s_max=s_max)
    decode = make_decode_step(model, mesh, B, s_max=s_max)
    sh = lambda t, s: jax.device_put(t, NamedSharding(mesh, s))
    _, bps = model.batch_specs(B, S)
    with compat.set_mesh(mesh):
        logits, caches = prefill(params, {"tokens": sh(
            jnp.asarray(tokens), bps["tokens"])})
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits2, caches = decode(params, caches, tok,
                                 jnp.full((B,), S, jnp.int32))

    # single-device reference (same padded layout: tp=4 matters for init
    # shapes, so rebuild with axes=1 but same weights is not comparable;
    # instead check internal consistency: decode logits are finite and
    # argmax is stable under a repeated call)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    print("ok: sharded prefill+decode runs and is finite")


def check_reduce_gather_scan():
    """The paper-section-6 'more methods' agree between the thread
    runtime and all SPMD backends."""
    def local_fn(world):
        r = world.get_rank()
        red = world.reduce(0, float(r), lambda a, b: a + b)
        gat = world.gather(2, r)
        scn = world.scan(float(r), lambda a, b: a + b)
        return red, gat, scn
    want = parallelize_func(local_fn).execute(8)

    for backend in ["native", "ring", "linear"]:
        def spmd_fn(world):
            r = world.rank()
            red = world.reduce(jnp.float32(r), root=0)
            gat = world.gather(jnp.float32(r), root=2)
            scn = world.scan(jnp.float32(r), "add")
            return red, gat, scn
        got = parallelize_func(spmd_fn, backend=backend).execute(
            8, mode="spmd")
        for r in range(8):
            lred, lgat, lscn = want[r]
            red, gat, scn = got[r]
            assert float(red) == (lred if lred is not None else 0.0)
            assert float(scn) == lscn == sum(range(r + 1))
            if r == 2:
                assert [float(x) for x in gat] == [float(x) for x in lgat]
            else:
                assert float(jnp.sum(gat)) == 0.0
    print("ok: reduce/gather/scan match local runtime (3 backends)")


def check_elastic_remesh_restart():
    """Train on a 2x4 mesh, checkpoint, restore onto a 4x2 mesh, keep
    training -- global shapes are the contract (DESIGN section 8)."""
    import dataclasses
    import tempfile
    from repro.train import checkpoint as CKPT
    from repro.train.optim import OptConfig, Optimizer
    from repro.train.step import init_opt_state, make_train_step

    cfg = dataclasses.replace(get_config("stablelm-3b", smoke=True),
                              dtype=jnp.float32)
    B, S = 4, 32
    tokens = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (B, S),
                                           0, cfg.vocab))
    opt_cfg = OptConfig(lr_peak=2e-3, warmup_steps=1, total_steps=50,
                        weight_decay=0.0)
    ckpt_dir = tempfile.mkdtemp()

    def build(data, model_par):
        mesh = make_test_mesh(data=data, model=model_par)
        axes = A.MeshAxes.from_mesh(mesh)
        pcfg = ParallelConfig(path="mpignite", sequence_parallel=True,
                              remat="none")
        model = Model(cfg, axes, pcfg)
        opt = Optimizer(opt_cfg)
        step, ps = make_train_step(model, opt, mesh, B)
        return mesh, model, opt, step, ps

    # phase 1: 2 data x 4 model
    mesh, model, opt, step, ps = build(2, 4)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    state = init_opt_state(model, opt, params)
    sh = lambda t, s, m: jax.device_put(t, jax.tree.map(
        lambda spec: NamedSharding(m, spec), s))
    params, state = sh(params, ps["params"], mesh), sh(state, ps["opt"], mesh)
    batch = {"tokens": jax.device_put(tokens, NamedSharding(
        mesh, ps["batch"]["tokens"]))}
    losses = []
    with compat.set_mesh(mesh):
        for _ in range(3):
            params, state, metrics = step(params, state, batch)
            losses.append(float(metrics["loss"]))
    CKPT.save(ckpt_dir, 3, {"params": params, "opt": state})

    # phase 2: REshape the cluster to 4 data x 2 model and resume
    mesh2, model2, opt2, step2, ps2 = build(4, 2)
    flat, _, _ = CKPT.load(ckpt_dir)
    tmpl_p = model2.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    tmpl_o = init_opt_state(model2, opt2, tmpl_p)
    params2 = CKPT.restore_sharded(
        tmpl_p, {k[len("params/"):]: v for k, v in flat.items()
                 if k.startswith("params/")}, mesh2, ps2["params"])
    state2 = CKPT.restore_sharded(
        tmpl_o, {k[len("opt/"):]: v for k, v in flat.items()
                 if k.startswith("opt/")}, mesh2, ps2["opt"])
    batch2 = {"tokens": jax.device_put(tokens, NamedSharding(
        mesh2, ps2["batch"]["tokens"]))}
    with compat.set_mesh(mesh2):
        for _ in range(3):
            params2, state2, metrics2 = step2(params2, state2, batch2)
            losses.append(float(metrics2["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[3] < losses[0], losses   # training continued, not reset
    assert losses[-1] < losses[3], losses
    print("ok: elastic re-mesh restart 2x4 -> 4x2, losses",
          [round(l, 4) for l in losses])


if __name__ == "__main__":
    check_spmd_matches_local_runtime()
    check_split_collectives_on_mesh()
    check_reduce_gather_scan()
    check_train_step_on_mesh()
    check_decode_on_mesh()
    check_elastic_remesh_restart()
    print("ALL DISTRIBUTED CHECKS PASSED")

"""Nonblocking MPI semantics: Request lifecycle (wait/test/cancel,
waitall/waitany), the per-rank progress engine advancing collective
schedules off the caller's thread, overlap of multiple outstanding
operations, and request hygiene (leaks, timeouts, teardown)."""
import threading
import time
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.core import (Mailbox, PeerDeadError, ProgressEngine, Request,
                        parallelize_func, waitall, waitany)


# ---------------------------------------------------------------------------
# Request object semantics
# ---------------------------------------------------------------------------

def test_isend_irecv_roundtrip():
    def closure(world):
        rank, size = world.get_rank(), world.get_size()
        sreq = world.isend((rank + 1) % size, 7, rank * 11)
        rreq = world.irecv((rank - 1) % size, 7)
        assert sreq.done()          # sends are always-nonblocking: born done
        assert sreq.wait() is None
        return rreq.wait(timeout=10)
    out = parallelize_func(closure).execute(4)
    assert out == [(r - 1) % 4 * 11 for r in range(4)]


def test_irecv_test_transitions():
    def closure(world):
        if world.get_rank() == 0:
            req = world.irecv(1, 0)
            before = req.test()
            world.send(1, 1, "go")              # unblock the sender
            val = req.wait(timeout=10)
            after = req.test()
            return before, val, after
        world.receive(0, 1)                     # hold until rank 0 polled
        world.send(0, 0, "payload")
        return None
    out = parallelize_func(closure).execute(2)
    before, val, after = out[0]
    assert before == (False, None)
    assert val == "payload"
    assert after == (True, "payload")


def test_request_wait_timeout_leaves_request_pending():
    """wait(timeout) expiring raises TimeoutError but does not retire the
    request -- a later wait can still complete it (MPI_Test semantics of
    repeated polling)."""
    def closure(world):
        if world.get_rank() == 0:
            req = world.irecv(1, 0)
            with pytest.raises(TimeoutError, match="still pending"):
                req.wait(timeout=0.1)
            world.send(1, 1, "now")
            return req.wait(timeout=10)
        world.receive(0, 1)
        world.send(0, 0, "late")
        return None
    out = parallelize_func(closure).execute(2)
    assert out[0] == "late"


def test_irecv_deadline_expiry_raises_timeout():
    """The transport receive deadline fails the request itself -- an
    unbounded ``wait()`` cannot hang past the mailbox deadline."""
    mb = Mailbox()
    req = Request(mb.get_async(0, 99, 1, timeout=0.2), op="irecv")
    with pytest.raises(TimeoutError, match="tag=99"):
        req.wait()
    assert req.done()


def test_cancel_irecv_preserves_late_message():
    def closure(world):
        if world.get_rank() == 0:
            req = world.irecv(1, 3)
            assert req.cancel() is True
            assert req.cancel() is False        # already retired
            with pytest.raises(CancelledError):
                req.wait(timeout=5)
            world.send(1, 1, "go")
            # the cancelled receive must not have consumed the message
            return world.receive(1, 3)
        world.receive(0, 1)
        world.send(0, 3, "kept")
        return None
    # sender waits for "go" before sending, so the cancel always precedes
    # the message: deterministic, not racy
    out = parallelize_func(closure).execute(2)
    assert out[0] == "kept"


def test_waitall_and_waitany():
    def closure(world):
        rank, size = world.get_rank(), world.get_size()
        reqs = [world.irecv(src, 10 + src) for src in range(size)
                if src != rank]
        for dst in range(size):
            if dst != rank:
                world.send(dst, 10 + rank, rank)
        vals = waitall(reqs, timeout=10)
        idx, first = waitany([world.iallreduce(1, lambda a, b: a + b)],
                             timeout=10)
        return sorted(vals), idx, first
    out = parallelize_func(closure).execute(3)
    for rank, (vals, idx, first) in enumerate(out):
        assert vals == sorted(r for r in range(3) if r != rank)
        assert (idx, first) == (0, 3)


def test_waitany_timeout():
    with pytest.raises(TimeoutError, match="none of 1"):
        mb = Mailbox()
        fut = mb.get_async(0, 0, 1, timeout=30)
        waitany([Request(fut, op="irecv")], timeout=0.1)


# ---------------------------------------------------------------------------
# Nonblocking collectives + the progress engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["linear", "ring"])
def test_nonblocking_collectives_match_blocking(backend):
    def closure(world):
        rank = world.get_rank()
        data = np.arange(5, dtype=np.int64) * (rank + 1)
        r1 = world.iallreduce(data, lambda a, b: a + b)
        r2 = world.iallgather(rank * 3)
        r3 = world.ibcast(2, "root-val" if rank == 2 else None)
        r4 = world.ibarrier()
        got = waitall([r1, r2, r3, r4], timeout=20)
        want = [world.allreduce(data, lambda a, b: a + b),
                world.allgather(rank * 3),
                world.broadcast(2, "root-val" if rank == 2 else None),
                world.barrier()]
        return [np.array_equal(got[0], want[0])] + \
            [g == w for g, w in zip(got[1:], want[1:])]
    out = parallelize_func(closure, backend=backend).execute(4)
    assert out == [[True, True, True, True]] * 4


def test_interleaved_nonblocking_and_blocking_collectives():
    """A pending iallreduce and a subsequent blocking allreduce draw
    distinct keys from the shared call counter: neither cross-matches."""
    def closure(world):
        rank = world.get_rank()
        req = world.iallreduce(np.int64(rank), lambda a, b: a + b)
        blocking = world.allreduce(np.int64(rank * 100), lambda a, b: a + b)
        return int(req.wait(timeout=20)), int(blocking)
    out = parallelize_func(closure).execute(4)
    assert out == [(6, 600)] * 4


def test_many_outstanding_requests_one_progress_thread():
    """Eight outstanding iallreduce schedules advance on ONE engine
    thread per rank -- not thread-per-request."""
    K = 8

    def closure(world):
        rank = world.get_rank()
        before = threading.active_count()
        reqs = [world.iallreduce(np.int64(rank + k), lambda a, b: a + b)
                for k in range(K)]
        in_flight = threading.active_count()
        vals = [int(v) for v in waitall(reqs, timeout=30)]
        return vals, in_flight - before
    out = parallelize_func(closure).execute(3)
    for vals, extra in out:
        assert vals == [sum(r + k for r in range(3)) for k in range(K)]
        # active_count is process-global: at most one engine per rank
        # plus the shared deliver/expiry threads -- NOT +K per rank
        assert extra <= 6, extra


def test_ibarrier_holds_until_all_enter():
    def closure(world):
        if world.get_rank() == 0:
            world.receive(1, 1)         # enter the barrier last
            return world.ibarrier().wait(timeout=10)
        req = world.ibarrier()
        time.sleep(0.15)
        held = req.test()[0]            # rank 0 hasn't entered yet
        world.send(0, 1, "enter")
        req.wait(timeout=10)
        return held
    out = parallelize_func(closure).execute(2)
    assert out[1] is False


def test_overlap_computation_advances_during_wait():
    """The schedule advances while the caller computes: total time for
    (iallreduce + sleep) stays well under (allreduce + sleep) serial."""
    delay = 0.3

    def closure(world):
        rank = world.get_rank()
        # handshake so every rank starts its clock together
        world.barrier()
        t0 = time.monotonic()
        req = world.iallreduce(np.full(1000, float(rank)),
                               lambda a, b: a + b)
        time.sleep(delay)               # "compute"
        red = req.wait(timeout=20)
        elapsed = time.monotonic() - t0
        return float(red[0]), elapsed
    out = parallelize_func(closure).execute(3)
    for red, elapsed in out:
        assert red == 3.0
        # the collective finished inside the sleep window: no extra
        # serial communication phase after compute
        assert elapsed < delay + 0.2, elapsed


# ---------------------------------------------------------------------------
# Engine hygiene: drain, leaks, teardown
# ---------------------------------------------------------------------------

def test_engine_drain_fails_pending_requests():
    mb = Mailbox()
    eng = ProgressEngine(name="test-drain")

    def sched():
        yield (0, 0, 1)                 # a receive that never matches

    req = eng.submit(sched(), mb, timeout=30, op="iallreduce")
    assert not req.done()
    assert eng.drain("test teardown") == 1
    with pytest.raises(PeerDeadError, match="test teardown"):
        req.wait(timeout=5)
    eng.close()


def test_engine_submit_after_close_refused():
    eng = ProgressEngine(name="test-closed")
    eng.close()
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(iter(()), Mailbox(), timeout=1, op="x")


def test_cancel_pending_collective():
    mb = Mailbox()
    eng = ProgressEngine(name="test-cancel")

    def sched():
        yield (0, 0, 1)

    req = eng.submit(sched(), mb, timeout=30, op="iallreduce")
    assert req.cancel() is True
    with pytest.raises(CancelledError):
        req.wait(timeout=5)
    eng.close()


def test_local_leaked_request_does_not_wedge_execute():
    """A closure returning with a request still pending must not hang
    the world join; teardown fails the leaked request."""
    def closure(world):
        world.irecv((world.get_rank() + 1) % 2, 42)     # leaked
        return world.get_rank()
    assert parallelize_func(closure, timeout=5).execute(2) == [0, 1]


@pytest.mark.cluster
def test_pool_leaked_request_does_not_poison_next_job():
    """Cluster teardown contract: a job that leaks a pending request (and
    a half-matched iallreduce) ends cleanly, and the SAME warm pool runs
    the next job with correct results -- stale schedules cannot resume
    into the new job's comm ctx."""
    from repro.core import ClusterPool

    def leaky(world):
        world.irecv((world.get_rank() + 1) % 3, 5)      # never sent
        if world.get_rank() != 0:
            # rank 0 skips the collective: peers' schedules stay parked
            world.iallreduce(np.int64(1), lambda a, b: a + b)
        return "leaked"

    def clean(world):
        return int(world.allreduce(np.int64(world.get_rank()),
                                   lambda a, b: a + b))

    with ClusterPool(3, timeout=20) as pool:
        assert pool.run(leaky) == ["leaked"] * 3
        assert pool.run(clean) == [3, 3, 3]
        assert pool.run(clean, backend="ring") == [3, 3, 3]


def _progress_threads() -> list[str]:
    return [t.name for t in threading.enumerate()
            if t.name.startswith("mpignite-progress")]


def test_engine_soak_mixed_ops_cancel_leak_teardown():
    """Soak the engine with N concurrent *mixed* nonblocking requests per
    rank -- every collective family at once, some cancelled, some leaked
    -- and assert one engine thread per rank throughout plus full
    engine-thread teardown when the world ends."""
    K = 4           # rounds of the full mixed set

    def closure(world):
        rank, size = world.get_rank(), world.get_size()
        before = len(_progress_threads())
        add = lambda a, b: a + b
        reqs = []
        for k in range(K):
            data = np.arange(6, dtype=np.int64) * (rank + 1) + k
            reqs += [
                world.iallreduce(data, add),
                world.iallgather((rank, k)),
                world.ireduce(0, np.int64(rank + k), add),
                world.igather(1, rank * 10 + k),
                world.iscan(np.int64(rank + 1), add),
                world.ialltoall([(rank, j, k) for j in range(size)]),
                world.iscatter(2, ([(j, k) for j in range(size)]
                                   if rank == 2 else None)),
                world.ibcast(0, ("root", k) if rank == 0 else None),
            ]
        in_flight = len(_progress_threads())
        # cancel a slice before completion (some will already be done --
        # cancel() returning False is part of the contract under test)
        cancelled = [r.cancel() for r in reqs[::7]]
        vals = []
        for i, req in enumerate(reqs):
            if i % 7 == 0 and cancelled[i // 7]:
                with pytest.raises(CancelledError):
                    req.wait(timeout=10)
            else:
                vals.append(req.wait(timeout=30))
        # leak a fresh batch on purpose: the world teardown must fail
        # them without wedging the join
        world.irecv((rank + 1) % size, 99)
        if rank != 0:           # rank 0 absent => peers' schedules park
            world.iallreduce(np.int64(1), add)
        # engine threads: at most one per rank (+ shared deliver/expiry
        # threads are named differently and excluded by the filter)
        return before, in_flight, len(_progress_threads())

    n = 3
    out = parallelize_func(closure, backend="ring", timeout=20).execute(n)
    for before, in_flight, after in out:
        assert in_flight <= n, (before, in_flight)
        assert after <= n, after
    # teardown: every engine thread died with the world
    deadline = time.monotonic() + 5
    while _progress_threads() and time.monotonic() < deadline:
        time.sleep(0.05)
    assert _progress_threads() == []


@pytest.mark.cluster
@pytest.mark.timeout(120)
def test_pool_engine_soak_across_jobs_no_leakage():
    """The pooled twin of the soak: successive jobs each post mixed
    requests (some cancelled, some leaked mid-collective), and the SAME
    warm pool keeps answering correctly -- stale schedules never resume
    into a later job, and per-job engines do not accumulate threads in
    the executors."""
    from repro.core import ClusterPool

    def soak(world):
        rank, size = world.get_rank(), world.get_size()
        add = lambda a, b: a + b
        reqs = [world.iallreduce(np.arange(5, dtype=np.int64) * rank, add),
                world.iscan(np.int64(rank), add),
                world.ialltoall([rank * 10 + j for j in range(size)]),
                world.igather(0, rank)]
        reqs[1].cancel()
        vals = [reqs[0].wait(timeout=20), reqs[2].wait(timeout=20),
                reqs[3].wait(timeout=20)]
        world.irecv((rank + 1) % size, 7)       # leaked p2p request
        if rank != 0:                           # leaked, half-parked
            world.iallreduce(np.int64(1), add)  # collective (no rank 0)
        return (vals[0].tolist(), vals[1], vals[2],
                len(_progress_threads()))

    def clean(world):
        return int(world.allreduce(np.int64(world.get_rank()),
                                   lambda a, b: a + b))

    n = 3
    want_red = (np.arange(5, dtype=np.int64) * sum(range(n))).tolist()
    with ClusterPool(n, timeout=20) as pool:
        for round_ in range(3):
            out = pool.run(soak, backend="ring", timeout=20)
            for rank, (red, a2a, gat, nthreads) in enumerate(out):
                assert red == want_red, (round_, rank, red)
                assert a2a == [j * 10 + rank for j in range(n)]
                assert gat == (list(range(n)) if rank == 0 else None)
                # one engine per live job (the previous job's engine is
                # closed at dispatch-time purge): never accumulating
                assert nthreads <= 2, (round_, rank, nthreads)
            assert pool.run(clean, timeout=20) == [sum(range(n))] * n


@pytest.mark.cluster
def test_cluster_nonblocking_matches_local():
    def closure(world):
        rank = world.get_rank()
        r1 = world.iallreduce(np.arange(4, dtype=np.int64) * rank,
                              lambda a, b: a + b)
        r2 = world.iallgather(rank)
        r3 = world.ibcast(1, rank * 7 if rank == 1 else None)
        red, gat, bc = waitall([r1, r2, r3], timeout=20)
        return red.tolist(), gat, bc

    want = parallelize_func(closure).execute(3)
    got = parallelize_func(closure).execute(3, mode="cluster")
    assert got == want


# ---------------------------------------------------------------------------
# SPMD wrappers: overlap-aware cost logging
# ---------------------------------------------------------------------------

def test_overlap_scope_marks_cost_entries():
    from repro.core import cost_log
    from repro.core.comm import _log, _overlap_scope
    with cost_log() as log:
        _log("allreduce", "ring", 128, 3)
        with _overlap_scope():
            _log("allreduce", "ring", 128, 3)
    assert [c.overlap for c in log] == [False, True]
    assert log[0].bytes_per_device == log[1].bytes_per_device == 128


def test_peercomm_request_api_presence():
    """Figure-1 style parity: the nonblocking surface exists on both
    communicator families with the same spelling."""
    from repro.core import LocalComm, PeerComm
    for cls in (LocalComm, PeerComm):
        for m in ("iallreduce", "iallgather", "ibcast", "ibarrier"):
            assert hasattr(cls, m), (cls, m)
    for m in ("isend", "irecv"):
        assert hasattr(LocalComm, m)

"""Elastic worlds against real process death: shrink-to-survivors
recovery (no relaunch, PIDs stable), grow-on-join absorption at step
boundaries, and the buddy-snapshot epoch protocol under SIGKILL.

The ``chaos`` marker selects the fault-injection subset (its own CI
step); everything here is also ``cluster`` (real process worlds)."""
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core.cluster import (ClusterSupervisor, ExecutorFailure,
                                ExecutorPool)
from repro.train import ft

pytestmark = pytest.mark.cluster


# ---------------------------------------------------------------------------
# Pool-level shrink and grow
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_pool_shrink_to_survivors_keeps_pids():
    """SIGKILL one rank; the pool rebuilds the communicator over the
    survivors -- same processes, contiguous new ranks, working
    collectives -- without relaunching anything."""
    with ExecutorPool(4, backend="ring", timeout=30, hb_interval=0.05,
                      hb_timeout=0.8) as pool:
        assert pool.run(lambda c: c.allgather(c.get_rank())) == [[0, 1, 2, 3]] * 4
        pids = pool.pids
        os.kill(pids[2], signal.SIGKILL)
        time.sleep(0.3)
        with pytest.raises(ExecutorFailure):
            pool.run(lambda c: c.barrier(), timeout=20)
        assert pool.broken

        info = pool.shrink_to_survivors()
        assert info["old_size"] == 4 and info["new_world"] == [0, 1, 3]
        assert info["dead_slots"] == [2] and info["dead_old_ranks"] == [2]
        assert info["old_rank_of"] == [0, 1, 3]
        assert pool.size == 3 and not pool.broken
        # survivors kept their processes: this was a re-broker, not a fork
        assert [pool.pids[s] for s in pool.world] == [pids[0], pids[1],
                                                      pids[3]]
        out = pool.run(lambda c: (c.get_rank(), c.get_size(),
                                  float(c.allreduce(
                                      np.float64(c.get_rank() + 1),
                                      lambda a, b: a + b))))
        assert out == [(0, 3, 6.0), (1, 3, 6.0), (2, 3, 6.0)]


def _seg_allreduce_job(c):
    rng = np.random.default_rng(c.get_rank())
    x = rng.standard_normal(1 << 12).astype(np.float32)
    return c.allreduce(x, lambda a, b: a + b)


@pytest.mark.timeout(120)
def test_grow_on_join_bitexact_with_static_oracle():
    """A fresh rank dials the driver, parks, is absorbed at a boundary;
    the grown world's segmented allreduce is bit-exact against a world
    that was 3-wide from the start."""
    kw = dict(backend="ring", timeout=30, hb_interval=0.05, hb_timeout=1.0)
    with ExecutorPool(3, **kw) as oracle:
        want = oracle.run(_seg_allreduce_job, backend="segmented",
                          segment_bytes=4096)
    with ExecutorPool(2, **kw) as pool:
        pids0 = [pool.pids[s] for s in pool.world]
        pool.run(lambda c: c.allgather(c.get_rank()))
        pool.spawn_joiner()
        deadline = time.time() + 30
        while pool.pending_joins() < 1 and time.time() < deadline:
            time.sleep(0.05)
        assert pool.pending_joins() == 1
        assert pool.size == 2                     # parked, not yet a member

        assert pool.absorb_joiners() == [2]
        assert pool.size == 3 and pool.pending_joins() == 0
        got = pool.run(_seg_allreduce_job, backend="segmented",
                       segment_bytes=4096)
        # the original members were not relaunched to grow the world
        assert [pool.pids[s] for s in pool.world[:2]] == pids0
        assert len(got) == 3
        for g, w in zip(got, want):
            assert g.dtype == w.dtype
            np.testing.assert_array_equal(g, w)   # bit-exact, not approx


@pytest.mark.chaos
@pytest.mark.timeout(120)
def test_join_during_inflight_segmented_iallreduce_parks(tmp_path):
    """A rank that dials mid-job -- while a segmented iallreduce is in
    flight -- must be parked until the step boundary: the running job's
    world and results are untouched, and the next boundary absorbs it."""
    gate = str(tmp_path / "inflight")

    def job(c):
        if c.get_rank() == 0:
            open(gate, "w").close()              # signal: job is in flight
        cc = c.with_segment_bytes(2048)
        acc = np.zeros(1 << 10, np.float32)
        for i in range(30):
            x = np.full(1 << 10, float(c.get_rank() + i), np.float32)
            acc = acc + cc.iallreduce(x, lambda a, b: a + b).wait(timeout=30)
            time.sleep(0.02)
        return acc

    with ExecutorPool(2, backend="segmented", timeout=90, hb_interval=0.05,
                      hb_timeout=2.0) as pool:
        res = {}
        t = threading.Thread(
            target=lambda: res.setdefault("out", pool.run(job, timeout=90)))
        t.start()
        deadline = time.time() + 30
        while not os.path.exists(gate) and time.time() < deadline:
            time.sleep(0.02)
        assert os.path.exists(gate)
        pool.spawn_joiner()                       # dials mid-collective
        t.join(timeout=100)
        assert not t.is_alive()

        expect = np.full(1 << 10,
                         float(sum(r + i for r in range(2)
                                   for i in range(30))), np.float32)
        np.testing.assert_array_equal(res["out"][0], expect)
        assert pool.size == 2                     # never joined mid-job
        deadline = time.time() + 30
        while pool.pending_joins() < 1 and time.time() < deadline:
            time.sleep(0.05)
        assert pool.pending_joins() == 1
        assert pool.absorb_joiners() == [2]
        assert pool.run(lambda c: c.allgather(c.get_size())) == [[3, 3, 3]] * 3


# ---------------------------------------------------------------------------
# Supervisor: shrink-first recovery, suspicion, buddy-snapshot chaos
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.timeout(120)
def test_post_shrink_dispatch_skips_dead_rank_straggler_wait():
    """A rank SIGKILLed mid-job can never deliver its result. The next
    dispatch after shrink must not sit in the straggler drain until the
    *failed* job's deadline waiting for it -- with a long job timeout
    that used to stall the whole pool for minutes after recovery."""
    with ExecutorPool(3, backend="ring", timeout=30, hb_interval=0.05,
                      hb_timeout=0.8) as pool:
        victim = pool.pids[2]
        killer = threading.Timer(0.4, os.kill, (victim, signal.SIGKILL))
        killer.start()
        with pytest.raises(ExecutorFailure):
            # no collectives: the survivors finish on their own and
            # report results; only the dead rank's slot stays unfilled
            pool.run(lambda c: time.sleep(1.5) or c.get_rank(),
                     timeout=90)
        killer.join()
        pool.shrink_to_survivors()
        time.sleep(1.5)             # let survivor stragglers deliver
        t0 = time.monotonic()
        assert pool.run(lambda c: c.get_rank(), timeout=30) == [0, 1]
        assert time.monotonic() - t0 < 10   # not the failed job's 90s


@pytest.mark.chaos
@pytest.mark.timeout(120)
def test_supervisor_elastic_shrink_no_relaunch(tmp_path):
    """SIGKILL between steps with ``elastic=True``: the supervisor
    shrinks to the survivors (same PIDs -- no relaunch), restores the
    step-4 checkpoint, resumes degraded per RecoveryPolicy, and the run
    completes with the correct (smaller-world) results."""
    total, n, kill_after = 8, 3, 4
    killed, pids_seen = [], {}

    def make_step(run, step):
        def closure(comm):
            rank = comm.get_rank()
            restored = run.restore()
            acc = 0.0 if restored is None else float(restored[0]["acc"][0])
            acc += float(comm.allreduce(np.float64(step),
                                        lambda a, b: a + b))
            if rank == 0:
                run.save(step, {"acc": np.array([acc])})
            return acc, comm.backend
        return closure

    def on_step(step, pool):
        pids_seen[step] = [pool.pids[s] for s in pool.world]
        if step == kill_after and not killed:
            killed.append(pool.pids[1])
            os.kill(pool.pids[1], signal.SIGKILL)
            time.sleep(0.3)

    policy = ft.RecoveryPolicy(degrade_backend="linear", recovery_steps=2,
                               max_restarts=3)
    sup = ClusterSupervisor(str(tmp_path), policy=policy,
                            fast_backend="ring", timeout=30,
                            hb_interval=0.05, hb_timeout=0.8,
                            elastic=True, min_ranks=2)
    out = sup.run_steps(make_step, n, total, on_step=on_step)

    assert killed and sup.state.restarts == 1
    assert sup.state.shrinks == 1                 # recovered WITHOUT relaunch
    pre, post = pids_seen[kill_after], pids_seen[total]
    assert post == [pre[0], pre[2]]               # survivors kept their PIDs
    # steps 1..4 summed over 3 ranks, 5..8 over the shrunken 2
    expect = sum(3.0 * s for s in range(1, kill_after + 1)) + \
        sum(2.0 * s for s in range(kill_after + 1, total + 1))
    assert len(out) == n - 1                      # degraded world size
    for acc, backend in out:
        assert acc == expect
        assert backend == "ring"                  # past the degrade window

    # degrade schedule was honored on the shrunken pool, too
    assert sup.failures[0][0] == kill_after


@pytest.mark.timeout(120)
def test_suspect_after_beats_hard_timeout(tmp_path):
    """A SIGSTOPped rank (process alive, connection open, heartbeats
    silent) is only caught by staleness: the suspicion threshold
    declares it dead and shrinks long before hb_timeout=30s would."""
    total, n = 6, 3
    stopped = []

    def make_step(run, step):
        def closure(comm):
            return float(comm.allreduce(np.float64(step),
                                        lambda a, b: a + b))
        return closure

    def on_step(step, pool):
        if step == 2 and not stopped:
            stopped.append(pool.pids[pool.world[1]])
            os.kill(stopped[0], signal.SIGSTOP)
            time.sleep(1.0)                       # staleness accrues

    sup = ClusterSupervisor(str(tmp_path),
                            policy=ft.RecoveryPolicy(recovery_steps=1,
                                                     max_restarts=2),
                            fast_backend="ring", timeout=30,
                            hb_interval=0.05, hb_timeout=30.0,
                            elastic=True, min_ranks=1, suspect_after=0.6)
    t0 = time.monotonic()
    try:
        out = sup.run_steps(make_step, n, total, on_step=on_step)
        elapsed = time.monotonic() - t0
    finally:
        if stopped:                               # never leak a stopped proc
            try:
                os.kill(stopped[0], signal.SIGKILL)
            except ProcessLookupError:
                pass
    assert sup.state.shrinks == 1
    assert "suspected dead" in sup.failures[0][1]
    assert elapsed < 20.0                         # nowhere near hb_timeout
    assert out == [2.0 * total] * 2               # finished on 2 ranks


@pytest.mark.chaos
@pytest.mark.timeout(180)
def test_sigkill_mid_snapshot_stale_epoch_never_restored(tmp_path):
    """The acceptance chaos case: a rank SIGKILLs mid-flight through an
    async buddy snapshot of epoch K. Nobody commits K, so recovery in
    the shrunken world agrees on K-1 -- the torn epoch is unreachable --
    and the dead rank's K-1 shard is rebuilt from its buddy's copy."""
    total, n, kill_step = 6, 3, 4
    marker = str(tmp_path / "recover.txt")

    def make_step(run, step):
        shrink = run.shrink_info

        def closure(comm):
            from repro.train import buddy as B
            bc = B.BuddyCheckpointer("chaos-snap", history=6)
            rank = comm.get_rank()
            if shrink is not None:
                ep, shards = bc.recover(comm, shrink["old_size"],
                                        shrink["old_rank_of"],
                                        shrink["dead_old_ranks"])
                if rank == 0:
                    dead = shrink["dead_old_ranks"][0]
                    with open(marker, "w") as f:
                        f.write(f"{ep}|{float(shards[dead][0])}")
            h = bc.snapshot(comm, step, np.full(2, 10.0 * rank + step))
            if run.attempt == 0 and step == kill_step and rank == 1:
                os.kill(os.getpid(), signal.SIGKILL)   # mid-snapshot death
            try:
                bc.commit(comm, h)
            except Exception:
                # the failure this snapshot was meant to survive: the
                # epoch stays staged-but-uncommitted, per the protocol
                pass
            if rank == 0:
                run.save(step, {"s": np.zeros(1)})
            return step
        return closure

    sup = ClusterSupervisor(str(tmp_path),
                            policy=ft.RecoveryPolicy(recovery_steps=1,
                                                     max_restarts=3),
                            fast_backend="ring", timeout=60,
                            hb_interval=0.05, hb_timeout=0.8,
                            elastic=True, min_ranks=2)
    out = sup.run_steps(make_step, n, total)

    assert sup.state.shrinks == 1 and len(out) == n - 1
    ep, dead_val = open(marker).read().split("|")
    # epoch kill_step was torn: the agreement lands on the last epoch
    # that committed world-wide, never the stale one
    assert int(ep) == kill_step - 1
    # and the dead rank's shard at that epoch came from its buddy
    assert float(dead_val) == 10.0 * 1 + (kill_step - 1)


@pytest.mark.chaos
@pytest.mark.timeout(180)
def test_owner_and_buddy_dead_falls_back_to_disk(tmp_path):
    """Double failure -- a rank AND the buddy holding its shard die
    together. In-memory recovery is impossible (BuddyShardLost); the
    closure falls back to the disk checkpoint and the run completes."""
    total, n, kill_after = 6, 4, 3
    marker = str(tmp_path / "fallback.txt")
    killed = []

    def make_step(run, step):
        shrink = run.shrink_info

        def closure(comm):
            from repro.train import buddy as B
            bc = B.BuddyCheckpointer("chaos-dbl", history=6)
            rank = comm.get_rank()
            restored = run.restore()
            acc = 0.0 if restored is None else float(restored[0]["acc"][0])
            if shrink is not None:
                try:
                    bc.recover(comm, shrink["old_size"],
                               shrink["old_rank_of"],
                               shrink["dead_old_ranks"])
                    src = "buddy"
                except B.BuddyShardLost:
                    src = "disk"      # acc above IS the disk fallback
                if rank == 0:
                    open(marker, "w").write(src)
            acc += float(comm.allreduce(np.float64(step),
                                        lambda a, b: a + b))
            try:
                bc.commit(comm, bc.snapshot(comm, step, np.array([acc])))
            except Exception:
                pass
            if rank == 0:
                run.save(step, {"acc": np.array([acc])})
            return acc
        return closure

    def on_step(step, pool):
        if step == kill_after and not killed:
            for w in (1, 2):          # old rank 1 and its buddy, rank 2
                killed.append(pool.pids[pool.world[w]])
                os.kill(pool.pids[pool.world[w]], signal.SIGKILL)
            time.sleep(0.3)

    sup = ClusterSupervisor(str(tmp_path),
                            policy=ft.RecoveryPolicy(recovery_steps=1,
                                                     max_restarts=3),
                            fast_backend="ring", timeout=60,
                            hb_interval=0.05, hb_timeout=0.8,
                            elastic=True, min_ranks=2)
    out = sup.run_steps(make_step, n, total, on_step=on_step)

    assert len(killed) == 2 and sup.state.shrinks == 1
    assert open(marker).read() == "disk"
    expect = sum(4.0 * s for s in range(1, kill_after + 1)) + \
        sum(2.0 * s for s in range(kill_after + 1, total + 1))
    assert out == [expect] * 2


@pytest.mark.timeout(120)
def test_run_steps_final_results_survive_posthumous_failure(tmp_path):
    """The lost-final-result hole: a failure lands after the final step
    completed (checkpoint saved, results persisted). A resume that finds
    nothing left to execute must return the real per-rank results, not
    raise."""
    total, n = 4, 2
    killed = []

    def make_step(run, step):
        def closure(comm):
            rank = comm.get_rank()
            if rank == 0:
                run.save(step, {"s": np.full(1, float(step))})
            return step * 100 + rank
        return closure

    def on_step(step, pool):
        if step == total and not killed:
            killed.append(pool.pids[pool.world[0]])
            os.kill(killed[0], signal.SIGKILL)
            time.sleep(0.3)
            # the *next* dispatch attempt notices the death; there is no
            # next step, so only the persisted results can save the run
            pool.fail_ranks([pool.world[0]], "post-final-step death")

    sup = ClusterSupervisor(str(tmp_path),
                            policy=ft.RecoveryPolicy(recovery_steps=1,
                                                     max_restarts=2),
                            fast_backend="ring", timeout=30,
                            hb_interval=0.05, hb_timeout=0.8,
                            elastic=True, min_ranks=1)
    out = sup.run_steps(make_step, n, total, on_step=on_step)
    assert out == [total * 100 + r for r in range(n)]
    assert sup.state.restarts == 1                # the failure was real

"""The section-Perf levers: correctness of microbatching, ZeRO++-style
int8 weight gathers, lean Adafactor, and the serving (fsdp=False) layout."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.model import Model
from repro.parallel import axes as A
from repro.parallel.ops import ParallelConfig, make_ops
from repro.train.optim import OptConfig, Optimizer

AXES1 = A.MeshAxes(1, 1, 1)
KEY = jax.random.PRNGKey(0)


def _setup(pcfg, dtype=jnp.float32):
    cfg = dataclasses.replace(get_config("stablelm-3b", smoke=True),
                              dtype=dtype)
    model = Model(cfg, AXES1, pcfg)
    params = model.init(KEY, dtype=dtype)
    batch = {"tokens": np.asarray(
        jax.random.randint(KEY, (4, 32), 0, cfg.vocab))}
    return cfg, model, params, batch


def test_microbatch_grads_match_full_batch():
    """mb=4 accumulated grads == single-batch grads (linearity of the
    mean over equal-sized microbatches)."""
    pcfg = ParallelConfig(sequence_parallel=False, remat="none")
    cfg, model, params, batch = _setup(pcfg)
    ops = make_ops(AXES1, pcfg)

    def gfull(p):
        return jax.grad(lambda q: model.loss(ops, q, batch)[0])(p)

    m = 4
    mb = {"tokens": batch["tokens"].reshape(m, 1, 32)}

    def gacc(p):
        def one(i):
            b = {"tokens": mb["tokens"][i]}
            return jax.grad(lambda q: model.loss(ops, q, b)[0])(p)
        acc = jax.tree.map(jnp.zeros_like, p)
        for i in range(m):
            acc = jax.tree.map(lambda a, g: a + g / m, acc, one(i))
        return acc

    ga, gb = gfull(params), gacc(params)
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-3)


def test_lean_adafactor_state_has_no_master():
    opt = Optimizer(OptConfig(name="adafactor", master=False, lr_peak=0.05,
                              warmup_steps=1, total_steps=100,
                              weight_decay=0.0))
    params = {"w": jnp.full((8, 16), 2.0, jnp.bfloat16)}
    state = opt.init(params)
    assert "master" not in state
    ps = opt.state_pspecs_from(
        {"w": __import__("repro.models.common", fromlist=["ParamSpec"])
         .ParamSpec((8, 16), P())})
    assert "master" not in ps

    def loss_fn(p):
        return jnp.sum(p["w"].astype(jnp.float32) ** 2)
    l0 = float(loss_fn(params))
    for _ in range(40):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
    assert float(loss_fn(params)) < 0.5 * l0


def test_quantized_gather_error_and_exact_bwd():
    """int8 qwZ gather: forward RMS error < 1%, backward == exact
    reduce-scatter (tested at data=1 where gather is identity-shaped,
    via the custom_vjp wiring on a fake 4-way comm in a subprocessless
    single-axis world is not expressible; here we check the quantizer
    round-trip error bound that the gather inherits)."""
    from repro.train.compress import quantize_int8
    w = jax.random.normal(KEY, (256, 128), jnp.float32) * 0.02
    q, s = quantize_int8(w)
    deq = q.astype(jnp.float32) * s
    rel = float(jnp.linalg.norm(deq - w) / jnp.linalg.norm(w))
    assert rel < 0.01, rel


def test_serving_layout_strips_data_axis():
    pcfg = ParallelConfig(sequence_parallel=False, remat="none",
                          fsdp=False)
    axes = A.MeshAxes(data=4, model=2, pod=1)
    cfg = get_config("qwen3-4b", smoke=True)
    model = Model(cfg, axes, pcfg)
    for spec in jax.tree.leaves(
            model.pspecs, is_leaf=lambda s: isinstance(s, P)):
        flat = [n for e in spec if e is not None
                for n in (e if isinstance(e, tuple) else (e,))]
        assert A.DATA_AXIS not in flat, spec
    # fsdp=True keeps it
    model2 = Model(cfg, axes, pcfg.replace(fsdp=True))
    found = any(
        A.DATA_AXIS in [n for e in spec if e is not None
                        for n in (e if isinstance(e, tuple) else (e,))]
        for spec in jax.tree.leaves(
            model2.pspecs, is_leaf=lambda s: isinstance(s, P)))
    assert found


def test_decode_grouped_attention_matches_repeat():
    """The no-repeat GQA decode einsum equals explicit KV repetition."""
    from repro.models.attention import attn_decode
    B, S, Hq, Hkv, D = 2, 64, 8, 2, 32
    q = jax.random.normal(KEY, (B, 1, Hq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, D))
    kv_len = jnp.asarray([40, 64])
    out = attn_decode(q, k, v, kv_len=kv_len)
    out_rep = attn_decode(q, jnp.repeat(k, 4, 2), jnp.repeat(v, 4, 2),
                          kv_len=kv_len)
    np.testing.assert_allclose(out, out_rep, atol=1e-5, rtol=1e-5)

"""Fault injection against the nonblocking layer: a rank is SIGKILLed
while its peers are blocked in ``Request.wait`` on an in-flight
iallreduce. Pending requests must fail promptly with ``PeerDeadError``
(the driver's failure detector notifies survivors via a ``peer_dead``
control frame -- nobody waits out the full receive timeout), and
``ClusterSupervisor`` checkpoint-restart recovery must still complete
the workload on a fresh pool."""
import os
import signal
import time

import numpy as np
import pytest

from repro.core import (ExecutorFailure, ExecutorPool, PeerDeadError)
from repro.core.cluster import ClusterSupervisor
from repro.train import ft

pytestmark = pytest.mark.cluster

#: receive/job timeout far above the detection path: if survivors only
#: unblocked by timing out, the elapsed assertions below would fail.
SLOW_TIMEOUT = 30.0


def _write_marker(d: str, rank: int, elapsed: float, exc: BaseException):
    with open(os.path.join(d, f"rank{rank}"), "w") as f:
        f.write(f"{elapsed:.3f}|{type(exc).__name__}|{exc}")


def _read_markers(d: str) -> dict[int, tuple[float, str, str]]:
    out = {}
    for name in os.listdir(d):
        if name.startswith("rank"):
            elapsed, kind, msg = open(os.path.join(d, name)).read().split(
                "|", 2)
            out[int(name[4:])] = (float(elapsed), kind, msg)
    return out


@pytest.mark.timeout(120)
def test_sigkill_mid_iallreduce_fails_requests_and_recovers(tmp_path):
    """The acceptance path: SIGKILL rank 2 while ranks {0,1,3} are blocked
    in Request.wait on an in-flight ring iallreduce. Every survivor's
    request fails with PeerDeadError well before the 30s receive timeout,
    the driver raises ExecutorFailure, and the supervisor completes the
    workload on a relaunched world."""
    n = 4
    marker_dir = str(tmp_path / "markers")
    os.makedirs(marker_dir)

    def make_closure(run):
        def closure(comm):
            rank = comm.get_rank()
            if run.attempt == 0:
                if rank == 2:
                    time.sleep(0.4)     # let peers park in Request.wait
                    os.kill(os.getpid(), signal.SIGKILL)
                req = comm.iallreduce(np.full(256, float(rank)),
                                      lambda a, b: a + b)
                t0 = time.monotonic()
                try:
                    req.wait(timeout=SLOW_TIMEOUT)
                except PeerDeadError as e:
                    _write_marker(marker_dir, rank,
                                  time.monotonic() - t0, e)
                    raise
                return "attempt-0 completed?!"
            red = comm.allreduce(np.full(256, float(rank)),
                                 lambda a, b: a + b)
            return float(red[0])
        return closure

    policy = ft.RecoveryPolicy(degrade_backend="linear", recovery_steps=1,
                               max_restarts=2)
    sup = ClusterSupervisor(str(tmp_path / "ckpt"), policy=policy,
                            fast_backend="ring", timeout=SLOW_TIMEOUT,
                            hb_interval=0.05, hb_timeout=0.8)
    out = sup.run(make_closure, n)

    # recovery completed with correct results on the relaunched world
    assert out == [float(sum(range(n)))] * n
    assert sup.state.restarts == 1 and len(sup.failures) == 1

    markers = _read_markers(marker_dir)
    assert sorted(markers) == [0, 1, 3], markers     # every survivor
    for rank, (elapsed, kind, msg) in markers.items():
        assert kind == "PeerDeadError", (rank, kind, msg)
        assert "declared dead" in msg and "2" in msg
        # unblocked by the peer_dead notification, not the 30s deadline
        assert elapsed < SLOW_TIMEOUT / 3, (rank, elapsed)


@pytest.mark.timeout(120)
def test_sigkill_mid_segmented_iallreduce_fails_fast(tmp_path):
    """Segmented transfers must not accumulate per-segment hangs: a rank
    SIGKILLed mid-segmented-iallreduce (hundreds of outstanding segment
    receives in the schedule) fails every survivor's Request with
    PeerDeadError at the *first* parked segment -- once, promptly -- and
    ``ClusterSupervisor`` still recovers on a fresh world."""
    n = 4
    marker_dir = str(tmp_path / "markers")
    os.makedirs(marker_dir)

    def make_closure(run):
        def closure(comm):
            # tiny segments: the 16 KiB payload streams as ~hundreds of
            # per-segment messages through the segmented ring schedule
            comm = comm.with_segment_bytes(256)
            rank = comm.get_rank()
            if run.attempt == 0:
                if rank == 2:
                    time.sleep(0.4)     # let peers park mid-schedule
                    os.kill(os.getpid(), signal.SIGKILL)
                req = comm.iallreduce(np.full(2048, float(rank)),
                                      lambda a, b: a + b)
                t0 = time.monotonic()
                try:
                    req.wait(timeout=SLOW_TIMEOUT)
                except PeerDeadError as e:
                    _write_marker(marker_dir, rank,
                                  time.monotonic() - t0, e)
                    raise
                return "attempt-0 completed?!"
            red = comm.allreduce(np.full(2048, float(rank)),
                                 lambda a, b: a + b)
            return float(red[0])
        return closure

    policy = ft.RecoveryPolicy(degrade_backend="linear", recovery_steps=1,
                               max_restarts=2)
    sup = ClusterSupervisor(str(tmp_path / "ckpt"), policy=policy,
                            fast_backend="segmented", timeout=SLOW_TIMEOUT,
                            hb_interval=0.05, hb_timeout=0.8)
    out = sup.run(make_closure, n)

    assert out == [float(sum(range(n)))] * n
    assert sup.state.restarts == 1 and len(sup.failures) == 1

    markers = _read_markers(marker_dir)
    assert sorted(markers) == [0, 1, 3], markers     # every survivor
    for rank, (elapsed, kind, msg) in markers.items():
        assert kind == "PeerDeadError", (rank, kind, msg)
        assert "declared dead" in msg and "2" in msg
        # one prompt failure at the first parked segment -- NOT a
        # timeout per segment (which would multiply far past this bound)
        assert elapsed < SLOW_TIMEOUT / 3, (rank, elapsed)


@pytest.mark.timeout(120)
def test_peer_death_fails_blocking_receive_and_irecv(tmp_path):
    """The poison covers every receive discipline: a blocking receive and
    a pending irecv Request targeting (or transitively stuck behind) the
    dead rank both fail with PeerDeadError, promptly."""
    marker_dir = str(tmp_path)

    def closure(world):
        rank = world.get_rank()
        if rank == 2:
            time.sleep(0.3)
            world.die()     # abrupt exit: no result frame, no goodbye
        t0 = time.monotonic()
        try:
            if rank == 0:
                world.receive(2, 9)                 # blocking receive
            else:
                world.irecv(2, 9).wait(timeout=SLOW_TIMEOUT)
        except PeerDeadError as e:
            _write_marker(marker_dir, rank, time.monotonic() - t0, e)
            raise
        return "completed?!"

    pool = ExecutorPool(3, timeout=SLOW_TIMEOUT, hb_interval=0.05,
                        hb_timeout=0.8)
    try:
        with pytest.raises(ExecutorFailure) as ei:
            pool.run(closure)
        assert 2 in ei.value.dead_ranks
        deadline = time.monotonic() + 10    # markers are written by the
        while time.monotonic() < deadline:  # executors after the driver
            if len(_read_markers(marker_dir)) == 2:     # already raised
                break
            time.sleep(0.05)
    finally:
        pool.shutdown()
    markers = _read_markers(marker_dir)
    assert sorted(markers) == [0, 1], markers
    for rank, (elapsed, kind, _) in markers.items():
        assert kind == "PeerDeadError"
        assert elapsed < SLOW_TIMEOUT / 3, (rank, elapsed)


@pytest.mark.timeout(60)
def test_buffered_messages_survive_poison():
    """Poison fails only *pending* receives: a message that arrived
    before the death is still deliverable (no data loss for matched
    traffic)."""
    from repro.core import Mailbox
    mb = Mailbox()
    mb.put(0, 1, 5, "arrived-before-death")
    fut_pending = mb.get_async(0, 2, 7, timeout=30)
    mb.poison_all("rank 7 declared dead")
    with pytest.raises(PeerDeadError):
        fut_pending.result(timeout=5)
    assert mb.get(0, 1, 5, timeout=1) == "arrived-before-death"
    with pytest.raises(PeerDeadError):      # next blocking receive fails
        mb.get(0, 1, 5, timeout=1)

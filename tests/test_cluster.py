"""Cluster transport: wire codec, multi-process equivalence with the
thread oracle, the persistent executor pool + direct data plane,
heartbeat failure detection, and checkpoint-restart recovery (the
paper's section-3.1 fault story against *real* process death, not
simulation)."""
import os
import signal
import time

import numpy as np
import pytest

from repro.core import parallelize_func
from repro.core.cluster import (ClusterFuncRDD, ClusterPool,
                                ClusterSupervisor, ExecutorFailure,
                                ExecutorPool, get_pool, wire)
from repro.train import ft

pytestmark = pytest.mark.cluster       # own CI job: real process worlds


# ---------------------------------------------------------------------------
# Wire protocol
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("obj", [
    None,
    42,
    3.5,
    "hello",
    True,
    [1, "two", 3.0, None],
    (1, (2, 3)),
    {"a": 1, "b": [2, {"c": 3}]},
    np.arange(12, dtype=np.int64).reshape(3, 4),
    np.linspace(0, 1, 7, dtype=np.float32),
    {"params": {"w": np.ones((2, 3), np.float32),
                "b": np.zeros(3, np.float64)},
     "step": 7, "tags": ["x", "y"]},
    np.float32(1.5),
    np.int64(-3),
])
def test_wire_codec_roundtrip(obj):
    out = wire.decode(wire.encode(obj))

    def eq(a, b):
        if isinstance(a, np.ndarray):
            return (isinstance(b, np.ndarray) and a.dtype == b.dtype
                    and a.shape == b.shape and np.array_equal(a, b))
        if isinstance(a, dict):
            return set(a) == set(b) and all(eq(a[k], b[k]) for k in a)
        if isinstance(a, (list, tuple)):
            return (type(a) is type(b) and len(a) == len(b)
                    and all(eq(x, y) for x, y in zip(a, b)))
        return a == b and type(a) is type(b)
    assert eq(obj, out), (obj, out)


def test_wire_codec_bf16_and_pickle_fallback():
    import ml_dtypes
    arr = np.linspace(-2, 2, 8).astype(ml_dtypes.bfloat16)
    out = wire.decode(wire.encode(arr))
    assert out.dtype == arr.dtype
    np.testing.assert_array_equal(out.view(np.uint16), arr.view(np.uint16))
    # arbitrary objects fall back to a pickle buffer
    obj = {"s": {1, 2, 3}, "arr": np.arange(3)}   # set is not JSON-able
    out = wire.decode(wire.encode(obj))
    assert out["s"] == {1, 2, 3}
    np.testing.assert_array_equal(out["arr"], np.arange(3))


# ---------------------------------------------------------------------------
# Multi-process equivalence with the thread oracle
# ---------------------------------------------------------------------------

def _full_api_closure(world):
    """Ring p2p + collectives + runtime split, all dynamic-routing."""
    rank, size = world.get_rank(), world.get_size()
    if rank == 0:
        world.send(1, 0, 42)
        token = world.receive(size - 1, 0)
    else:
        token = world.receive(rank - 1, 0)
        world.send((rank + 1) % size, 0, token)
    fut = world.receive_async((rank + 1) % size, 5)
    world.send((rank - 1) % size, 5, rank * 10)
    async_val = fut.result(timeout=30)
    s = world.allreduce(np.float64(rank), lambda a, b: a + b)
    g = world.allgather(rank * 2)
    arr = world.allreduce(np.arange(4, dtype=np.float32) * rank,
                          lambda a, b: a + b)
    red = world.reduce(0, rank, lambda a, b: a + b)
    gat = world.gather(1, rank)
    scn = world.scan(rank, lambda a, b: a + b)
    a2a = world.alltoall([rank * 100 + j for j in range(size)])
    world.barrier()
    sub = world.split(rank % 2, rank)
    ssum = sub.allreduce(rank, lambda a, b: a + b)
    srank = sub.get_rank()
    return (token, async_val, float(s), g, arr.tolist(), red, gat, scn,
            a2a, ssum, srank)


@pytest.mark.parametrize("n", [2, 5])
def test_cluster_matches_local_oracle(n):
    want = parallelize_func(_full_api_closure).execute(n)
    got = parallelize_func(_full_api_closure).execute(n, mode="cluster")
    assert got == want


def test_cluster_ring_backend_matches_linear():
    def closure(world):
        r = world.get_rank()
        s = world.allreduce(np.float64(r + 1), lambda a, b: a + b)
        g = world.allgather(r)
        b = world.broadcast(2, r * 3 if r == 2 else None)
        return float(s), g, b
    lin = ClusterFuncRDD(closure, backend="linear").execute(4)
    ring = ClusterFuncRDD(closure, backend="ring").execute(4)
    assert lin == ring == [(10.0, [0, 1, 2, 3], 6)] * 4


def test_cluster_arbitrary_payloads():
    """The runtime transports arbitrary python objects, like local mode."""
    def closure(world):
        r = world.get_rank()
        if r == 0:
            world.send(1, 0, {"nested": [np.eye(2), ("t", r)], "ok": True})
            return None
        msg = world.receive(0, 0)
        return (np.array_equal(msg["nested"][0], np.eye(2)),
                msg["nested"][1], msg["ok"])
    out = ClusterFuncRDD(closure).execute(2)
    assert out[1] == (True, ("t", 0), True)


def test_with_backend_shares_call_counter():
    """A comm and its with_backend clones are one logical communicator:
    their collectives must draw keys from a single sequence, or two steps
    (one on the parent, one on a clone) would issue identical match
    contexts and staggered ranks could cross-match messages."""
    from repro.core.local import LocalComm, _World
    comm = LocalComm(_World(1), (0,), 0, ctx=0)
    clone = comm.with_backend("ring")
    keys = [comm._next_key(), clone._next_key(), comm._next_key()]
    assert len(set(keys)) == 3
    assert keys[0][-1] < keys[1][-1] < keys[2][-1]


def test_cluster_executor_exception_propagates():
    def closure(world):
        if world.get_rank() == 1:
            raise ValueError("boom on rank 1")
        return world.get_rank()
    with pytest.raises(RuntimeError, match="boom on rank 1"):
        ClusterFuncRDD(closure, timeout=30).execute(3)


def test_executor_error_beats_deadlock_verdict():
    """When one rank raises and the others block waiting for it, the
    driver must surface the root-cause traceback, not a phantom
    deadlock/heartbeat failure."""
    def closure(world):
        if world.get_rank() == 1:
            raise ValueError("root cause on rank 1")
        return world.receive(1, 0)        # blocks forever
    with pytest.raises(RuntimeError, match="root cause on rank 1"):
        ClusterFuncRDD(closure, timeout=30, hb_interval=0.05,
                       hb_timeout=0.5).execute(2)


def test_parallel_closure_backend_reaches_both_runtimes():
    """An explicit backend= on parallelize_func must reach local and
    cluster equally: a non-commutative allreduce fold exposes the
    difference between linear (rank-ordered at the root) and ring
    (rotation-ordered per rank)."""
    def closure(world):
        return world.allreduce(str(world.get_rank()), lambda a, b: a + b)

    for backend in ["linear", "native"]:     # native aliases linear
        loc = parallelize_func(closure, backend=backend).execute(3)
        clu = parallelize_func(closure, backend=backend).execute(
            3, mode="cluster")
        assert loc == clu == ["012"] * 3, (backend, loc, clu)
    # ring: every rank folds in its own rotation order -- same on both
    # runtimes, different from linear
    loc = parallelize_func(closure, backend="ring").execute(3)
    clu = parallelize_func(closure, backend="ring").execute(
        3, mode="cluster")
    assert loc == clu, (loc, clu)
    assert loc != ["012"] * 3


# ---------------------------------------------------------------------------
# Failure detection + checkpoint-restart recovery
# ---------------------------------------------------------------------------

def test_heartbeat_detects_stalled_executor():
    """A wedged executor (process alive, closure stuck, heartbeats
    silenced) is declared dead by the driver's monitor."""
    import time

    def closure(world):
        if world.get_rank() == 1:
            world.channel.stop_heartbeat()
            time.sleep(30)
        return world.receive(1, 0)   # never arrives
    rdd = ClusterFuncRDD(closure, timeout=30, hb_interval=0.05,
                         hb_timeout=0.5)
    with pytest.raises(ExecutorFailure, match="missed heartbeats") as ei:
        rdd.execute(2)
    assert ei.value.dead_ranks == [1]


def test_heartbeat_detects_killed_executor():
    """Abrupt process death (no result frame, no goodbye) is detected."""
    def closure(world):
        if world.get_rank() == 0:
            world.die()
        world.barrier()
    rdd = ClusterFuncRDD(closure, timeout=30, hb_interval=0.05,
                         hb_timeout=0.5)
    with pytest.raises(ExecutorFailure) as ei:
        rdd.execute(2)
    assert 0 in ei.value.dead_ranks


@pytest.mark.timeout(120)
def test_supervisor_kill_restart_recovery(tmp_path):
    """The acceptance path: kill one executor mid-run; the supervisor
    detects it via missed heartbeats, restores the latest checkpoint,
    relaunches with backend='linear' for recovery_steps, then resumes the
    fast backend -- and the run completes with correct results."""
    total, n = 10, 4
    kill_step = 5

    def make_closure(run):
        def closure(comm):
            rank = comm.get_rank()
            restored = run.restore()
            if restored is None:
                acc, start = np.zeros(3, np.float64), 0
            else:
                flat, _, start = restored
                acc = flat["acc"]
            backends = []
            for step in range(start + 1, total + 1):
                c = run.comm_for(comm, step)
                backends.append(c.backend)
                acc = acc + c.allreduce(np.full(3, float(rank * step)),
                                        lambda a, b: a + b)
                if run.attempt == 0 and step == kill_step and rank == 2:
                    c.die()                      # real process loss
                if rank == 0:
                    run.save(step, {"acc": acc})
                comm.barrier()
            return acc.tolist(), backends
        return closure

    policy = ft.RecoveryPolicy(degrade_backend="linear", recovery_steps=3,
                               max_restarts=3)
    sup = ClusterSupervisor(str(tmp_path), policy=policy,
                            fast_backend="ring", timeout=60,
                            hb_interval=0.05, hb_timeout=0.8)
    out = sup.run(make_closure, n)

    assert sup.state.restarts == 1
    assert len(sup.failures) == 1 and "heartbeat" in sup.failures[0][1]
    expect = float(sum(sum(range(n)) * s for s in range(1, total + 1)))
    for acc, _ in out:
        assert acc == [expect] * 3
    # the relaunch ran degraded (phase-1 linear) for recovery_steps steps,
    # then resumed the fast peer-to-peer backend
    _, backends = out[0]
    restart_from = sup.failures[0][0]
    want = ["linear" if s <= restart_from + policy.recovery_steps else "ring"
            for s in range(restart_from + 1, total + 1)]
    assert backends == want
    assert "ring" in backends and "linear" in backends


# ---------------------------------------------------------------------------
# Persistent executor pool + direct data plane
# ---------------------------------------------------------------------------

def test_pool_warm_reuse_same_processes():
    """Executors survive across run() calls: the second job is dispatched
    to the same live processes, not a re-forked world."""
    with ClusterPool(3) as pool:
        pids = pool.pids
        out1 = pool.run(lambda c: c.allgather(c.get_rank()))
        out2 = pool.run(
            lambda c: float(c.allreduce(np.float64(1.0), lambda a, b: a + b)),
            backend="ring")
        assert pool.pids == pids
    assert out1 == [[0, 1, 2]] * 3
    assert out2 == [3.0] * 3


@pytest.mark.timeout(60)
def test_pool_survives_idle_beyond_connect_timeout():
    """The connect timeout must not become a control-socket read
    timeout: a warm pool's control plane is legitimately quiet between
    jobs (heartbeats flow executor->driver only), so executors must not
    exit while the pool idles."""
    with ClusterPool(2, timeout=3) as pool:
        assert pool.run(lambda c: c.get_rank()) == [0, 1]
        time.sleep(4.5)                       # idle > connect timeout
        assert pool.run(lambda c: c.get_rank() + 1) == [1, 2]


def test_direct_data_plane_bypasses_driver():
    """The acceptance property: a p2p payload between two executors
    traverses zero driver sockets. The driver counts every frame it
    sees; in direct mode no 'msg' frame may appear there, while relay
    mode (the PR-1 behavior) routes every one through it."""
    payload = np.arange(1 << 16, dtype=np.float64)        # 512 KiB

    def closure(world):
        if world.get_rank() == 0:
            world.send(1, 7, payload)
            return 0.0
        return float(world.receive(0, 7).sum())

    with ExecutorPool(2, data_plane="direct") as pool:
        out = pool.run(closure)
        assert out[1] == float(payload.sum())
        assert pool.frame_counts.get("msg", 0) == 0, pool.frame_counts

    with ExecutorPool(2, data_plane="relay") as pool:
        out = pool.run(closure)
        assert out[1] == float(payload.sum())
        assert pool.frame_counts.get("msg", 0) >= 1


def test_pool_survives_job_exception():
    """A closure error is a job failure, not a pool failure: the
    traceback propagates and the same pool serves the next job -- even a
    short-deadline one, because dispatch first drains the straggler rank
    still blocked in the errored job's closure."""
    def bad(world):
        if world.get_rank() == 1:
            raise ValueError("job boom")
        return world.receive(1, 0)      # straggler: blocks to job timeout

    with ClusterPool(2, timeout=30) as pool:
        with pytest.raises(RuntimeError, match="job boom"):
            pool.run(bad, timeout=3)
        assert not pool.broken
        assert pool.run(lambda c: c.get_rank(), timeout=5) == [0, 1]
        assert not pool.broken


def test_pool_rejects_jobs_after_rank_death():
    """Rank death breaks the pool: the failing run raises
    ExecutorFailure and later dispatches are refused."""
    def die0(world):
        if world.get_rank() == 0:
            world.die()
        world.barrier()

    pool = ExecutorPool(2, timeout=30, hb_interval=0.05, hb_timeout=0.5)
    try:
        with pytest.raises(ExecutorFailure):
            pool.run(die0)
        assert pool.broken
        with pytest.raises(ExecutorFailure):
            pool.run(lambda c: c.get_rank())
    finally:
        pool.shutdown()


def test_warm_pool_cache_replaces_broken_pool():
    """get_pool hands back the cached live pool, and transparently
    replaces one that a failure broke."""
    p1 = get_pool(2, backend="linear")
    assert get_pool(2, backend="linear") is p1

    def die0(world):
        if world.get_rank() == 0:
            world.die()
        world.barrier()

    with pytest.raises(ExecutorFailure):
        p1.run(die0, timeout=30)
    p2 = get_pool(2, backend="linear")
    assert p2 is not p1
    assert p2.run(lambda c: c.get_rank()) == [0, 1]


@pytest.mark.timeout(120)
def test_pool_sigkill_between_jobs_supervisor_recovery(tmp_path):
    """Failure *between* pooled jobs: an executor is SIGKILLed while the
    pool idles between two run() calls. The next dispatch detects the
    dead rank, and the supervisor's checkpoint-restart path recovers on
    a fresh pool -- degraded backend first, then the fast one."""
    total, n, kill_after = 8, 3, 4
    killed = []

    def make_step(run, step):
        def closure(comm):
            rank = comm.get_rank()
            restored = run.restore()
            acc = 0.0 if restored is None else float(restored[0]["acc"][0])
            acc += float(comm.allreduce(np.float64(rank * step),
                                        lambda a, b: a + b))
            if rank == 0:
                run.save(step, {"acc": np.array([acc])})
            return acc, comm.backend
        return closure

    def on_step(step, pool):
        if step == kill_after and not killed:
            killed.append(pool.pids[1])
            os.kill(pool.pids[1], signal.SIGKILL)
            time.sleep(0.2)        # let the OS reap / EOF propagate

    policy = ft.RecoveryPolicy(degrade_backend="linear", recovery_steps=2,
                               max_restarts=3)
    sup = ClusterSupervisor(str(tmp_path), policy=policy,
                            fast_backend="ring", timeout=30,
                            hb_interval=0.05, hb_timeout=0.8)
    out = sup.run_steps(make_step, n, total, on_step=on_step)

    assert killed and sup.state.restarts == 1
    assert len(sup.failures) == 1
    assert sup.failures[0][0] == kill_after       # restart from step 4 ckpt
    expect = float(sum(step * sum(range(n)) for step in range(1, total + 1)))
    for acc, backend in out:
        assert acc == expect
        assert backend == "ring"                  # recovered past degrade


def test_supervisor_restart_budget(tmp_path):
    """A rank that dies on every attempt exhausts max_restarts."""
    def make_closure(run):
        def closure(comm):
            if comm.get_rank() == 0:
                comm.die()
            comm.barrier()
        return closure

    policy = ft.RecoveryPolicy(recovery_steps=1, max_restarts=2)
    sup = ClusterSupervisor(str(tmp_path), policy=policy, timeout=30,
                            hb_interval=0.05, hb_timeout=0.4)
    with pytest.raises(RuntimeError, match="restart budget exhausted"):
        sup.run(make_closure, 2)
    assert sup.state.restarts == policy.max_restarts + 1

"""Cross-mode equivalence oracle: one operation, three deployments.

Usage: python _cross_mode_check.py <op>   (op: ring_p2p | allreduce |
allgather | split)

Runs the op's closure with 8 ranks under mode="local" (threads),
mode="cluster" (real processes over TCP) and mode="spmd" (8 forced host
devices, static-routing subset) and asserts identical results. The
runtime closure is shared verbatim by local and cluster; the spmd closure
is the static-routing spelling of the same program.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys                                         # noqa: E402

import jax.numpy as jnp                            # noqa: E402
import numpy as np                                 # noqa: E402

from repro.core import parallelize_func            # noqa: E402

N = 8


def runtime_ring_p2p(world):
    r, p = world.get_rank(), world.get_size()
    world.send((r + 1) % p, 0, float(r + 1))
    return world.receive((r - 1) % p, 0)


def spmd_ring_p2p(world):
    return world.shift(jnp.float32(world.rank() + 1), 1)


def runtime_allreduce(world):
    return world.allreduce(float(world.get_rank() + 1),
                           lambda a, b: a + b)


def spmd_allreduce(world):
    return world.allreduce(jnp.float32(world.rank() + 1), "add")


def runtime_allgather(world):
    return world.allgather(float(world.get_rank() * 2))


def spmd_allgather(world):
    return world.allgather(jnp.float32(world.rank() * 2))


def runtime_split(world):
    r = world.get_rank()
    row = world.split(r // 4, r)     # 2 rows of 4
    return row.allreduce(float(r), lambda a, b: a + b)


def spmd_split(world):
    row = world.split([i // 4 for i in range(N)], list(range(N)))
    return row.allreduce(jnp.float32(world.rank()), "add")


def runtime_iallreduce(world):
    # nonblocking: post the reduction, compute locally while the progress
    # engine advances it, then wait -- same value as the blocking op
    req = world.iallreduce(float(world.get_rank() + 1), lambda a, b: a + b)
    local = sum(float(i) for i in range(100))
    return req.wait() + local * 0.0


def spmd_iallreduce(world):
    req = world.iallreduce(jnp.float32(world.rank() + 1), "add")
    return req.wait()


OPS = {
    "ring_p2p": (runtime_ring_p2p, spmd_ring_p2p),
    "allreduce": (runtime_allreduce, spmd_allreduce),
    "allgather": (runtime_allgather, spmd_allgather),
    "split": (runtime_split, spmd_split),
    "iallreduce": (runtime_iallreduce, spmd_iallreduce),
}


def flatten(out):
    """Per-rank result -> flat list of floats, mode-agnostic."""
    vals = []
    for item in out:
        arr = np.asarray(item, dtype=np.float64).reshape(-1)
        vals.extend(float(v) for v in arr)
    return vals


def main():
    op = sys.argv[1]
    runtime_fn, spmd_fn = OPS[op]
    want = flatten(parallelize_func(runtime_fn).execute(N))

    got_cluster = flatten(
        parallelize_func(runtime_fn).execute(N, mode="cluster"))
    assert got_cluster == want, (op, "cluster", got_cluster, want)

    for backend in ["native", "ring", "linear"]:
        got_spmd = flatten(parallelize_func(spmd_fn, backend=backend)
                           .execute(N, mode="spmd"))
        assert got_spmd == want, (op, "spmd", backend, got_spmd, want)
    print(f"CROSS-MODE OK {op}: local == cluster == spmd(x3 backends)")


if __name__ == "__main__":
    main()

"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes each Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention, flash_attention_fwd
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


ATTN_CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window, dtype
    (2, 128, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 256, 4, 4, 64, False, 0, jnp.float32),
    (2, 256, 256, 8, 2, 128, True, 64, jnp.float32),
    (1, 128, 384, 2, 1, 64, True, 0, jnp.float32),      # chunked prefill
    (1, 192, 192, 2, 2, 64, True, 0, jnp.float32),      # non-multiple of 128
    (2, 128, 128, 4, 1, 64, True, 0, jnp.bfloat16),
    (1, 128, 128, 2, 2, 96, True, 48, jnp.bfloat16),    # odd head dim
]


@pytest.mark.parametrize("case", ATTN_CASES,
                         ids=[f"attn{i}" for i in range(len(ATTN_CASES))])
def test_flash_attention_matches_oracle(case):
    B, Sq, Sk, Hq, Hkv, D, causal, window, dtype = case
    q = rand(KEY, (B, Sq, Hq, D), dtype)
    k = rand(jax.random.fold_in(KEY, 1), (B, Sk, Hkv, D), dtype)
    v = rand(jax.random.fold_in(KEY, 2), (B, Sk, Hkv, D), dtype)
    qoff = Sk - Sq
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              q_offset=qoff, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=qoff)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=tol, rtol=tol)


def test_flash_attention_grads_flow():
    q = rand(KEY, (1, 128, 2, 64), jnp.float32)
    k = rand(jax.random.fold_in(KEY, 1), (1, 128, 2, 64), jnp.float32)
    v = rand(jax.random.fold_in(KEY, 2), (1, 128, 2, 64), jnp.float32)

    def f(q, k, v):
        return flash_attention(q, k, v, True, 0, 0, 128, 128, True).sum()

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q, k, v: ref.attention_ref(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


SSD_CASES = [
    (2, 256, 4, 64, 64, 128, jnp.float32),
    (1, 128, 2, 32, 16, 64, jnp.float32),
    (2, 512, 3, 16, 8, 128, jnp.float32),
    (1, 256, 2, 64, 32, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES,
                         ids=[f"ssd{i}" for i in range(len(SSD_CASES))])
def test_ssd_scan_matches_sequential_oracle(case):
    B, S, H, P, N, Q, dtype = case
    x = rand(KEY, (B, S, H, P), dtype) * 0.5
    dt = jax.nn.softplus(rand(jax.random.fold_in(KEY, 1), (B, S, H),
                              jnp.float32))
    a_log = rand(jax.random.fold_in(KEY, 2), (H,), jnp.float32) * 0.3
    Bm = rand(jax.random.fold_in(KEY, 3), (B, S, N), dtype) * 0.5
    Cm = rand(jax.random.fold_in(KEY, 4), (B, S, N), dtype) * 0.5
    y = ssd_scan(x, dt, a_log, Bm, Cm, chunk=Q, interpret=True)
    want, state_ref = ref.ssd_ref(x, dt, a_log, Bm, Cm)
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(y.astype(np.float32),
                               want.astype(np.float32), atol=tol, rtol=tol)
    # the XLA chunk decomposition must agree too (and provides the state)
    y2, state = ssd_chunked(x, dt, a_log, Bm, Cm, chunk=Q)
    np.testing.assert_allclose(y2.astype(np.float32),
                               want.astype(np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(state, state_ref, atol=tol, rtol=tol)


@pytest.mark.parametrize("shape,dtype", [
    ((4, 96, 160), jnp.bfloat16),
    ((2, 33, 256), jnp.float32),
    ((1, 1, 64), jnp.float32),
    ((512, 128), jnp.bfloat16),
])
def test_rmsnorm_matches_oracle(shape, dtype):
    x = rand(KEY, shape, dtype)
    w = rand(jax.random.fold_in(KEY, 9), (shape[-1],), jnp.float32)
    out = rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=2e-2, rtol=2e-2)

"""The shared-memory transport tier (``core.cluster.shm``).

Three layers:

- pure unit tests of the SPSC ring segment: roundtrip, wrap-around,
  the <8-byte end-of-region pad skip, backpressure (full-ring
  ``ConnectionError``), never-fits records, the crc gate that holds
  back stale/torn records until their bytes are really visible, and
  the contiguous ``pack_frame``/``unpack_frame`` codec the rings
  carry;
- ``cluster`` integration: a direct-plane pool auto-selects shm between
  same-host ranks (observed via the per-channel shm counters), an
  ``shm=False`` pool stays pure TCP, and a clean shutdown unlinks every
  brokered segment;
- ``chaos``: SIGKILL a rank mid-shm transfer -- survivors' parked
  receives fail with ``PeerDeadError`` (not a hang), the driver raises
  ``ExecutorFailure``, and teardown leaves zero ``/dev/shm`` segments
  behind even though the victim never got to clean up.
"""
import glob
import os
import signal
import struct
import time

import numpy as np
import pytest

from repro.core.cluster import ExecutorPool, get_pool
from repro.core.cluster import shm as shm_mod
from repro.core.cluster import wire
from repro.core.cluster.shm import ShmRings


def _segments() -> set[str]:
    return {os.path.basename(p)
            for p in glob.glob(f"/dev/shm/{shm_mod.SEG_PREFIX}*")}


@pytest.fixture
def rings():
    r = ShmRings.create(nrings=2, cap=4096)
    yield r
    r.close()
    shm_mod.unlink(r.name)


# ---------------------------------------------------------------------------
# ring mechanics
# ---------------------------------------------------------------------------

def test_ring_roundtrip_many_records(rings):
    att = ShmRings.attach(rings.name)
    try:
        msgs = [os.urandom(n) for n in (0, 1, 7, 100, 1000)]
        for m in msgs:
            assert att.write(0, m)
        got = []
        while (r := rings.try_read(0)) is not None:
            got.append(r)
        assert got == msgs
        assert rings.try_read(0) is None
        assert rings.pending(0) == 0
    finally:
        att.close()


def test_ring_wraps_and_skips_short_end_stub(rings):
    """Drive the cursors past the region end repeatedly, including the
    case where fewer than 8 bytes remain before the end (the record
    header must be contiguous, so both sides deterministically skip the
    stub): 2000- then 2077-byte records park the cursors 3 bytes from
    the region end, so the next write must pad."""
    a, b = b"A" * 2000, b"B" * 2077
    assert rings.write(0, a)
    assert rings.try_read(0) == a
    assert rings.write(0, b)
    assert rings.try_read(0) == b                   # head=tail=4093
    for i in range(50):                             # many wraps + pads
        m = bytes([i % 256]) * (1000 + i * 7 % 97)
        assert rings.write(0, m)
        assert rings.try_read(0) == m
    assert rings.pending(0) == 0


def test_ring_interleaved_wrap_with_backlog(rings):
    """Records queued two-deep across the wrap point survive intact."""
    a, b = os.urandom(1800), os.urandom(1900)
    for _ in range(20):
        assert rings.write(1, a)
        assert rings.write(1, b)
        assert rings.try_read(1) == a
        assert rings.try_read(1) == b


def test_ring_backpressure_and_never_fits(rings):
    big = b"z" * 2000
    assert rings.write(0, big)
    assert rings.write(0, big)                       # 4016 of 4096 used
    with pytest.raises(ConnectionError, match="full"):
        rings.write(0, big, deadline=0.05)           # consumer wedged
    assert rings.try_read(0) == big                  # drain one...
    assert rings.write(0, big, deadline=0.05)        # ...and it fits again
    # a record larger than the ring can *ever* hold: False (use TCP),
    # never an exception
    assert rings.write(0, b"q" * 4096) is False
    assert rings.write(0, b"q" * rings.max_record() + b"!") is False
    # out-of-range ring index (a joiner beyond the provisioned slots)
    assert rings.write(99, b"hi") is False
    assert rings.write(-1, b"hi") is False


def test_ring_withholds_stale_bytes_until_visible(rings):
    """The consumer's visibility gate: on hosts where a shared mapping
    is only eventually coherent, the reader can see ``head`` before the
    record bytes. Simulate both stale-header and stale-payload views by
    stomping the committed bytes -- ``try_read`` must return None (not
    garbage, not an exception) and must not advance ``tail``, then heal
    and deliver the record once the true bytes 'arrive' again."""
    assert rings.write(0, b"ok")
    base = rings._data(0)
    # stale header: a length word from another lap looks like garbage
    struct.pack_into("<I", rings._seg.buf, base, 1 << 30)
    assert rings.try_read(0) is None
    assert rings.pending(0) > 0                     # tail did not move
    struct.pack_into("<I", rings._seg.buf, base, 2)
    assert rings.try_read(0) == b"ok"               # healed
    # stale payload: length+crc visible, one payload byte still old
    # (cursors sit at 10 after the 2-byte record, so the new record's
    # 8-byte header is at +10 and its payload starts at +18)
    assert rings.write(0, b"payload!")
    old = rings._seg.buf[base + 18]
    rings._seg.buf[base + 18] = (old + 1) % 256
    assert rings.try_read(0) is None                # crc gate holds it
    assert rings.pending(0) > 0
    rings._seg.buf[base + 18] = old
    assert rings.try_read(0) == b"payload!"
    assert rings.pending(0) == 0


def test_attach_validates_magic():
    from multiprocessing import shared_memory
    seg = shared_memory.SharedMemory(name=f"{shm_mod.SEG_PREFIX}bogus-test",
                                     create=True, size=4096)
    try:
        with pytest.raises(ValueError, match="not an MPIgnite"):
            ShmRings.attach(seg.name)
    finally:
        seg.close()
        seg.unlink()


def test_unlink_reaps_name_once():
    r = ShmRings.create(nrings=1, cap=4096)
    name = r.name
    r.close()
    assert name in _segments()
    assert shm_mod.unlink(name) is True
    assert name not in _segments()
    assert shm_mod.unlink(name) is False            # already gone
    with pytest.raises(FileNotFoundError):
        ShmRings.attach(name)


def test_enable_and_ring_bytes_env(monkeypatch):
    monkeypatch.delenv(shm_mod.ENABLE_ENV, raising=False)
    assert shm_mod.enabled()
    for off in ("0", "false", "OFF", "no", ""):
        monkeypatch.setenv(shm_mod.ENABLE_ENV, off)
        assert not shm_mod.enabled(), off
    monkeypatch.setenv(shm_mod.ENABLE_ENV, "1")
    assert shm_mod.enabled()
    monkeypatch.delenv(shm_mod.RING_BYTES_ENV, raising=False)
    assert shm_mod.ring_bytes() == shm_mod.DEFAULT_RING_BYTES
    monkeypatch.setenv(shm_mod.RING_BYTES_ENV, str(1 << 16))
    assert shm_mod.ring_bytes() == 1 << 16
    for bad in ("12", "-5", "zap"):                 # too small / invalid
        monkeypatch.setenv(shm_mod.RING_BYTES_ENV, bad)
        assert shm_mod.ring_bytes() == shm_mod.DEFAULT_RING_BYTES


def test_host_token_is_stable_and_host_shaped():
    a, b = shm_mod.host_token(), shm_mod.host_token()
    assert a == b and "|" in a


# ---------------------------------------------------------------------------
# the contiguous frame codec shm records ride
# ---------------------------------------------------------------------------

def test_pack_unpack_frame_roundtrip():
    hdr = {"kind": "msg", "ctx": 7, "tag": -3, "src": 2, "job": 1}
    for payload in (b"", b"x", os.urandom(4096)):
        header, body = wire.unpack_frame(wire.pack_frame(hdr, payload))
        assert header == hdr and bytes(body) == payload
    # multi-part payloads concatenate exactly like the socket path
    parts = [b"abc", b"", os.urandom(100)]
    header, body = wire.unpack_frame(wire.pack_frame(hdr, parts))
    assert bytes(body) == b"".join(parts)


def test_unpack_frame_rejects_malformed():
    good = wire.pack_frame({"a": 1}, b"xyz")
    for bad in (b"", b"\x00" * 3, good[:-1], good + b"!",
                b"\xff" * len(good)):
        with pytest.raises(ValueError):
            wire.unpack_frame(bad)


# ---------------------------------------------------------------------------
# cluster integration: auto-selection, counters, clean teardown
# ---------------------------------------------------------------------------

def _collect_and_stats(comm):
    out = comm.allreduce(np.arange(512, dtype=np.int64),
                         lambda a, b: a + b)
    comm.barrier()
    s = comm._chan.stats.summary()
    return (out.tolist(), s["shm_tx_frames"], s["shm_rx_frames"],
            s["tx_frames"])


@pytest.mark.cluster
@pytest.mark.timeout(120)
def test_pool_auto_selects_shm_and_unlinks_on_shutdown():
    before = _segments()
    with ExecutorPool(4, timeout=60.0, data_plane="direct",
                      shm=True) as pool:
        during = _segments() - before
        assert len(during) >= 4                 # one segment per rank
        out = pool.run(_collect_and_stats, backend="ring", timeout=60.0)
        want = (np.arange(512, dtype=np.int64) * 4).tolist()
        for rank, (got, shm_tx, shm_rx, tx) in enumerate(out):
            assert got == want, rank
            # the p=4 whole-buffer ring moves 3 data messages each way
            # per rank, all eligible for shm (same host by definition)
            assert shm_tx >= 3 and shm_rx >= 3, (rank, shm_tx, shm_rx)
            assert shm_tx <= tx
    after = _segments() - before
    assert after == set(), f"leaked segments: {after}"


@pytest.mark.cluster
@pytest.mark.timeout(120)
def test_shm_disabled_pool_stays_on_tcp():
    with ExecutorPool(2, timeout=60.0, data_plane="direct",
                      shm=False) as pool:
        out = pool.run(_collect_and_stats, backend="ring", timeout=60.0)
        want = (np.arange(512, dtype=np.int64) * 2).tolist()
        for got, shm_tx, shm_rx, tx in out:
            assert got == want
            assert shm_tx == 0 and shm_rx == 0
            assert tx > 0


@pytest.mark.cluster
@pytest.mark.timeout(120)
def test_shm_fragments_oversized_frames():
    """A frame bigger than one ring record (8 MiB payload vs the 4 MiB
    default ring) is fragmented through the ring and reassembled, not
    spilled to TCP -- frame size must never select the transport, or a
    big send and a small same-tag successor could be reordered across
    the two reader threads."""
    def closure(comm):
        x = np.arange(1 << 20, dtype=np.float64) * (comm.get_rank() + 1)
        # segment_bytes=0 disables the segmented upgrade, forcing
        # whole-buffer 8 MiB wire frames through the ring backend
        out = comm.with_backend("ring").allreduce(x, lambda a, b: a + b)
        comm.barrier()
        s = comm._chan.stats.summary()
        return (float(out[1]), s["shm_tx_frames"], s["shm_rx_frames"])

    with ExecutorPool(2, timeout=60.0, data_plane="direct",
                      shm=True) as pool:
        out = pool.run(closure, timeout=60.0, segment_bytes=0)
    for val, shm_tx, shm_rx in out:
        assert val == 3.0                   # 1*(1) + 1*(2)
        assert shm_tx >= 1 and shm_rx >= 1, (shm_tx, shm_rx)


@pytest.mark.cluster
@pytest.mark.timeout(120)
def test_get_pool_caches_shm_and_tcp_pools_separately():
    a = get_pool(2, data_plane="direct", shm=True)
    b = get_pool(2, data_plane="direct", shm=False)
    assert a is not b
    assert a is get_pool(2, data_plane="direct", shm=True)


# ---------------------------------------------------------------------------
# chaos: SIGKILL mid-shm transfer
# ---------------------------------------------------------------------------

@pytest.mark.cluster
@pytest.mark.chaos
@pytest.mark.timeout(180)
def test_sigkill_mid_shm_transfer_fails_fast_and_leaks_nothing(tmp_path):
    """Rank 1 SIGKILLs itself between shm ring rounds. Survivors parked
    on receives from the victim must fail with ``PeerDeadError`` well
    before the receive timeout, the driver must raise
    ``ExecutorFailure``, and -- the lifecycle point of the tier --
    every brokered segment (including the dead rank's, which its owner
    can no longer clean up) is unlinked by the driver at teardown."""
    from repro.core import ExecutorFailure, PeerDeadError

    marker_dir = str(tmp_path / "markers")
    os.makedirs(marker_dir)
    before = _segments()

    def closure(comm):
        rank = comm.get_rank()
        x = np.arange(1 << 15, dtype=np.int64)      # 256 KiB via shm
        t0 = time.monotonic()
        try:
            for i in range(100):
                x = comm.with_backend("ring").allreduce(
                    x, lambda a, b: a + b)
                if i == 2 and rank == 1:
                    s = comm._chan.stats.summary()
                    with open(os.path.join(marker_dir, "victim"),
                              "w") as f:
                        f.write(str(s["shm_tx_frames"]))
                    os.kill(os.getpid(), signal.SIGKILL)
        except PeerDeadError as e:
            with open(os.path.join(marker_dir, f"rank{rank}"), "w") as f:
                f.write(f"{time.monotonic() - t0:.3f}")
            raise e
        return "survived"

    with pytest.raises(ExecutorFailure):
        with ExecutorPool(3, timeout=30.0, data_plane="direct", shm=True,
                          hb_interval=0.05, hb_timeout=0.8) as pool:
            pool.run(closure, timeout=30.0)

    victim = os.path.join(marker_dir, "victim")
    assert os.path.exists(victim), "victim never reached the shm rounds"
    assert int(open(victim).read()) > 0, "victim was not sending via shm"
    survivors = sorted(n for n in os.listdir(marker_dir)
                       if n.startswith("rank"))
    assert survivors, "no survivor saw PeerDeadError"
    for n in survivors:
        assert float(open(os.path.join(marker_dir, n)).read()) < 25.0
    after = _segments() - before
    assert after == set(), f"leaked segments: {after}"

"""The trip-count-aware HLO parser: dot FLOPs, while multipliers, fusion
memory model and collective byte parsing."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.launch import hlo_analysis as H


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    s = H.summarize(compile_text(f, a, b), 1)
    want = 2 * 64 * 128 * 32
    assert abs(s.flops - want) / want < 0.05, (s.flops, want)


def test_while_trip_count_multiplies():
    def f(x):
        y, _ = lax.scan(lambda c, _: (jnp.tanh(c @ c), None), x, None,
                        length=17)
        return y.sum()
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    s = H.summarize(compile_text(f, x), 1)
    one_dot = 2 * 64 * 64 * 64
    assert s.flops > 17 * one_dot * 0.95
    assert s.flops < 17 * one_dot * 1.3   # + tanh elementwise


def test_grad_scan_counts_both_loops():
    def f(x):
        y, _ = lax.scan(lambda c, _: (jnp.tanh(c @ c), None), x, None,
                        length=10)
        return y.sum()
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    s = H.summarize(compile_text(jax.grad(f), x), 1)
    one_dot = 2 * 32 * 32 * 32
    # fwd: 10 dots; bwd: 2 dots per step = 30 total
    assert s.flops > 28 * one_dot, s.flops / one_dot


def test_scan_memory_not_inflated_by_stacked_buffers():
    """The scan body reads one (64,64) slice of the stacked (40,64,64)
    weights per iteration -- memory must scale with slices, not buffers."""
    def f(ws, x):
        y, _ = lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y.sum()
    ws = jax.ShapeDtypeStruct((40, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    s = H.summarize(compile_text(f, ws, x), 1, norm_float_bytes=0)
    per_iter = 3 * 64 * 64 * 4          # w slice + x in + x out
    assert s.mem_bytes < 40 * per_iter * 6, \
        f"{s.mem_bytes} vs {40 * per_iter}"


def test_bf16_normalization():
    def f(a, b):
        return (a @ b).sum()
    a32 = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b32 = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = compile_text(f, a32, b32)
    full = H.summarize(txt, 1, norm_float_bytes=0)
    norm = H.summarize(txt, 1, norm_float_bytes=2)
    assert 0.45 < norm.mem_bytes / full.mem_bytes < 0.55


SYNTH = """\
HloModule test, entry_computation_layout={()->f32[]}

%body.1 (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %arg = (s32[], f32[128,256]) parameter(0)
  %gte = f32[128,256] get-tuple-element(%arg), index=1
  %ar = f32[128,256] all-reduce(%gte), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %cp = f32[128,256] collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
}

ENTRY %main.1 (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  %t = (s32[], f32[128,256]) tuple(%p0)
  %w = (s32[], f32[128,256]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  %ag = f32[512,256] all-gather(%p0), replica_groups=[2,4]<=[8], dimensions={0}
}
"""


def test_collective_parsing_synthetic():
    s = H.summarize(SYNTH, 8, norm_float_bytes=0)
    nb = 128 * 256 * 4
    # all-reduce in a 12-trip loop over groups of 4: 2*S*(3/4) each
    want_ar = 12 * 2 * nb * 3 / 4
    want_cp = 12 * nb
    want_ag = (512 * 256 * 4) * 3 / 4
    assert abs(s.coll_bytes["all-reduce"] - want_ar) < 1
    assert abs(s.coll_bytes["collective-permute"] - want_cp) < 1
    assert abs(s.coll_bytes["all-gather"] - want_ag) < 1
    assert s.coll_count["all-reduce"] == 12


def test_schedule_lists_collectives():
    sched = H.collective_schedule(SYNTH, 8, norm_float_bytes=0)
    ops = sorted(r["op"] for r in sched)
    assert ops == ["all-gather", "all-reduce", "collective-permute"]
    ar = [r for r in sched if r["op"] == "all-reduce"][0]
    assert ar["times"] == 12 and ar["group"] == 4

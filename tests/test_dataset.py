"""The Spark-shaped dataset layer (``repro.data.dataset``).

Fast lane: placement math, wordcount/sort/groupByKey conformance of the
thread runtime and the driver-gather baseline against the
single-process oracle, cache()/lineage behavior, and the
``batch_shards`` pipeline re-expression.

``cluster`` lane: the same conformance over real executor processes.
``chaos`` lane: SIGKILL a rank mid-shuffle and prove lineage recomputes
exactly the lost partitions, bit-exact."""
import os
import signal
import threading
from collections import Counter

import numpy as np
import pytest

from repro.core import groups as G
from repro.data import DataContext, SyntheticTokens, batch_shards, make_batch

TEXT = ("the quick brown fox jumps over the lazy dog "
        "the dog barks and the fox runs away " * 9).split()
ADD = lambda a, b: a + b    # noqa: E731


def wordcount(ctx, nparts=5, out=4):
    return (ctx.parallelize(TEXT, nparts)
              .map(lambda w: (w, 1))
              .reduceByKey(ADD, nparts=out)
              .sortByKey(nparts=3))


def mixed_group(ctx):
    return (ctx.range(120, nparts=7)
              .flatMap(lambda i: [(i % 10, i), (i % 3, -i)])
              .filter(lambda kv: kv[1] % 2 == 0)
              .groupByKey(nparts=3))


def oracle(build, n=4):
    with DataContext(n, mode="single") as ctx:
        return build(ctx).collect()


# ---------------------------------------------------------------------------
# placement math (groups.py)
# ---------------------------------------------------------------------------

def test_partition_placement_covers_everything():
    for nparts in (1, 3, 8, 11):
        for size in (1, 2, 4, 5):
            owners = [G.partition_owner(p, nparts, size)
                      for p in range(nparts)]
            assert all(0 <= o < size for o in owners)
            seen = [p for r in range(size)
                    for p in G.owned_partitions(r, nparts, size)]
            assert sorted(seen) == list(range(nparts))
            rounds = G.shuffle_rounds(nparts, size)
            assert all(len(G.owned_partitions(r, nparts, size)) <= rounds
                       for r in range(size))


def test_lost_partitions_is_dead_owner_preimage():
    assert G.lost_partitions(8, [1], 4) == {1, 5}
    assert G.lost_partitions(8, [0, 2], 4) == {0, 2, 4, 6}
    assert G.lost_partitions(5, [], 4) == set()


def test_stable_key_hash_is_process_stable():
    # identical across calls, spread across buckets, and independent of
    # the builtin salted hash
    assert G.stable_key_hash("spark") == G.stable_key_hash("spark")
    assert G.stable_key_hash(("a", 1)) != G.stable_key_hash(("a", 2))
    buckets = {G.stable_key_hash(f"w{i}") % 8 for i in range(100)}
    assert len(buckets) == 8


# ---------------------------------------------------------------------------
# single-process oracle semantics
# ---------------------------------------------------------------------------

def test_wordcount_matches_counter():
    got = oracle(wordcount)
    assert dict(got) == Counter(TEXT)
    assert [k for k, _ in got] == sorted(set(TEXT))


def test_groupbykey_groups_everything():
    got = dict(oracle(mixed_group))
    want = {}
    for i in range(120):
        for k, v in ((i % 10, i), (i % 3, -i)):
            if v % 2 == 0:
                want.setdefault(k, []).append(v)
    assert {k: sorted(vs) for k, vs in got.items()} == \
        {k: sorted(vs) for k, vs in want.items()}


def test_sort_orders_and_keeps_duplicates():
    def build(ctx):
        return (ctx.range(200, nparts=6).map(lambda i: (i % 9, i))
                  .sortByKey(nparts=4))
    got = oracle(build)
    assert len(got) == 200
    assert [k for k, _ in got] == sorted(k for k, _ in got)

    def build_desc(ctx):
        return (ctx.range(60, nparts=4).map(lambda i: (i % 7, i))
                  .sortByKey(ascending=False, nparts=3))
    keys = [k for k, _ in oracle(build_desc)]
    assert keys == sorted(keys, reverse=True)


def test_non_pair_records_raise():
    with DataContext(2, mode="single") as ctx:
        with pytest.raises(TypeError, match="key, value"):
            ctx.range(4).reduceByKey(ADD).collect()


def test_closed_context_refuses_work():
    ctx = DataContext(2, mode="local")
    ctx.close()
    with pytest.raises(RuntimeError, match="closed"):
        ctx.parallelize([1, 2])


# ---------------------------------------------------------------------------
# cross-mode conformance: local threads and the driver-gather baseline
# must be bit-exact with the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("build", [wordcount, mixed_group],
                         ids=["wordcount", "groupby"])
def test_local_and_gather_match_oracle(build):
    want = oracle(build)
    with DataContext(4, mode="local") as ctx:
        assert build(ctx).collect() == want
        assert build(ctx).collect(shuffle="gather") == want


def test_local_matches_oracle_when_nparts_exceeds_world():
    def build(ctx):
        return (ctx.parallelize(TEXT, 11).map(lambda w: (w[0], 1))
                  .reduceByKey(ADD, nparts=9).sortByKey(nparts=2))
    want = oracle(build, n=2)
    with DataContext(2, mode="local") as ctx:
        assert build(ctx).collect() == want


# ---------------------------------------------------------------------------
# cache() and lineage bookkeeping
# ---------------------------------------------------------------------------

def test_cache_short_circuits_upstream():
    calls = []
    lock = threading.Lock()

    def spy(x):
        with lock:
            calls.append(x)
        return (x % 3, x)

    with DataContext(2, mode="local") as ctx:
        ds = ctx.range(12, nparts=4).map(spy).cache()
        first = ds.groupByKey(nparts=2).collect()
        assert sorted(calls) == list(range(12))
        assert ds.groupByKey(nparts=2).collect() == first
        assert len(calls) == 12             # cached: map did not re-run
        ctx.clear_cache()
        ds.groupByKey(nparts=2).collect()
        assert len(calls) == 24             # dropped: map re-ran


def test_shuffle_outputs_are_reused_across_collects():
    calls = []
    lock = threading.Lock()

    def spy(kv):
        with lock:
            calls.append(kv)
        return kv

    with DataContext(2, mode="local") as ctx:
        counts = (ctx.parallelize(TEXT, 4).map(lambda w: (w, 1))
                    .map(spy).reduceByKey(ADD, nparts=4))
        counts.collect()
        n1 = len(calls)
        counts.collect()                    # same shuffle uid: store hit
        assert len(calls) == n1
        assert ctx.last_stats["recomputed"] == {}


def test_lineage_names_match_stats():
    with DataContext(2, mode="local") as ctx:
        ds = wordcount(ctx)
        lin = ds.lineage()
        assert [n["kind"] for n in lin] == \
            ["root", "map", "shuffle", "shuffle"]
        ds.collect()
        shuffles = [n["uid"] for n in lin if n["kind"] == "shuffle"]
        assert set(shuffles) == set(ctx.last_stats["recomputed"])


# ---------------------------------------------------------------------------
# pipeline re-expression
# ---------------------------------------------------------------------------

def test_batch_shards_bit_exact_with_make_batch():
    from repro.configs.xlstm_125m import SMOKE as cfg
    src = SyntheticTokens(vocab=64, seq=8, global_batch=4, seed=3)
    with DataContext(2, mode="local") as ctx:
        got = dict(batch_shards(ctx, cfg, src, steps=6, nparts=3)
                   .collect())
    assert sorted(got) == list(range(1, 7))
    for step in (1, 4, 6):
        want = make_batch(cfg, src, step)
        assert set(got[step]) == set(want)
        for k in want:
            assert np.array_equal(got[step][k], want[k])


# ---------------------------------------------------------------------------
# cluster lane: real executor processes
# ---------------------------------------------------------------------------

@pytest.mark.cluster
@pytest.mark.timeout(180)
def test_cluster_matches_oracle():
    want_wc = oracle(wordcount)
    want_gp = oracle(mixed_group)
    with DataContext(4, mode="cluster", timeout=60) as ctx:
        assert wordcount(ctx).collect() == want_wc
        assert ctx.last_stats["world_size"] == 4
        assert mixed_group(ctx).collect() == want_gp
        # the naive baseline agrees too (that is what makes the
        # benchmark's speedup comparison apples-to-apples)
        assert wordcount(ctx).collect(shuffle="gather") == want_wc


@pytest.mark.cluster
@pytest.mark.chaos
@pytest.mark.timeout(240)
def test_sigkill_mid_shuffle_recomputes_only_lost_partitions(tmp_path):
    """Kill a rank while the second wide stage's collectives are in
    flight. The supervisor shrinks the pool to the survivors; the retry
    must (a) rebalance the first shuffle's surviving partitions to
    their re-homed owners, (b) recompute exactly the partitions that
    died with the victim, and (c) produce a bit-exact result."""
    flag = str(tmp_path / "killed")
    # a key whose stage-2 input partition lands in the second pipelined
    # round (mp >= world size), so round 1's collective is already in
    # flight when the victim dies computing round 2's map side
    key = next(k for k in sorted(set(TEXT))
               if G.stable_key_hash(k) % 8 >= 4)
    victim_part = G.stable_key_hash(key) % 8

    def maybe_kill(kv):
        if kv[0] == key and not os.path.exists(flag):
            open(flag, "w").close()
            os.kill(os.getpid(), signal.SIGKILL)
        return kv

    def build(ctx):
        counts = (ctx.parallelize(TEXT, 8).map(lambda w: (w, 1))
                    .reduceByKey(ADD, nparts=8))
        return counts.map(maybe_kill).groupByKey(nparts=8)

    with DataContext(4, mode="cluster", timeout=60, hb_interval=0.05,
                     hb_timeout=1.0) as ctx:
        ds = build(ctx)
        got = ds.collect()
        stats = ctx.last_stats
        assert os.path.exists(flag), "victim never fired"
        assert stats["shrinks"] == 1 and stats["world_size"] == 3

        uid1 = [n["uid"] for n in ds.lineage()
                if n["kind"] == "shuffle"][0]
        dead_old_rank = victim_part % 4
        lost = sorted(G.lost_partitions(8, [dead_old_rank], 4))
        # lineage recompute is *partial*: only the dead rank's
        # partitions of the completed first shuffle re-execute...
        assert stats["recomputed"][uid1] == lost
        # ...and every surviving partition whose owner was re-homed by
        # the shrink moved instead of recomputing (the rest stayed put
        # on the survivor that already held them)
        new_rank = {old: new for new, old in enumerate(
            sorted(set(range(4)) - {dead_old_rank}))}
        moved = sorted(p for p in range(8) if p not in lost
                       and new_rank[p % 4] != p % 3)
        assert stats["rebalanced"][uid1] == moved

    # bit-exact: same plan on the oracle (the flag file is set, so the
    # kill closure is inert there)
    assert got == oracle(build)


# ---------------------------------------------------------------------------
# streaming take()/first(): untouched partitions never evaluate
# ---------------------------------------------------------------------------

def test_take_streams_narrow_plans_without_touching_later_partitions():
    """A narrow-only plan evaluates partitions one at a time under
    ``take(n)`` and stops once n records are ready: the counting map
    proves partitions past the cutoff were never computed."""
    seen: list[int] = []

    def spy(i):
        seen.append(i)
        return i * 10

    with DataContext(2, mode="single") as ctx:
        ds = ctx.range(100, nparts=10).map(spy).filter(lambda v: v % 20 == 0)
        got = ds.take(3)
    assert got == [0, 20, 40]
    # partitions hold 10 records each; 3 survivors of the filter live in
    # partition 0, so exactly one partition may have evaluated
    assert seen == list(range(10)), seen


def test_take_partial_partition_and_overshoot():
    seen = []

    def spy(i):
        seen.append(i)
        return i

    with DataContext(2, mode="local") as ctx:
        ds = ctx.range(40, nparts=4).map(spy)
        assert ds.take(15) == list(range(15))
        # 15 records need partitions 0 (10 recs) and 1; 2-3 untouched
        assert seen == list(range(20)), seen
        assert ds.take(0) == []
        assert ds.take(10 ** 6) == list(range(40))


def test_take_falls_back_to_collect_across_shuffles():
    with DataContext(3, mode="local") as ctx:
        ds = (ctx.parallelize([(i % 5, i) for i in range(50)], 5)
                 .sortByKey(nparts=3))
        assert ds.take(4) == ds.collect()[:4]


def test_first_streams_and_raises_on_empty():
    seen = []

    def spy(i):
        seen.append(i)
        return i

    with DataContext(2, mode="single") as ctx:
        assert ctx.range(1000, nparts=100).map(spy).first() == 0
        assert seen == list(range(10)), seen       # one partition only
        with pytest.raises(ValueError, match="empty"):
            ctx.parallelize([], 2).first()


# ---------------------------------------------------------------------------
# skew-aware sortByKey splitters
# ---------------------------------------------------------------------------

def _zipf_pairs(n=20000, nkeys=1000, seed=0):
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, nkeys + 1)
    w /= w.sum()
    return [(int(k), i) for i, k in
            enumerate(rng.choice(np.arange(nkeys), size=n, p=w))]


def test_sortbykey_splitters_bound_skew_on_zipfian_keys():
    """Zipf(1)-distributed keys (top key ~13% of records) through the
    sampled splitters: no output partition may exceed 2x the mean --
    the rebalance bound -- even when the *input* partitions are
    themselves skewed."""
    from repro.data.dataset import (_bucket_of, _partition_samples,
                                    _splitters_from_samples)
    pairs = _zipf_pairs()
    n, nparts = len(pairs), 8
    # skewed map partitions too: partition 0 holds half the records
    bounds = [0, n // 2, n // 2 + n // 6, n // 2 + n // 3, n]
    samples = [(mp, _partition_samples(pairs[bounds[mp]:bounds[mp + 1]]))
               for mp in range(4)]
    splitters = _splitters_from_samples(samples, nparts)
    counts = [0] * nparts
    for k, _ in pairs:
        counts[_bucket_of("sortByKey", k, nparts, splitters, True)] += 1
    ratio = max(counts) / (n / nparts)
    assert ratio <= 2.0, (counts, ratio)


def test_sortbykey_hot_key_is_walled_off():
    """A single key holding 40% of the records is inseparable (range
    partitioning cannot split equal keys) but must not drag *other*
    keys into its bucket: every other partition stays below the mean
    of the remaining mass plus slack."""
    from repro.data.dataset import (_bucket_of, _partition_samples,
                                    _splitters_from_samples)
    rng = np.random.default_rng(1)
    hot = [(500, i) for i in range(8000)]
    cold = [(int(k), i) for i, k in
            enumerate(rng.integers(0, 1000, size=12000))]
    pairs = hot + cold
    nparts = 5
    samples = [(mp, _partition_samples(pairs[mp::4])) for mp in range(4)]
    splitters = _splitters_from_samples(samples, nparts)
    counts = [0] * nparts
    for k, _ in pairs:
        counts[_bucket_of("sortByKey", k, nparts, splitters, True)] += 1
    hot_bucket = _bucket_of("sortByKey", 500, nparts, splitters, True)
    # the hot bucket carries the inseparable run plus its range slice;
    # every other bucket shares the cold mass evenly-ish
    others = [c for b, c in enumerate(counts) if b != hot_bucket]
    assert counts[hot_bucket] >= 8000
    assert max(others) <= 2.0 * (12000 / nparts), counts


def test_sortbykey_zipf_end_to_end_sorted_and_conformant():
    """The skewed plan still sorts globally and matches the oracle in
    every mode (the splitter math is shared, so this pins purity)."""
    pairs = _zipf_pairs(n=4000, nkeys=200, seed=2)

    def build(ctx):
        return ctx.parallelize(pairs, 6).sortByKey(nparts=4)

    want = oracle(build)
    assert [k for k, _ in want] == sorted(k for k, _ in pairs)
    with DataContext(3, mode="local") as ctx:
        assert build(ctx).collect() == want
        assert build(ctx).collect(shuffle="gather") == want

"""Cross-mode collective conformance matrix.

Every collective -- blocking and nonblocking -- runs over mode {local
threads, cluster-relay, cluster-direct (TCP), cluster-shm
(shared-memory rings)} x backend {linear, ring, segmented(-ring)} and
is compared bit-exact against a numpy oracle computed in the test
process. Payloads are int64 so the fold order
(rank-ordered at the linear root, rotation-ordered around the ring,
per-segment in the segmented schedules) cannot perturb the bits: any
mismatch is a routing/matching bug, not a float artifact.

This is the systematic replacement for the ad-hoc per-mode spot checks
that previously lived scattered across test_cluster/test_cross_mode.
Cluster legs dispatch into warm pools (one per data-plane/transport
combination, cached by ``get_pool``), so the whole matrix costs three
bootstraps total.
"""
import numpy as np
import pytest

from repro.core import parallelize_func
from repro.core.cluster import get_pool

pytestmark = pytest.mark.cluster

N = 4
ROOT = 1


def _base(rank: int) -> np.ndarray:
    return np.arange(6, dtype=np.int64).reshape(2, 3) * (rank + 1) + rank


# -- closures (one per collective; `backend` arrives via the runtime) -------

def clo_barrier(world):
    world.barrier()
    return "past"


def clo_broadcast(world):
    r = world.get_rank()
    return world.broadcast(ROOT, _base(ROOT) if r == ROOT else None)


def clo_allreduce(world):
    return world.allreduce(_base(world.get_rank()), lambda a, b: a + b)


def clo_allgather(world):
    return world.allgather(world.get_rank() * 2 + 1)


def clo_reduce(world):
    return world.reduce(ROOT, _base(world.get_rank()), lambda a, b: a + b)


def clo_gather(world):
    return world.gather(ROOT, world.get_rank() * 3)


def clo_scan(world):
    return world.scan(np.int64(world.get_rank() + 5), lambda a, b: a + b)


def clo_alltoall(world):
    r = world.get_rank()
    return world.alltoall([r * 10 + j for j in range(world.get_size())])


def clo_reducescatter(world):
    r = world.get_rank()
    chunks = [np.full(3, r + d, np.int64) for d in range(world.get_size())]
    return world.reducescatter(chunks, lambda a, b: a + b)


def clo_scatter(world):
    r = world.get_rank()
    items = ([_base(j) for j in range(world.get_size())]
             if r == ROOT else None)
    return world.scatter(ROOT, items)


def clo_ibarrier(world):
    return world.ibarrier().wait(timeout=30) or "past"


def clo_ibcast(world):
    r = world.get_rank()
    req = world.ibcast(ROOT, _base(ROOT) if r == ROOT else None)
    return req.wait(timeout=30)


def clo_iallreduce(world):
    req = world.iallreduce(_base(world.get_rank()), lambda a, b: a + b)
    return req.wait(timeout=30)


def clo_iallgather(world):
    return world.iallgather(world.get_rank() * 2 + 1).wait(timeout=30)


def clo_ireduce(world):
    req = world.ireduce(ROOT, _base(world.get_rank()), lambda a, b: a + b)
    return req.wait(timeout=30)


def clo_igather(world):
    return world.igather(ROOT, world.get_rank() * 3).wait(timeout=30)


def clo_iscatter(world):
    r = world.get_rank()
    items = ([_base(j) for j in range(world.get_size())]
             if r == ROOT else None)
    return world.iscatter(ROOT, items).wait(timeout=30)


def clo_iscan(world):
    req = world.iscan(np.int64(world.get_rank() + 5), lambda a, b: a + b)
    return req.wait(timeout=30)


def clo_ialltoall(world):
    r = world.get_rank()
    chunks = [r * 10 + j for j in range(world.get_size())]
    return world.ialltoall(chunks).wait(timeout=30)


def clo_ireducescatter(world):
    r = world.get_rank()
    chunks = [np.full(3, r + d, np.int64) for d in range(world.get_size())]
    return world.ireducescatter(chunks, lambda a, b: a + b).wait(timeout=30)


def _oracle():
    """Expected per-rank results, computed with plain numpy."""
    allred = sum((_base(r) for r in range(N)),
                 np.zeros((2, 3), np.int64))
    scan = np.cumsum([r + 5 for r in range(N)])
    rs_sum = sum(range(N))
    return {
        "barrier": ["past"] * N,
        "broadcast": [_base(ROOT)] * N,
        "allreduce": [allred] * N,
        "allgather": [[r * 2 + 1 for r in range(N)]] * N,
        "reduce": [allred if r == ROOT else None for r in range(N)],
        "gather": [[s * 3 for s in range(N)] if r == ROOT else None
                   for r in range(N)],
        "scatter": [_base(r) for r in range(N)],
        "scan": [np.int64(scan[r]) for r in range(N)],
        "alltoall": [[j * 10 + r for j in range(N)] for r in range(N)],
        "reducescatter": [np.full(3, rs_sum + N * r, np.int64)
                          for r in range(N)],
        "ibarrier": ["past"] * N,
        "ibcast": [_base(ROOT)] * N,
        "iallreduce": [allred] * N,
        "iallgather": [[r * 2 + 1 for r in range(N)]] * N,
        "ireduce": [allred if r == ROOT else None for r in range(N)],
        "igather": [[s * 3 for s in range(N)] if r == ROOT else None
                    for r in range(N)],
        "iscatter": [_base(r) for r in range(N)],
        "iscan": [np.int64(scan[r]) for r in range(N)],
        "ialltoall": [[j * 10 + r for j in range(N)] for r in range(N)],
        "ireducescatter": [np.full(3, rs_sum + N * r, np.int64)
                           for r in range(N)],
    }


CLOSURES = {
    "barrier": clo_barrier, "broadcast": clo_broadcast,
    "allreduce": clo_allreduce, "allgather": clo_allgather,
    "reduce": clo_reduce, "gather": clo_gather, "scatter": clo_scatter,
    "scan": clo_scan, "alltoall": clo_alltoall,
    "reducescatter": clo_reducescatter,
    "ibarrier": clo_ibarrier, "ibcast": clo_ibcast,
    "iallreduce": clo_iallreduce, "iallgather": clo_iallgather,
    "ireduce": clo_ireduce, "igather": clo_igather,
    "iscatter": clo_iscatter, "iscan": clo_iscan,
    "ialltoall": clo_ialltoall, "ireducescatter": clo_ireducescatter,
}

ORACLE = _oracle()


def _eq(a, b) -> bool:
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        a, b = np.asarray(a), np.asarray(b)
        return (a.dtype == b.dtype and a.shape == b.shape
                and np.array_equal(a, b))
    if isinstance(a, (list, tuple)):
        return (isinstance(b, (list, tuple)) and len(a) == len(b)
                and all(_eq(x, y) for x, y in zip(a, b)))
    return type(a) is type(b) and a == b


def _run(closure, mode: str, backend: str) -> list:
    # the forced segmented backend also gets a tiny segment size so the
    # matrix payloads (48-byte arrays) stream as multiple segments per
    # chunk rather than degenerating to one-segment transfers
    seg = 8 if backend == "segmented" else None
    if mode == "local":
        return parallelize_func(closure, backend=backend, timeout=60,
                                segment_bytes=seg).execute(N)
    if mode == "cluster-shm":
        # direct plane with the shared-memory transport brokered on;
        # cluster-direct pins shm *off* so the matrix covers the plain
        # TCP direct path separately (get_pool caches them apart)
        pool = get_pool(N, data_plane="direct", shm=True)
    else:
        plane = mode.split("-", 1)[1]
        pool = get_pool(N, data_plane=plane,
                        shm=False if plane == "direct" else None)
    return pool.run(closure, backend=backend, timeout=60,
                    segment_bytes=seg)


@pytest.mark.timeout(180)
@pytest.mark.parametrize("backend", ["linear", "ring", "segmented"])
@pytest.mark.parametrize("mode", ["local", "cluster-relay",
                                  "cluster-direct", "cluster-shm"])
@pytest.mark.parametrize("op", sorted(CLOSURES))
def test_collective_conformance(op, mode, backend):
    out = _run(CLOSURES[op], mode, backend)
    want = ORACLE[op]
    assert len(out) == len(want)
    for rank, (got, expect) in enumerate(zip(out, want)):
        assert _eq(got, expect), (op, mode, backend, rank, got, expect)


@pytest.mark.timeout(120)
@pytest.mark.parametrize("mode", ["local", "cluster-direct"])
def test_ring_equals_linear_for_commutative_fold(mode):
    """The message backends realize the same mathematical collective
    for commutative folds: bit-identical int results across the whole op
    set (the matrix above pins each to the oracle; this pins them to
    each other within one process world)."""
    def closure(world):
        r = world.get_rank()
        return (world.allreduce(_base(r), lambda a, b: a + b).tolist(),
                world.allgather(r),
                world.iallreduce(np.int64(r), lambda a, b: a + b).wait(30))
    lin = _run(closure, mode, "linear")
    ring = _run(closure, mode, "ring")
    seg = _run(closure, mode, "segmented")
    assert lin == ring == seg

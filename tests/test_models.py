"""Per-architecture smoke + decode-parity tests (single device, reduced
configs -- the full configs are exercised only via the dry-run)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import Model
from repro.models.common import gqa_layout
from repro.parallel import axes as A
from repro.parallel.ops import ParallelConfig, make_ops

AXES1 = A.MeshAxes(1, 1, 1)
PCFG = ParallelConfig(path="mpignite", sequence_parallel=False, remat="none")
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B, S, key=KEY):
    batch = {}
    if cfg.input_mode == "frames":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.cross_attn_every:
        batch["image_emb"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.vision_d), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss(arch):
    """One forward/loss on the reduced config: output shapes + no NaNs."""
    cfg = get_config(arch, smoke=True)
    model = Model(cfg, AXES1, PCFG)
    params = model.init(KEY)
    ops = make_ops(AXES1, PCFG)
    loss, metrics = model.loss(ops, params, make_batch(cfg, 2, 32))
    assert np.isfinite(float(loss))
    assert 0.0 < float(loss) < 2 * np.log(cfg.vocab)
    assert float(metrics["n_valid"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_decreases_loss(arch):
    """A few optimizer steps on one repeated batch must reduce the loss."""
    from repro.train.optim import OptConfig, Optimizer
    cfg = get_config(arch, smoke=True)
    model = Model(cfg, AXES1, PCFG)
    params = model.init(KEY)
    ops = make_ops(AXES1, PCFG)
    opt = Optimizer(OptConfig(lr_peak=3e-3, warmup_steps=1, total_steps=50,
                              weight_decay=0.0))
    state = opt.init(params)
    batch = make_batch(cfg, 2, 16)

    @jax.jit
    def step(params, state):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(ops, p, batch), has_aux=True)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    losses = []
    for _ in range(6):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0] - 0.05, losses


DECODE_ARCHS = [a for a in ARCHS if a != "hubert-xlarge"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced prefill+decode logits must match the full forward
    pass at every position (the cache path is consistent with training)."""
    cfg = get_config(arch, smoke=True)
    # capacity routing drops depend on the token-batch size; pin capacity
    # high so prefill/decode dispatch identically to the full forward
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, capacity_factor=8.0)
    model = Model(cfg, AXES1, PCFG)
    params = model.init(KEY, dtype=jnp.float32)
    ops = make_ops(AXES1, PCFG)
    B, S, n_pre = 2, 24, 16
    batch = make_batch(cfg, B, S)
    tokens = batch["tokens"]

    # reference: full-sequence forward logits
    x, img = model._embed_in(ops, params, batch)
    rope = model._rope(jnp.arange(S))
    h, _, _ = model.forward(ops, params, x, rope, img, "train")
    from repro.models.layers import rmsnorm, logits_only
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    full_logits = logits_only(ops, params["head"], h, model.v_pad, cfg.vocab)

    # prefill on the first n_pre tokens, then teacher-forced decode
    pre = dict(batch)
    pre["tokens"] = tokens[:, :n_pre]
    logits, caches = model.prefill(ops, params, pre, s_max=S + 4)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, n_pre - 1]),
                               atol=2e-3, rtol=2e-3)
    for t in range(n_pre, S):
        tok = tokens[:, t:t + 1]
        pos = jnp.full((B,), t, jnp.int32)
        logits, caches = model.decode(ops, params, caches, tok, pos)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            atol=3e-3, rtol=3e-3,
            err_msg=f"{arch}: decode diverges at position {t}")


def test_gqa_layout_invariants():
    for (nq, nkv, tp) in [(32, 8, 16), (56, 8, 16), (16, 16, 16),
                          (4, 4, 16), (32, 32, 16), (7, 1, 1), (32, 8, 1)]:
        lay = gqa_layout(nq, nkv, tp)
        assert lay.n_q_pad % tp == 0
        assert lay.kv_eff % tp == 0
        assert lay.n_q_pad >= nq
        assert lay.q_real_mask().sum() == nq
        assert lay.n_q_pad == lay.kv_eff * lay.gq
        src = lay.kv_source()
        assert src.max() < nkv
        # every real q slot's kv head matches the true GQA grouping
        gq0 = nq // nkv
        mask = lay.q_real_mask()
        real_seen = {}
        for slot in range(lay.n_q_pad):
            if not mask[slot]:
                continue
            kv = src[slot // lay.gq]
            real_seen.setdefault(kv, 0)
            real_seen[kv] += 1
        assert all(v == gq0 for v in real_seen.values())


def test_head_padding_zeroes_are_inert():
    """arctic-smoke has 7 q heads / 1 kv head: padded slots must not
    change the output (zero columns in wq, zero rows in wo)."""
    cfg = get_config("arctic-480b", smoke=True)
    model = Model(cfg, AXES1, PCFG)
    params = model.init(KEY)
    wq = params["blocks"]["seg0"]["wq"]
    lay = model.layout
    mask = np.repeat(lay.q_real_mask(), cfg.dh)
    dead = np.asarray(wq)[..., ~mask]
    assert np.all(dead == 0)


def test_n_params_counts():
    cfg = get_config("qwen3-4b")
    model = Model(cfg, AXES1, PCFG)
    n = model.n_params()
    assert 3.5e9 < n < 5.5e9, n        # qwen3-4b-ish
    cfg = get_config("arctic-480b")
    model = Model(cfg, A.MeshAxes(16, 16, 1),
                  ParallelConfig(path="mpignite"))
    n = model.n_params()
    assert 4.3e11 < n < 5.3e11, n      # ~480B total
    na = model.n_params(active_only=True)
    assert na < 0.1 * n                # top-2 of 128 experts + dense

"""GPipe on PeerComm.shift: pipelined forward (and autodiff backward)
must equal the unpipelined stack. Runs in a subprocess (needs 4 forced
host devices)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import compat
from repro.core.comm import PeerComm
from repro.parallel.pipeline import gpipe, stack_stages

S, L, M, B, D = 4, 8, 6, 2, 16          # stages, layers, microbatches
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (L, D, D), jnp.float32) * (0.5 / D ** 0.5)
xs = jax.random.normal(jax.random.fold_in(key, 1), (M, B, D), jnp.float32)

def layer(w, x):
    return jnp.tanh(x @ w)

# ---- reference: plain stacked forward ----
def ref_forward(Ws, xs):
    ys = []
    for m in range(M):
        x = xs[m]
        for l in range(L):
            x = layer(Ws[l], x)
        ys.append(x)
    return jnp.stack(ys)

want = ref_forward(Ws, xs)

# ---- pipelined: stages over a 4-way pipe axis ----
mesh = jax.make_mesh((S,), ("pipe",))
comm = PeerComm.world("pipe", S)
staged = stack_stages(Ws, S)            # (S, L/S, D, D)

def stage_fn(params, x):
    for i in range(L // S):
        x = layer(params[i], x)
    return x

def run(staged, xs):
    # local shard keeps a size-1 leading `pipe` dim; drop it
    out = gpipe(comm, stage_fn, staged[0], xs, n_stages=S)
    # outputs live on the last stage; broadcast makes them replicated
    return comm.broadcast(out, root=S - 1)

piped = jax.jit(compat.shard_map(
    run, mesh=mesh, in_specs=(P("pipe"), P()), out_specs=P(),
    check_vma=False))
with compat.set_mesh(mesh):
    got = piped(staged, xs)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           atol=1e-5, rtol=1e-5)
print("fwd ok")

# ---- backward through the pipeline ----
def loss_pipe(staged, xs):
    out = gpipe(comm, stage_fn, staged[0], xs, n_stages=S)
    # per-device local loss: shard_map AD seeds every device, so the
    # differentiated objective is the sum over stages -- which equals the
    # true loss because only the last stage banks non-zero outputs.
    return jnp.sum(out ** 2)

gfn = jax.jit(compat.shard_map(
    jax.grad(loss_pipe), mesh=mesh, in_specs=(P("pipe"), P()),
    out_specs=P("pipe"), check_vma=False))

def loss_ref(Ws):
    return jnp.sum(ref_forward(Ws, xs) ** 2)

gref = jax.grad(loss_ref)(Ws)
with compat.set_mesh(mesh):
    gpiped = gfn(staged, xs)
np.testing.assert_allclose(np.asarray(gpiped).reshape(L, D, D),
                           np.asarray(gref), atol=1e-4, rtol=1e-4)
print("bwd ok")
print("PIPELINE OK")
"""


@pytest.mark.timeout(600)
def test_gpipe_subprocess():
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=550,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "PIPELINE OK" in r.stdout

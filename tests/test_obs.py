"""Observability plane: the per-rank tracer (ring buffer, span balance,
zero-cost disabled path), driver-side aggregation across real executor
processes, Perfetto/Chrome export, the measured-vs-analytic byte
cross-check, always-on runtime health counters, rank-tagged logging, and
heartbeat-RTT rank health."""
import json
import logging
import os
import signal
import threading
import time
import tracemalloc

import numpy as np
import pytest

from repro.core import parallelize_func
from repro.core.matching import Mailbox, ProgressEngine
from repro.core.obs import (ChannelStats, CollSpan, JobTrace, Tracer,
                            cross_check_collectives, get_logger,
                            trace_enabled)
from repro.core.obs import trace as trace_mod


# ---------------------------------------------------------------------------
# Tracer mechanics
# ---------------------------------------------------------------------------

def test_trace_enabled_parsing(monkeypatch):
    for off in [None, "", "0", "false", "OFF", "no"]:
        if off is None:
            monkeypatch.delenv(trace_mod.TRACE_ENV, raising=False)
        else:
            monkeypatch.setenv(trace_mod.TRACE_ENV, off)
        assert not trace_enabled(), off
    for on in ["1", "true", "yes", "perfetto"]:
        monkeypatch.setenv(trace_mod.TRACE_ENV, on)
        assert trace_enabled(), on


def test_ring_buffer_wraps_oldest_first():
    tr = Tracer(0, 1, capacity=8)
    for i in range(20):
        tr.instant(str(i))
    assert len(tr) == 8
    assert tr.dropped == 12                 # the 12 oldest were overwritten
    names = [e[2] for e in tr.events()]
    assert names == [str(i) for i in range(12, 20)]     # newest window,
    ts = [e[3] for e in tr.events()]                    # oldest first
    assert ts == sorted(ts)


def test_begin_end_balance_and_imbalance():
    tr = Tracer(0, 1, capacity=64)
    tr.begin("outer", "t")
    tr.begin("inner", "t")
    assert tr.open_spans() == 2
    tr.end()
    tr.end()
    assert tr.open_spans() == 0
    names = [e[2] for e in tr.events()]
    assert names == ["inner", "outer"]      # LIFO close order
    with pytest.raises(RuntimeError, match="imbalance"):
        tr.end()


def test_coll_span_accumulates_and_exports():
    tr = Tracer(2, 4, job=7)
    span = tr.coll_begin("allreduce", "segmented", 4, 1000)
    span.add(300)
    span.add(450)
    tr.coll_end(span)
    (ph, cat, name, ts, dur, tid, args), = tr.events()
    assert (ph, cat, name) == ("X", "coll", "allreduce")
    assert args["sent_bytes"] == 750 and args["sent_msgs"] == 2
    assert args["backend"] == "segmented" and args["p"] == 4
    # overlap spans land on synthetic tracks so they never interleave
    s2 = tr.coll_begin("iallreduce", "ring", 4, 1000, overlap=True)
    assert s2.tid.startswith("sched-")


# ---------------------------------------------------------------------------
# Local mode end to end: spans balanced, export valid, bytes cross-check
# ---------------------------------------------------------------------------

def _traced_local(n=4, segment_bytes=4096):
    def closure(comm):
        r = comm.get_rank()
        x = np.full(2048, float(r), np.float64)     # 16 KiB
        s = comm.with_segment_bytes(segment_bytes).with_backend("ring")
        r1 = s.allreduce(x, np.add)                 # segmented upgrade
        r2 = s.iallreduce(x, np.add).wait()         # nonblocking twin
        b = comm.broadcast(0, x if r == 0 else None)
        comm.barrier()
        return float(r1.sum() + r2.sum() + b.sum())

    closure_rdd = parallelize_func(closure, trace=True)
    out = closure_rdd.execute(n, mode="local")
    assert len(set(out)) == 1
    jt = closure_rdd.last_trace
    assert isinstance(jt, JobTrace)
    return jt


def test_local_trace_spans_balanced_per_rank():
    jt = _traced_local()
    assert jt.ranks == [0, 1, 2, 3]
    for rank in jt.ranks:
        colls = [e for e in jt.events(rank)
                 if e[0] == "X" and e[1] == "coll"]
        # every collective the closure ran closed exactly once, no errors
        assert sorted(e[2] for e in colls) == sorted(
            ["allreduce", "iallreduce", "broadcast", "barrier"])
        assert all("error" not in (e[6] or {}) for e in colls)
        ctr = jt.counters(rank)
        assert ctr["engine.pending"] == 0       # nothing leaked
        assert ctr["mb.waiting"] == 0
        assert ctr["mb.total_matched"] > 0


def test_local_trace_cross_check_exact():
    jt = _traced_local()
    checks = jt.cross_check()
    assert checks, "expected checkable collectives"
    assert all(v["ok"] for v in checks), checks
    # the segmented ring realizes the analytic model *exactly*
    seg = [v for v in checks if v["backend"] == "segmented"]
    assert seg and all(v["measured"] == v["expected"] for v in seg)
    # both the blocking and the nonblocking allreduce produced rows
    assert len(seg) == 2 * len(jt.ranks)


def test_chrome_export_roundtrips_and_nests(tmp_path):
    jt = _traced_local()
    path = jt.write_chrome(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.loads(f.read())              # valid JSON end to end
    evs = doc["traceEvents"]
    metas = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert metas == {f"rank {r}/4" for r in range(4)}   # one track per rank
    for ev in evs:
        if ev["ph"] == "X":
            assert ev["ts"] >= 0 and ev["dur"] >= 0
    # segment spans nest inside their owning collective's [ts, ts+dur]
    for pid in range(4):
        colls = [e for e in evs if e["ph"] == "X" and e.get("cat") == "coll"
                 and e["pid"] == pid
                 and e.get("args", {}).get("backend") == "segmented"]
        segs = [e for e in evs if e["ph"] == "X" and e.get("cat") == "seg"
                and e["pid"] == pid]
        assert colls and segs
        for s in segs:
            assert any(c["ts"] <= s["ts"] + 1e-3 and
                       s["ts"] + s["dur"] <= c["ts"] + c["dur"] + 1e-3
                       for c in colls if c["tid"] == s["tid"]), \
                (s, [c for c in colls if c["tid"] == s["tid"]])
    assert doc["otherData"]["dropped_events"] == 0


def test_disabled_mode_zero_events_zero_allocations(monkeypatch):
    """The whole point of the guards: with $MPIGNITE_TRACE unset a run
    creates no spans, no tracers, and performs zero allocations inside
    the trace module (tracemalloc filename filter pins it)."""
    monkeypatch.delenv(trace_mod.TRACE_ENV, raising=False)

    def closure(comm):
        x = np.full(512, float(comm.get_rank()), np.float64)
        s = comm.with_segment_bytes(1024).with_backend("ring")
        r = s.allreduce(x, np.add)
        r2 = s.iallreduce(x, np.add).wait()
        comm.barrier()
        return float(r.sum() + r2.sum())

    rdd = parallelize_func(closure)
    rdd.execute(2, mode="local")                # warm code paths first
    created_before = CollSpan.created
    tracemalloc.start()
    try:
        rdd.execute(2, mode="local")
        snap = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    assert rdd.last_trace is None
    assert CollSpan.created == created_before   # no spans constructed
    stats = snap.filter_traces(
        [tracemalloc.Filter(True, trace_mod.__file__)]).statistics("lineno")
    assert not stats, [str(s) for s in stats]   # zero trace.py allocations


def test_env_flag_enables_local_tracing(monkeypatch):
    monkeypatch.setenv(trace_mod.TRACE_ENV, "1")

    def closure(comm):
        comm.barrier()
        return comm.get_rank()

    rdd = parallelize_func(closure)             # trace=None: follow env
    rdd.execute(2, mode="local")
    assert isinstance(rdd.last_trace, JobTrace)
    assert rdd.last_trace.collectives()


# ---------------------------------------------------------------------------
# Always-on health counters (no tracing required)
# ---------------------------------------------------------------------------

def test_mailbox_health_counters():
    mb = Mailbox()
    mb.put(0, 1, 0, "a")
    mb.put(0, 2, 0, "b")
    h = mb.health()
    assert h["depth"] == 2 and h["peak_depth"] == 2
    assert mb.get(0, 1, 0, 1.0) == "a"
    h = mb.health()
    assert h["depth"] == 1 and h["peak_depth"] == 2
    assert h["total_matched"] == 1 and h["poisoned_waiters"] == 0


def test_progress_engine_gauges():
    eng = ProgressEngine(name="gauge-test")
    g = eng.gauges()
    assert g["submitted"] == 0 and g["completed"] == 0
    assert g["pending"] == 0 and not g["thread_alive"]

    def closure(comm):
        r = comm.iallreduce(np.ones(4), np.add).wait()
        return float(r[0])

    rdd = parallelize_func(closure, trace=True)
    rdd.execute(2, mode="local")
    for rank in rdd.last_trace.ranks:
        ctr = rdd.last_trace.counters(rank)
        assert ctr["engine.submitted"] == 1
        assert ctr["engine.completed"] == 1
        assert ctr["engine.wakeups"] >= 1
        assert ctr["engine.peak_pending"] == 1


def test_channel_stats_totals_and_per_peer():
    st = ChannelStats()
    st.on_tx(-1, 100)
    st.on_tx(2, 50)
    st.on_rx(2, 70)
    s = st.summary()
    assert s["tx_frames"] == 2 and s["tx_bytes"] == 150
    assert s["rx_frames"] == 1 and s["rx_bytes"] == 70
    assert s["peers"][-1] == {"tx_frames": 1, "tx_bytes": 100,
                              "rx_frames": 0, "rx_bytes": 0,
                              "shm_tx_bytes": 0, "shm_rx_bytes": 0}
    assert s["peers"][2]["rx_bytes"] == 70


def test_channel_stats_shm_counters_are_subsets_of_totals():
    """An shm frame counts in *both* the shm counters and the totals
    (the frame is byte-identical to its TCP form), so the byte
    cross-check holds whatever transport the broker picked."""
    st = ChannelStats()
    st.on_tx(3, 100, shm=True)
    st.on_tx(3, 40)
    st.on_rx(3, 60, shm=True)
    s = st.summary()
    assert s["tx_frames"] == 2 and s["tx_bytes"] == 140
    assert s["shm_tx_frames"] == 1 and s["shm_tx_bytes"] == 100
    assert s["rx_frames"] == 1 and s["rx_bytes"] == 60
    assert s["shm_rx_frames"] == 1 and s["shm_rx_bytes"] == 60
    assert s["peers"][3]["shm_tx_bytes"] == 100
    assert s["peers"][3]["shm_rx_bytes"] == 60
    assert s["shm_tx_bytes"] <= s["tx_bytes"]
    assert s["shm_rx_bytes"] <= s["rx_bytes"]


# ---------------------------------------------------------------------------
# Cross-check unit behavior (scopes, skips, failure detection)
# ---------------------------------------------------------------------------

def _row(op, backend, p, nbytes, sent, rank=0, overlap=False):
    return {"rank": rank, "op": op, "backend": backend, "p": p,
            "nbytes": nbytes, "sent_bytes": sent, "sent_msgs": 1,
            "overlap": overlap, "dur_ns": 1, "ts_ns": 0}


def test_cross_check_scopes_and_skips():
    p, S = 4, 16384
    rows = []
    for r in range(p):      # segmented allreduce: per-rank, 2S(p-1)/p
        rows.append(_row("allreduce", "segmented", p, S,
                         2 * S * (p - 1) // p, rank=r))
    # linear broadcast: group total (p-1)*S concentrated at the root
    rows.append(_row("broadcast", "linear", p, S, (p - 1) * S, rank=0))
    for r in range(1, p):
        rows.append(_row("broadcast", "linear", p, S, 0, rank=r))
    # whole-buffer ring allreduce: deliberately unpriced -> skipped
    rows.append(_row("allreduce", "ring", p, S, (p - 1) * S))
    rows.append(_row("barrier", "linear", p, 0, 0))     # no byte model
    checks = cross_check_collectives(rows)
    assert all(v["ok"] for v in checks), checks
    assert len([v for v in checks if v["scope"] == "per-rank"]) == p
    assert len([v for v in checks if v["scope"] == "group-total"]) == 1
    assert not any(v["backend"] == "ring" for v in checks)


def test_cross_check_flags_byte_drift():
    p, S = 4, 1 << 20
    rows = [_row("allreduce", "segmented", p, S, 2 * S * (p - 1) // p // 2,
                 rank=r) for r in range(p)]     # half the modeled bytes
    checks = cross_check_collectives(rows)
    assert checks and all(not v["ok"] for v in checks)
    # the i-prefixed twin maps onto the same model
    irows = [_row("iallreduce", "segmented", p, S, 2 * S * (p - 1) // p,
                  rank=r, overlap=True) for r in range(p)]
    assert all(v["ok"] for v in cross_check_collectives(irows))


# ---------------------------------------------------------------------------
# Rank-tagged logging
# ---------------------------------------------------------------------------

def test_rank_logger_prefixes():
    records = []
    handler = logging.Handler()
    handler.emit = records.append
    log = logging.getLogger("mpignite.obs_test")
    log.addHandler(handler)
    log.setLevel(logging.DEBUG)
    try:
        rl = get_logger("obs_test")
        rl.bound(rank=2, world=8, job=5).warning("boom %d", 7)
        rl.bound(rank=1).info("partial")
        rl.debug("unbound")
        msgs = [r.getMessage() for r in records]
        assert msgs == ["[rank 2/8 job 5] boom 7", "[rank 1] partial",
                        "unbound"]
    finally:
        log.removeHandler(handler)


# ---------------------------------------------------------------------------
# Cluster mode: aggregation at the driver, RTT health, the acceptance job
# ---------------------------------------------------------------------------

@pytest.mark.cluster
@pytest.mark.timeout(180)
def test_cluster_traced_8rank_segmented_iallreduce(tmp_path):
    """The PR's acceptance scenario: a traced 8-rank cluster job running
    segmented iallreduce on the direct data plane produces a valid
    Chrome trace with one track per rank and nested spans, and the
    measured wire bytes agree with ``groups.collective_cost``."""
    from repro.core.cluster import ExecutorPool

    def closure(comm):
        r = comm.get_rank()
        x = np.full(4096, float(r), np.float64)     # 32 KiB
        s = comm.with_segment_bytes(8192).with_backend("ring")
        red = s.iallreduce(x, np.add).wait()
        comm.barrier()
        return float(red.sum())

    with ExecutorPool(8, backend="linear", timeout=120.0,
                      data_plane="direct") as pool:
        out = pool.run(closure, trace=True)
        assert len(set(out)) == 1
        jt = pool.last_trace
        assert isinstance(jt, JobTrace) and jt.ranks == list(range(8))
        assert pool.frame_counts["msg"] == 0        # stayed on the
        assert pool.frame_counts["trace"] == 8      # direct plane

        checks = jt.cross_check()
        seg = [v for v in checks if v["backend"] == "segmented"
               and v["op"] == "allreduce"]
        assert len(seg) == 8 and all(v["ok"] for v in seg), checks
        # exact agreement: 2*S*(p-1)/p per rank
        assert all(v["measured"] == v["expected"] == 2 * 32768 * 7 // 8
                   for v in seg)

        path = jt.write_chrome(str(tmp_path / "trace.json"))
        with open(path) as f:
            doc = json.loads(f.read())
        evs = doc["traceEvents"]
        metas = {e["args"]["name"] for e in evs if e["ph"] == "M"}
        assert metas == {f"rank {r}/8" for r in range(8)}
        # the overlapped collective rides a synthetic sched track with
        # its segment spans nested inside it
        for pid in range(8):
            coll = [e for e in evs if e["ph"] == "X"
                    and e.get("cat") == "coll" and e["pid"] == pid
                    and e["name"] == "iallreduce"]
            assert len(coll) == 1 and coll[0]["tid"].startswith("sched-")
            c = coll[0]
            segs = [e for e in evs if e["ph"] == "X"
                    and e.get("cat") == "seg" and e["pid"] == pid
                    and e["tid"] == c["tid"]]
            assert segs
            assert all(c["ts"] <= s["ts"] + 1e-3 and
                       s["ts"] + s["dur"] <= c["ts"] + c["dur"] + 1e-3
                       for s in segs)

        # runtime counters came along: wire totals and engine gauges
        for rank in jt.ranks:
            ctr = jt.counters(rank)
            assert ctr["chan.tx_bytes"] > 0 and ctr["chan.rx_bytes"] > 0
            assert ctr["engine.completed"] == 1
            assert ctr["engine.pending"] == 0

        # second, untraced job: disabled path leaves no trace behind
        assert pool.run(closure) is not None
        assert pool.last_trace is None


@pytest.mark.cluster
@pytest.mark.timeout(120)
def test_rank_health_rtt_and_sigstop():
    """``pool.rank_health()``: every rank reports a measured heartbeat
    RTT, and a SIGSTOPped executor's last-seen age grows while the
    others stay fresh (the wedged-process signal), recovering on
    SIGCONT."""
    from repro.core.cluster import ExecutorPool

    with ExecutorPool(3, timeout=60.0, hb_interval=0.05,
                      hb_timeout=30.0) as pool:
        deadline = time.time() + 10.0
        while time.time() < deadline:
            health = pool.rank_health()
            if all(h["rtt"] is not None for h in health):
                break
            time.sleep(0.05)
        health = pool.rank_health()
        assert all(h["alive"] and not h["conn_dead"] for h in health)
        assert all(h["rtt"] is not None and h["rtt"] < 5.0
                   for h in health)

        victim = pool.pids[1]
        os.kill(victim, signal.SIGSTOP)
        try:
            time.sleep(0.6)
            health = {h["rank"]: h for h in pool.rank_health()}
            assert health[1]["last_seen_age"] > 0.4     # heartbeats froze
            assert health[0]["last_seen_age"] < 0.4     # peers keep beating
            assert health[2]["last_seen_age"] < 0.4
            assert health[1]["alive"]       # stopped, not dead
        finally:
            os.kill(victim, signal.SIGCONT)
        deadline = time.time() + 10.0
        while time.time() < deadline:       # recovers once resumed
            if {h["rank"]: h for h in pool.rank_health()}[1][
                    "last_seen_age"] < 0.3:
                break
            time.sleep(0.05)
        assert {h["rank"]: h for h in pool.rank_health()}[1][
            "last_seen_age"] < 0.3


@pytest.mark.cluster
@pytest.mark.timeout(120)
def test_streaming_flush_surfaces_partial_trace_mid_job(
        tmp_path, monkeypatch):
    """Mid-job trace recovery: executors stream incremental trace
    frames every ``MPIGNITE_TRACE_FLUSH`` seconds, so when one rank is
    SIGSTOPped mid-job the driver's ``pool.last_trace`` already holds
    the *other* ranks' spans while the job is still wedged -- the
    post-mortem view a dead job used to take to the grave."""
    from repro.core.cluster import ExecutorPool

    monkeypatch.setenv("MPIGNITE_TRACE_FLUSH", "0.2")
    stop_flag = str(tmp_path / "parked")
    go_flag = str(tmp_path / "go")

    def closure(comm):
        r = comm.get_rank()
        x = comm.allreduce(np.arange(64, dtype=np.int64), np.add)
        if r == 1:
            open(stop_flag, "w").close()
            while not os.path.exists(go_flag):
                time.sleep(0.02)
        comm.barrier()
        return int(x.sum())

    with ExecutorPool(3, timeout=90.0, hb_interval=0.05,
                      hb_timeout=60.0) as pool:
        result: dict = {}

        def run():
            result["out"] = pool.run(closure, trace=True, timeout=90.0)

        t = threading.Thread(target=run)
        t.start()
        deadline = time.time() + 30.0
        while not os.path.exists(stop_flag) and time.time() < deadline:
            time.sleep(0.02)
        assert os.path.exists(stop_flag), "rank 1 never parked"
        victim = pool.pids[1]
        os.kill(victim, signal.SIGSTOP)
        try:
            # ranks 0 and 2 are parked in the barrier; their flush
            # threads keep streaming. Poll until their allreduce spans
            # surface on the driver while the job is still running.
            got_ranks: set = set()
            deadline = time.time() + 20.0
            while time.time() < deadline:
                jt = pool.last_trace
                if jt is not None:
                    got_ranks = {row["rank"] for row in jt.collectives()
                                 if row["op"] == "allreduce"}
                    if {0, 2} <= got_ranks:
                        break
                time.sleep(0.05)
            assert t.is_alive(), "job finished before the partial check"
            assert {0, 2} <= got_ranks, got_ranks
        finally:
            os.kill(victim, signal.SIGCONT)
        open(go_flag, "w").close()
        t.join(timeout=60.0)
        assert not t.is_alive()
        assert result["out"] == [int(np.arange(64).sum()) * 3] * 3
        # the end-of-job flush completes the picture: all three ranks
        rows = pool.last_trace.collectives()
        assert {row["rank"] for row in rows
                if row["op"] == "allreduce"} == {0, 1, 2}

"""Multi-replica serving on the cluster runtime: generations sharded
across pooled engine replicas must be bit-identical to a single driver
engine; acceptance telemetry must land in the traced snapshot; SIGKILL
of a replica must re-route its queued requests to the survivors.

``cluster`` lane: each test spawns a real executor world (spawned
interpreters -- the engines run jax, which is not fork-safe)."""
import os
import signal

import numpy as np
import pytest

from repro.serve.cluster import ClusterServer, smoke_engine_spec

#: generous liveness budget -- each executor compiles a smoke model on
#: its first serving round, which can monopolize a shared CI core
POOL_KW = dict(hb_interval=0.25, hb_timeout=60.0)


def _reference(build_engine, load_params, prompts, max_new):
    """Expected generations: a driver-local engine built from the same
    spec (same seeded params the pool broadcasts)."""
    eng = build_engine(load_params(), 0)
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    out = eng.run()
    return [list(out[u]) for u in uids]


@pytest.mark.cluster
@pytest.mark.timeout(600)
def test_cluster_serving_matches_reference_and_traces_acceptance():
    build_engine, load_params = smoke_engine_spec(
        s_max=48, slots=2, seed=0, gamma=2, draft_layers=None)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 100, 6).astype(np.int32) for _ in range(6)]
    with ClusterServer(2, build_engine, load_params, trace=True,
                       quantum=6, round_timeout=600,
                       pool_kwargs=POOL_KW) as srv:
        want = _reference(build_engine, load_params, prompts, 8)
        uids = [srv.submit(p, max_new_tokens=8) for p in prompts]
        out = srv.run_until_drained()
        assert [list(out[u]) for u in uids] == want
        # least-loaded routing spread work over both replicas
        prefills = [srv.replica_stats[s]["stats"]["prefills"]
                    for s in srv.pool.world]
        assert all(p > 0 for p in prefills) and sum(prefills) >= 6
        # a draft identical to the target accepts every proposal
        acc = srv.acceptance_summary()
        assert acc["proposed"] > 0 and acc["ratio"] == 1.0
        assert all(out[u].accept_ratio == 1.0 for u in uids)
        # ... and the ratio is visible in the traced snapshot
        tr = srv.pool.last_trace
        assert tr is not None
        ctrs = [tr.counters(r) for r in range(srv.pool.size)]
        assert any(c.get("serve.spec.accept_ratio") == 1.0 for c in ctrs)
        assert any(c.get("serve.tokens_out", 0) > 0 for c in ctrs)


@pytest.mark.cluster
@pytest.mark.chaos
@pytest.mark.timeout(600)
def test_sigkill_replica_reroutes_queued_requests_to_survivors():
    build_engine, load_params = smoke_engine_spec(s_max=48, slots=2,
                                                 seed=0)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 100, 5).astype(np.int32) for _ in range(9)]
    with ClusterServer(3, build_engine, load_params, quantum=2,
                       round_timeout=600,
                       pool_kwargs=POOL_KW) as srv:
        want = _reference(build_engine, load_params, prompts, 10)
        uids = [srv.submit(p, max_new_tokens=10) for p in prompts]
        srv.step_round()        # everything admitted; nothing done yet
        victim = srv.pool.world[-1]
        os.kill(srv.pool.pids[victim], signal.SIGKILL)
        out = srv.run_until_drained()
        assert srv.pool.size == 2               # shrunk, not relaunched
        assert victim not in srv.pool.world
        assert srv.rerouted >= 1                # victim's work re-queued
        # every request completed, bit-identical to the single engine --
        # including the ones that died with the victim and re-ran
        assert [list(out[u]) for u in uids] == want

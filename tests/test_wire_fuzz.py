"""Wire-codec fuzzing: property-based round-trips (hypothesis, skipped
where it isn't installed) plus always-on adversarial cases -- truncated
frames, corrupted length prefixes, oversized pre-auth frames, random
garbage -- asserting clean ``ValueError``/``ConnectionError`` outcomes
rather than hangs, giant allocations, or codec-internal tracebacks."""
import random
import socket
import struct
import threading

import numpy as np
import pytest

from repro.core.cluster import wire

# ---------------------------------------------------------------------------
# Bit-exact comparison helpers (NaNs and all)
# ---------------------------------------------------------------------------


def _bits_equal(a, b) -> bool:
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        if not isinstance(b, type(a)):
            return False
        return (a.dtype == b.dtype and a.shape == b.shape
                and np.ascontiguousarray(a).tobytes()
                == np.ascontiguousarray(b).tobytes())
    if isinstance(a, dict):
        return (isinstance(b, dict) and set(a) == set(b)
                and all(_bits_equal(a[k], b[k]) for k in a))
    if isinstance(a, (list, tuple)):
        return (type(a) is type(b) and len(a) == len(b)
                and all(_bits_equal(x, y) for x, y in zip(a, b)))
    return type(a) is type(b) and a == b


# ---------------------------------------------------------------------------
# Seeded random round-trip fuzz (runs everywhere, no hypothesis needed)
# ---------------------------------------------------------------------------

_DTYPES = [np.int8, np.uint8, np.int16, np.uint32, np.int64, np.float16,
           np.float32, np.float64, np.complex64, np.bool_]


def _random_tree(rng: random.Random, depth: int = 0):
    roll = rng.random()
    if depth >= 3 or roll < 0.45:
        kind = rng.randrange(6)
        if kind == 0:
            return None
        if kind == 1:
            return rng.randint(-2**40, 2**40)
        if kind == 2:
            return rng.random() * 1e6 - 5e5
        if kind == 3:
            return "".join(chr(rng.randrange(32, 0x2FF))
                           for _ in range(rng.randrange(8)))
        if kind == 4:
            return rng.random() < 0.5
        shape = tuple(rng.randrange(4) for _ in range(rng.randrange(4)))
        dt = np.dtype(rng.choice(_DTYPES))
        nbytes = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        raw = rng.getrandbits(8 * nbytes).to_bytes(nbytes, "little") \
            if nbytes else b""
        return np.frombuffer(raw, dtype=dt).reshape(shape).copy()
    if roll < 0.65:
        return [_random_tree(rng, depth + 1)
                for _ in range(rng.randrange(4))]
    if roll < 0.85:
        return tuple(_random_tree(rng, depth + 1)
                     for _ in range(rng.randrange(3)))
    return {f"k{i}": _random_tree(rng, depth + 1)
            for i in range(rng.randrange(4))}


@pytest.mark.parametrize("seed", range(40))
def test_random_pytree_roundtrip_bit_exact(seed):
    rng = random.Random(seed)
    obj = _random_tree(rng)
    out = wire.decode(wire.encode(obj))
    assert _bits_equal(obj, out), (obj, out)


@pytest.mark.parametrize("seed", range(40))
def test_truncated_payload_raises_value_error(seed):
    """Every strict prefix of a valid encoding decodes to ValueError --
    never an allocation blow-up, a hang, or a stray exception type."""
    rng = random.Random(1000 + seed)
    blob = wire.encode(_random_tree(rng))
    if len(blob) < 2:
        pytest.skip("degenerate tiny encoding")
    cut = rng.randrange(1, len(blob))
    try:
        wire.decode(blob[:cut])
    except ValueError:
        pass        # the contract
    # a prefix that still satisfies the manifest (trailing don't-care
    # bytes truncated) may legitimately decode: success is also fine


@pytest.mark.parametrize("seed", range(60))
def test_single_byte_corruption_is_contained(seed):
    """Arbitrary single-byte corruption either still decodes or raises
    ValueError -- codec internals (struct/json/numpy errors) never
    escape raw."""
    rng = random.Random(2000 + seed)
    obj = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
           "b": [1, "two", None], "c": (np.int64(7),)}
    blob = bytearray(wire.encode(obj))
    blob[rng.randrange(len(blob))] ^= 1 << rng.randrange(8)
    try:
        wire.decode(bytes(blob))
    except ValueError:
        pass


def test_garbage_bytes_raise_value_error():
    for blob in [b"", b"\x00", b"\xff" * 3, b"\xff" * 64,
                 b"{not json}" * 10, bytes(range(256))]:
        with pytest.raises(ValueError):
            wire.decode(blob)


def test_corrupted_manifest_length_prefix():
    blob = bytearray(wire.encode({"x": 1}))
    struct.pack_into(">I", blob, 0, 2**31)      # mlen far beyond payload
    with pytest.raises(ValueError, match="manifest length"):
        wire.decode(bytes(blob))


def test_manifest_buffer_overrun_is_bounded():
    """A manifest claiming a giant buffer must fail by bounds check,
    not by attempting the allocation/copy."""
    import json
    manifest = json.dumps({"t": "nd", "n": 2**40, "d": "float64",
                           "s": [2**37]}).encode()
    blob = struct.pack(">I", len(manifest)) + manifest + b"\x00" * 16
    with pytest.raises(ValueError, match="overruns payload"):
        wire.decode(blob)


def test_negative_buffer_length_rejected():
    import json
    manifest = json.dumps({"t": "pkl", "n": -5}).encode()
    blob = struct.pack(">I", len(manifest)) + manifest
    with pytest.raises(ValueError):
        wire.decode(blob)


# ---------------------------------------------------------------------------
# Framing-level adversarial input (socket pairs)
# ---------------------------------------------------------------------------

def test_oversized_preauth_frame_rejected_before_allocation():
    """A dialer claiming a 2 GiB frame before authenticating must be
    refused at the length prefix -- PREAUTH_MAX_FRAME bounds both
    lengths before any buffer is allocated."""
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack(">IQ", 16, 1 << 31))
        with pytest.raises(ValueError, match="oversized frame"):
            wire.recv_frame(b, limit=wire.PREAUTH_MAX_FRAME)
    finally:
        a.close()
        b.close()


def test_oversized_payload_rejected_post_auth_too():
    """Even authenticated peers are bounded by MAX_FRAME (16 GiB)."""
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack(">IQ", 16, 1 << 35))
        with pytest.raises(ValueError, match="oversized frame"):
            wire.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_frame_truncated_mid_payload_is_connection_error():
    a, b = socket.socketpair()
    try:
        header = b'{"kind":"msg"}'
        a.sendall(struct.pack(">IQ", len(header), 100) + header + b"x" * 10)
        a.close()       # EOF with 90 payload bytes missing
        with pytest.raises(ConnectionError, match="mid-frame"):
            wire.recv_frame(b)
    finally:
        b.close()


def test_clean_eof_at_frame_boundary_is_none():
    a, b = socket.socketpair()
    try:
        wire.send_frame(a, {"kind": "hb"}, b"ok")
        a.close()
        frame = wire.recv_frame(b)
        assert frame is not None and frame[0] == {"kind": "hb"}
        assert wire.recv_frame(b) is None
    finally:
        b.close()


def test_wrong_secret_dial_fails_closed_fast():
    """Auth fuzz: a dialer with the wrong secret is rejected with
    AuthError on both ends, promptly (no hang waiting for frames)."""
    server, client = socket.socketpair()
    results = {}

    def serve():
        try:
            wire.server_handshake(server, b"right-secret", timeout=5.0)
            results["server"] = "accepted"
        except wire.AuthError:
            results["server"] = "refused"

    t = threading.Thread(target=serve)
    t.start()
    with pytest.raises(wire.AuthError):
        wire.client_handshake(client, b"wrong-secret", timeout=5.0)
    t.join(timeout=10)
    assert results.get("server") == "refused"
    server.close()
    client.close()


# ---------------------------------------------------------------------------
# Hypothesis property tests (CI installs hypothesis; skipped without it)
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:     # container without hypothesis: seeded fuzz above
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _scalar = st.one_of(
        st.none(), st.booleans(), st.integers(),
        st.floats(allow_nan=False),     # NaN in arrays is covered bitwise;
        st.text(max_size=16))           # a bare JSON NaN breaks == oracle

    _array = st.one_of(*[
        hnp.arrays(dtype=dt, shape=hnp.array_shapes(max_dims=3, max_side=4))
        for dt in (np.int8, np.uint16, np.int64, np.float32, np.float64,
                   np.bool_)])

    _tree = st.recursive(
        st.one_of(_scalar, _array),
        lambda children: st.one_of(
            st.lists(children, max_size=3),
            st.tuples(children, children),
            st.dictionaries(st.text(max_size=6), children, max_size=3)),
        max_leaves=10)

    @settings(max_examples=120, deadline=None)
    @given(obj=_tree)
    def test_property_roundtrip_arbitrary_pytrees(obj):
        out = wire.decode(wire.encode(obj))
        assert _bits_equal(obj, out)

    @settings(max_examples=120, deadline=None)
    @given(obj=_tree, data=st.data())
    def test_property_mutations_contained(obj, data):
        """Truncations and byte flips of any valid encoding either decode
        or raise ValueError -- no other exception type, ever."""
        blob = bytearray(wire.encode(obj))
        if len(blob) == 0:
            return
        if data.draw(st.booleans(), label="truncate"):
            cut = data.draw(st.integers(0, len(blob) - 1), label="cut")
            blob = blob[:cut]
        else:
            i = data.draw(st.integers(0, len(blob) - 1), label="pos")
            blob[i] ^= data.draw(st.integers(1, 255), label="xor")
        try:
            wire.decode(bytes(blob))
        except ValueError:
            pass

"""Property-based schedule conformance harness.

One case generator drives every message-composed collective -- blocking
AND nonblocking driver, every backend (linear / whole-buffer ring /
segmented ring) -- across world sizes 2-5, payload shapes/dtypes
(including 0-d and zero-size arrays and ragged pytrees), and segment
sizes chosen to *not* divide the payload, asserting bit-exactness
against a numpy oracle computed in the test process.

Payload values are small integers (exactly representable in every dtype
drawn), so any legal fold order -- rank-ordered at the linear root,
rotation-ordered around the ring, per-segment in the segmented
schedules -- must reproduce the oracle bit-for-bit: a mismatch is a
routing/chunking/matching bug, never a float artifact.

Three layers, mirroring ``test_wire_fuzz``:

- an always-on *seeded* sweep (no hypothesis needed) with a bounded
  fast-lane profile and a deeper profile marked ``slow`` + ``cluster``
  so the cluster CI lane carries the heavy half;
- hypothesis-driven sweeps of the same case space where hypothesis is
  installed (CI), with shrinking on failure;
- directed edge cases the random layers must never be trusted to hit
  (non-dividing segments, 0-d/empty payloads, the auto-upgrade
  threshold).
"""
import random

import numpy as np
import pytest

from repro.core import parallelize_func, waitall
from repro.core import groups as G

OPS = ("barrier", "broadcast", "allreduce", "allgather", "reduce",
       "gather", "scatter", "scan", "alltoall", "reducescatter")
DRIVERS = ("blocking", "nonblocking")
BACKENDS = ("linear", "ring", "segmented")
DTYPES = (np.int32, np.int64, np.float64)
#: shapes include 0-d, zero-size, and sizes that no segment/world size
#: divides evenly
SHAPES = ((), (1,), (7,), (3, 4), (2, 3, 2), (13,), (0,), (5, 0, 2))
#: segment sizes in BYTES: tiny (many segments, never dividing an int64
#: payload evenly), moderate, 0 (auto-upgrade disabled), None (default)
SEGMENT_BYTES = (1, 3, 8, 24, 1000, 0, None)


def _tree_map2(f, a, b):
    """Structure-preserving binary map over the ragged pytrees this
    harness generates (dicts / lists / leaves) -- the tree-aware fold a
    user would pass for pytree payloads."""
    if isinstance(a, dict):
        return {k: _tree_map2(f, a[k], b[k]) for k in a}
    if isinstance(a, (list, tuple)):
        return type(a)(_tree_map2(f, x, y) for x, y in zip(a, b))
    return f(a, b)


def _base_array(rank: int, shape: tuple, dtype, salt: int = 0) -> np.ndarray:
    """Deterministic per-rank payload: small exact integers."""
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    flat = (np.arange(n, dtype=np.int64) % 17) * (rank + 1) + rank + salt
    return flat.astype(dtype).reshape(shape)


def _make_payload(kind: str, rank: int, shape: tuple, dtype,
                  salt: int = 0):
    if kind == "array":
        return _base_array(rank, shape, dtype, salt)
    # ragged pytree: leaves of *different* shapes, one of them the drawn
    # shape -- exercises the non-array fallback of every segmented path
    return {"a": _base_array(rank, shape, dtype, salt),
            "b": [_base_array(rank, (3,), dtype, salt + 5),
                  _base_array(rank, (2, 2), dtype, salt + 9)]}


def _add(a, b):
    return _tree_map2(np.add, a, b)


def _payloads(kind, n, shape, dtype, salt=0):
    return [_make_payload(kind, r, shape, dtype, salt) for r in range(n)]


def _oracle(op, kind, n, shape, dtype, root):
    """Expected per-rank results, folded rank-ordered with numpy."""
    xs = _payloads(kind, n, shape, dtype)
    if op == "barrier":
        return [None] * n
    if op == "broadcast":
        return [xs[root]] * n
    if op == "allreduce":
        acc = xs[0]
        for x in xs[1:]:
            acc = _add(acc, x)
        return [acc] * n
    if op == "allgather":
        return [xs] * n
    if op == "reduce":
        acc = xs[0]
        for x in xs[1:]:
            acc = _add(acc, x)
        return [acc if r == root else None for r in range(n)]
    if op == "gather":
        return [xs if r == root else None for r in range(n)]
    if op == "scatter":
        items = _payloads(kind, n, shape, dtype, salt=100)
        return [items[r] for r in range(n)]
    if op == "scan":
        out, acc = [], None
        for x in xs:
            acc = x if acc is None else _add(acc, x)
            out.append(acc)
        return out
    if op == "alltoall":
        mat = [[_make_payload(kind, s, shape, dtype, salt=10 * d)
                for d in range(n)] for s in range(n)]
        return [[mat[s][r] for s in range(n)] for r in range(n)]
    if op == "reducescatter":
        mat = [[_make_payload(kind, s, shape, dtype, salt=10 * d)
                for d in range(n)] for s in range(n)]
        out = []
        for r in range(n):
            acc = mat[0][r]
            for s in range(1, n):
                acc = _add(acc, mat[s][r])
            out.append(acc)
        return out
    raise AssertionError(op)


def _closure(op, kind, shape, dtype, root, driver):
    """One closure covering the whole op surface; captured args arrive
    via pickle in cluster mode, so everything is plain data. Array
    payloads fold with ``np.add`` (a ufunc, so plain ``ring`` exercises
    the *automatic* segmented upgrade too); pytrees use the tree-aware
    fold (and always take the whole-buffer fallback)."""
    fold = np.add if kind == "array" else _add

    def run(world):
        r, n = world.get_rank(), world.get_size()
        data = _make_payload(kind, r, shape, dtype)
        items = _payloads(kind, n, shape, dtype, salt=100) \
            if r == root else None
        chunks = [_make_payload(kind, r, shape, dtype, salt=10 * d)
                  for d in range(n)]
        if driver == "blocking":
            if op == "barrier":
                return world.barrier()
            if op == "broadcast":
                return world.broadcast(root, data if r == root else None)
            if op == "allreduce":
                return world.allreduce(data, fold)
            if op == "allgather":
                return world.allgather(data)
            if op == "reduce":
                return world.reduce(root, data, fold)
            if op == "gather":
                return world.gather(root, data)
            if op == "scatter":
                return world.scatter(root, items)
            if op == "scan":
                return world.scan(data, fold)
            if op == "alltoall":
                return world.alltoall(chunks)
            if op == "reducescatter":
                return world.reducescatter(chunks, fold)
        else:
            if op == "barrier":
                req = world.ibarrier()
            elif op == "broadcast":
                req = world.ibcast(root, data if r == root else None)
            elif op == "allreduce":
                req = world.iallreduce(data, fold)
            elif op == "allgather":
                req = world.iallgather(data)
            elif op == "reduce":
                req = world.ireduce(root, data, fold)
            elif op == "gather":
                req = world.igather(root, data)
            elif op == "scatter":
                req = world.iscatter(root, items)
            elif op == "scan":
                req = world.iscan(data, fold)
            elif op == "alltoall":
                req = world.ialltoall(chunks)
            elif op == "reducescatter":
                req = world.ireducescatter(chunks, fold)
            return waitall([req], timeout=30)[0]
        raise AssertionError(op)
    return run


def _bit_eq(a, b) -> bool:
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        a, b = np.asarray(a), np.asarray(b)
        return (a.dtype == b.dtype and a.shape == b.shape
                and np.array_equal(a, b))
    if isinstance(a, dict):
        return (isinstance(b, dict) and a.keys() == b.keys()
                and all(_bit_eq(a[k], b[k]) for k in a))
    if isinstance(a, (list, tuple)):
        return (isinstance(b, (list, tuple)) and len(a) == len(b)
                and all(_bit_eq(x, y) for x, y in zip(a, b)))
    return type(a) is type(b) and a == b


def check_case(op, driver, backend, n, kind, shape, dtype, seg, root):
    got = parallelize_func(
        _closure(op, kind, shape, dtype, root, driver),
        backend=backend, timeout=30, segment_bytes=seg).execute(n)
    want = _oracle(op, kind, n, shape, dtype, root)
    for rank, (g, w) in enumerate(zip(got, want)):
        assert _bit_eq(g, w), (op, driver, backend, n, kind, shape,
                               np.dtype(dtype).name, seg, rank, g, w)


# ---------------------------------------------------------------------------
# Always-on seeded sweep (no hypothesis needed)
# ---------------------------------------------------------------------------

def _draw_case_rng(rng: random.Random):
    n = rng.randint(2, 5)
    return (rng.choice(OPS), rng.choice(DRIVERS), rng.choice(BACKENDS),
            n, rng.choice(("array", "array", "pytree")),
            rng.choice(SHAPES), rng.choice(DTYPES),
            rng.choice(SEGMENT_BYTES), rng.randrange(n))


@pytest.mark.parametrize("seed", range(20))
def test_schedule_conformance_seeded(seed):
    """Fast-lane profile: a bounded seeded sweep of the cross product."""
    rng = random.Random(seed)
    for _ in range(4):
        check_case(*_draw_case_rng(rng))


@pytest.mark.slow
@pytest.mark.cluster
@pytest.mark.timeout(600)
@pytest.mark.parametrize("seed", range(1000, 1040))
def test_schedule_conformance_seeded_deep(seed):
    """Cluster-lane profile: the same sweep, ~4x deeper."""
    rng = random.Random(seed)
    for _ in range(7):
        check_case(*_draw_case_rng(rng))


# ---------------------------------------------------------------------------
# Directed cases the random sweeps must never be trusted to hit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("driver", DRIVERS)
@pytest.mark.parametrize("seg", [1, 3, 8, None])
def test_segmented_allreduce_nondividing_segments(driver, seg):
    """Segment sizes that divide neither the payload nor the per-rank
    chunks, with a world size that does not divide the payload either."""
    check_case("allreduce", driver, "segmented", 3, "array", (13,),
               np.int64, seg, 0)


@pytest.mark.parametrize("backend", ["ring", "segmented"])
@pytest.mark.parametrize("op", ["allreduce", "broadcast", "allgather"])
def test_segmented_zero_d_and_empty(backend, op):
    """0-d arrays and zero-size arrays through every segmented path."""
    for shape in [(), (0,), (5, 0, 2)]:
        check_case(op, "blocking", backend, 4, "array", shape,
                   np.int64, 1, 1)


def test_ragged_pytree_takes_whole_buffer_fallback_bit_exact():
    """A ragged pytree under the forced segmented backend falls back to
    the whole-buffer ring and still matches the oracle bit-exactly."""
    for driver in DRIVERS:
        check_case("allreduce", driver, "segmented", 4, "pytree", (7,),
                   np.int64, 1, 0)


def test_ring_auto_upgrades_to_segmented_above_threshold():
    """Under plain ``ring``, a ufunc-folded payload >= the segment
    threshold streams segmented (message count rises with the pipelined
    schedule); below the threshold -- or with an arbitrary callable
    fold, whose semantics per-segment application could change -- the
    whole-buffer ring is kept. Observed via the send hook."""
    from repro.core.local import LocalComm

    counts = {}
    orig = LocalComm._put

    def counting_put(self, *a, **kw):
        counts[self._backend] = counts.get(self._backend, 0) + 1
        return orig(self, *a, **kw)

    def make_closure(fold):
        def closure(world):
            arr = np.arange(64, dtype=np.int64)
            return world.allreduce(arr, fold).sum()
        return closure

    def messages(fold, seg):
        counts.clear()
        parallelize_func(make_closure(fold), backend="ring", timeout=30,
                         segment_bytes=seg).execute(2)
        return counts.get("ring", 0)

    LocalComm._put = counting_put
    try:
        whole = messages(np.add, 10 ** 9)       # below threshold
        segmented = messages(np.add, 64)        # above, elementwise fold
        # an arbitrary callable is NOT provably elementwise: plain ring
        # must keep the whole-buffer schedule however big the payload,
        # or working non-elementwise folds would silently change meaning
        lam = messages(lambda a, b: a + b, 64)
    finally:
        LocalComm._put = orig
    # whole-buffer ring: one message per rank (p=2). Segmented: chunks
    # stream as ceil(256B chunk / 64B segment) messages per phase.
    assert whole == 2, whole
    assert segmented > whole, (whole, segmented)
    assert lam == whole, (lam, whole)


def test_non_elementwise_fold_is_never_segmented_under_plain_ring():
    """The semantic guard end-to-end: an associative+commutative but
    NON-elementwise fold (sorted top-k merge) stays correct under plain
    ``ring`` at any payload size, because auto-upgrade is restricted to
    np.ufunc folds. (Forcing ``segmented`` opts into the elementwise
    contract and is allowed to differ.)"""
    K = 4

    def topk_merge(a, b):
        return np.sort(np.concatenate([a, b]))[-K:]

    def closure(world):
        r = world.get_rank()
        x = np.sort((np.arange(100, dtype=np.int64) * 37 + r * 53) % 997)
        return world.allreduce(x[-K:], topk_merge)

    want_pool = np.concatenate(
        [(np.arange(100, dtype=np.int64) * 37 + r * 53) % 997
         for r in range(3)])
    want = np.sort(want_pool)[-K:]
    # tiny segment threshold: would have re-routed this fold pre-guard
    out = parallelize_func(closure, backend="ring", timeout=30,
                           segment_bytes=1).execute(3)
    for got in out:
        assert np.array_equal(got, want), (got, want)


def test_backend_aliases_accepted():
    from repro.core.matching import normalize_backend
    assert normalize_backend("native") == "linear"
    assert normalize_backend("segmented-ring") == "segmented"
    with pytest.raises(ValueError, match="unknown message backend"):
        normalize_backend("bogus")


# ---------------------------------------------------------------------------
# Pure chunk/segment math invariants (hypothesis where installed, seeded
# fallback everywhere)
# ---------------------------------------------------------------------------

def _check_chunk_bounds(n, p):
    bounds = G.chunk_bounds(n, p)
    assert len(bounds) == p + 1
    assert bounds[0] == 0 and bounds[-1] == n
    sizes = [bounds[i + 1] - bounds[i] for i in range(p)]
    assert all(s >= 0 for s in sizes)
    assert max(sizes) - min(sizes) <= 1          # near-equal
    assert sizes == sorted(sizes, reverse=True)  # long chunks first


def _check_segment_spans(length, seg):
    spans = G.segment_spans(length, seg)
    if length <= 0:
        assert spans == []
        return
    assert spans[0][0] == 0 and spans[-1][1] == length
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c                   # contiguous, ordered
    assert all(0 < b - a <= seg for a, b in spans)


def test_chunk_and_segment_math_seeded():
    rng = random.Random(7)
    for _ in range(500):
        _check_chunk_bounds(rng.randrange(0, 10 ** 6), rng.randint(1, 64))
        _check_segment_spans(rng.randrange(0, 10 ** 5),
                             rng.randint(1, 10 ** 4))
    with pytest.raises(ValueError):
        G.chunk_bounds(10, 0)
    with pytest.raises(ValueError):
        G.segment_spans(10, 0)


# ---------------------------------------------------------------------------
# Hypothesis sweeps of the same case space (CI installs hypothesis)
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import HealthCheck, given, settings
    HAVE_HYPOTHESIS = True
except ImportError:     # container without hypothesis: seeded sweep above
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    COMMON = dict(deadline=None, derandomize=True,
                  suppress_health_check=[HealthCheck.too_slow,
                                         HealthCheck.data_too_large,
                                         HealthCheck.filter_too_much])

    def _draw_case(data):
        op = data.draw(st.sampled_from(OPS), label="op")
        driver = data.draw(st.sampled_from(DRIVERS), label="driver")
        backend = data.draw(st.sampled_from(BACKENDS), label="backend")
        n = data.draw(st.integers(2, 5), label="world")
        kind = data.draw(st.sampled_from(("array", "pytree")),
                         label="kind")
        shape = data.draw(st.sampled_from(SHAPES), label="shape")
        dtype = data.draw(st.sampled_from(DTYPES), label="dtype")
        seg = data.draw(st.sampled_from(SEGMENT_BYTES),
                        label="segment_bytes")
        root = data.draw(st.integers(0, n - 1), label="root")
        return op, driver, backend, n, kind, shape, dtype, seg, root

    @settings(max_examples=50, **COMMON)
    @given(data=st.data())
    def test_schedule_conformance_hypothesis_bounded(data):
        """Fast-lane hypothesis profile (shrinks failures to a minimal
        op x world x payload x segment counterexample)."""
        check_case(*_draw_case(data))

    @pytest.mark.slow
    @pytest.mark.cluster
    @pytest.mark.timeout(600)
    @settings(max_examples=250, **COMMON)
    @given(data=st.data())
    def test_schedule_conformance_hypothesis_deep(data):
        """Cluster-lane hypothesis profile: the same harness, 5x deeper."""
        check_case(*_draw_case(data))

    @given(n=st.integers(0, 10 ** 6), p=st.integers(1, 64))
    def test_chunk_bounds_partition(n, p):
        _check_chunk_bounds(n, p)

    @given(length=st.integers(0, 10 ** 5), seg=st.integers(1, 10 ** 4))
    def test_segment_spans_cover_exactly(length, seg):
        _check_segment_spans(length, seg)

"""MoE dispatch invariants (single device) + capacity behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe as MOE
from repro.parallel import axes as A
from repro.parallel.ops import ParallelConfig, make_ops

AXES1 = A.MeshAxes(1, 1, 1)
PCFG = ParallelConfig(sequence_parallel=False, remat="none")
KEY = jax.random.PRNGKey(0)


def setup(T=64, d=32, E=8, k=2, cf=8.0):
    cfg = dataclasses.replace(
        get_config("deepseek-moe-16b", smoke=True),
        d_model=d, n_experts=E, top_k=k, moe_d_ff=16,
        capacity_factor=cf, dtype=jnp.float32)
    specs = MOE.moe_param_specs(cfg)
    from repro.models.common import tree_instantiate
    p = tree_instantiate(specs, KEY, 0.02, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (T, d), jnp.float32)
    return cfg, p, x


def test_moe_aux_loss_bounds():
    cfg, p, x = setup()
    ops = make_ops(AXES1, PCFG)
    _, aux = MOE.moe_ffn(ops, p, x, cfg)
    # switch aux is ~1.0 at perfect balance, <= E at total collapse
    assert 0.9 < float(aux) <= cfg.n_experts


def test_moe_no_drops_at_high_capacity_matches_dense_gate():
    """With capacity >= T*k no token is dropped: output equals the dense
    per-token mixture computed directly."""
    cfg, p, x = setup(cf=16.0)
    ops = make_ops(AXES1, PCFG)
    out, _ = MOE.moe_ffn(ops, p, x, cfg)

    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(x))
    for t in range(x.shape[0]):
        acc = 0
        for j in range(cfg.top_k):
            e = int(topi[t, j])
            h = jax.nn.silu(x[t] @ p["wg"][e]) * (x[t] @ p["wu"][e])
            acc = acc + float(topv[t, j]) * np.asarray(h @ p["wd"][e])
        want[t] = acc
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg, p, x = setup(cf=0.25)
    ops = make_ops(AXES1, PCFG)
    out, _ = MOE.moe_ffn(ops, p, x, cfg)
    # some tokens must be zero (dropped entirely)
    norms = np.linalg.norm(np.asarray(out), axis=-1)
    assert (norms < 1e-12).any()


def test_moe_deterministic():
    cfg, p, x = setup()
    ops = make_ops(AXES1, PCFG)
    a, _ = MOE.moe_ffn(ops, p, x, cfg)
    b, _ = MOE.moe_ffn(ops, p, x, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_capacity_helper():
    assert MOE.capacity(4096, 6, 64, 1.25) % 4 == 0
    assert MOE.capacity(1, 1, 64, 1.0) == 4   # floor

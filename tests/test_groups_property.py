"""Property tests (hypothesis) for the pure rank/group machinery --
the invariants every comm backend builds on."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given  # noqa: E402

from repro.core import groups as G


sizes = st.integers(min_value=1, max_value=64)


@given(size=sizes)
def test_world_groups_partition(size):
    G.validate_groups(G.world_groups(size), size)


@given(size=st.integers(2, 48), data=st.data())
def test_split_partitions_and_orders(size, data):
    """MPI_Comm_split: every rank lands in exactly one color group,
    ordered by (key, parent rank)."""
    colors = data.draw(st.lists(st.integers(0, 3), min_size=size,
                                max_size=size))
    keys = data.draw(st.lists(st.integers(-5, 5), min_size=size,
                              max_size=size))
    per_color = G.split_groups(G.world_groups(size), colors, keys)
    seen = []
    for color, groups in per_color.items():
        for g in groups:
            seen.extend(g)
            # ordering invariant within the group
            ks = [(keys[r], r) for r in g]
            assert ks == sorted(ks)
            for r in g:
                assert colors[r] == color
    assert sorted(seen) == list(range(size))


@given(size=st.integers(1, 64), shift=st.integers(-64, 64),
       ngroups=st.integers(1, 4))
def test_ring_perm_is_permutation(size, shift, ngroups):
    if size % ngroups:
        ngroups = 1
    per = size // ngroups
    groups = tuple(tuple(range(i * per, (i + 1) * per))
                   for i in range(ngroups))
    pairs = G.ring_perm(groups, shift)
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    assert sorted(srcs) == list(range(size))
    assert sorted(dsts) == list(range(size))
    # shift composition: shifting by k then by -k is identity
    fwd = dict(pairs)
    back = dict(G.ring_perm(groups, -shift))
    assert all(back[fwd[r]] == r for r in range(size))


@given(size=st.integers(2, 32))
def test_comm_rank_table_roundtrip(size):
    groups = G.world_groups(size)
    table = G.comm_rank_table(groups, size)
    assert table == list(range(size))
    # two groups
    if size % 2 == 0:
        half = size // 2
        g2 = (tuple(range(half)), tuple(range(half, size)))
        t2 = G.comm_rank_table(g2, size)
        assert t2 == list(range(half)) * 2
        gid = G.group_id_table(g2, size)
        assert gid == [0] * half + [1] * half


@given(size=st.integers(2, 32), data=st.data())
def test_context_id_isolates_split_lineages(size, data):
    colors = data.draw(st.lists(st.integers(0, 1), min_size=size,
                                max_size=size))
    if len(set(colors)) < 2:
        colors = [i % 2 for i in range(size)]
    per = G.split_groups(G.world_groups(size), colors,
                         list(range(size)))
    ids = {c: G.context_id(g, 0) for c, g in per.items()}
    assert len(set(ids.values())) == len(ids)
    assert all(i != 0 for i in ids.values())   # 0 is the world context


def test_p2p_perm_rejects_cross_group_and_duplicates():
    groups = ((0, 1), (2, 3))
    # valid: comm-rank pair (0 -> 1) realized inside both groups
    pairs = G.p2p_perm(groups, [(0, 1)], 4)
    assert sorted(pairs) == [(0, 1), (2, 3)]
    with pytest.raises(ValueError):
        G.p2p_perm(groups, [(0, 2)], 4)      # comm rank out of range
    with pytest.raises(ValueError):
        G.p2p_perm(groups, [(0, 1), (0, 0)], 4)  # duplicate sender


@given(nbytes=st.integers(0, 10 ** 9), p=st.integers(1, 512),
       op=st.sampled_from(["allreduce", "broadcast", "allgather",
                           "reducescatter", "alltoall", "p2p"]),
       backend=st.sampled_from(["linear", "ring", "native"]))
def test_collective_cost_model_sane(nbytes, p, op, backend):
    c = G.collective_cost(op, backend, nbytes, p)
    assert c.bytes_per_device >= 0 and c.steps >= 0
    if p == 1:
        assert c.bytes_per_device == 0
    if p > 2 and nbytes > 0 and op == "allreduce":
        lin = G.collective_cost(op, "linear", nbytes, p)
        ring = G.collective_cost(op, "ring", nbytes, p)
        # phase-1 master relay moves ~p/2 x more bytes than the ring
        assert lin.bytes_per_device > ring.bytes_per_device


@given(n=st.integers(0, 10 ** 6), p=st.integers(1, 512))
def test_pad_to_multiple(n, p):
    m = G.pad_to_multiple(n, p)
    assert m % p == 0 and 0 <= m - n < p

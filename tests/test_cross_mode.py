"""Cross-mode equivalence: the same program under threads (local),
processes (cluster) and compiled SPMD produces identical results.

Each op runs in a subprocess because the spmd leg needs 8 forced host
devices, which must be set before jax initializes (same isolation as
tests/test_distributed.py)."""
import os
import subprocess
import sys

import pytest


pytestmark = pytest.mark.cluster       # own CI job: subprocess + compile


@pytest.mark.timeout(300)
@pytest.mark.parametrize("op", ["ring_p2p", "allreduce", "allgather",
                                "split", "iallreduce"])
def test_cross_mode_equivalence(op):
    script = os.path.join(os.path.dirname(__file__), "_cross_mode_check.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, script, op], capture_output=True,
                       text=True, timeout=280, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert f"CROSS-MODE OK {op}" in r.stdout

"""Matched mailbox internals: dict-indexed buffering, deadline
semantics, and thread-free ``receive_async`` waiter registration."""
import threading
import time

import pytest

from repro.core import parallelize_func
from repro.core.matching import Mailbox


# ---------------------------------------------------------------------------
# Mailbox: dict-of-deques buffering
# ---------------------------------------------------------------------------

def test_mailbox_match_is_keyed_and_fifo_per_key():
    mb = Mailbox()
    mb.put(0, 1, 2, "a")
    mb.put(0, 1, 2, "b")          # same key: arrival order preserved
    mb.put(0, 9, 2, "other-tag")
    mb.put(7, 1, 2, "other-ctx")
    assert mb.get(0, 1, 2, timeout=1.0) == "a"
    assert mb.get(0, 1, 2, timeout=1.0) == "b"
    assert mb.get(0, 9, 2, timeout=1.0) == "other-tag"
    assert mb.get(7, 1, 2, timeout=1.0) == "other-ctx"
    assert not mb.queues              # fully drained: no empty deques leak


def test_mailbox_get_timeout_is_absolute_deadline():
    """Unrelated arrivals wake the condition but must not restart the
    clock: the deadline is absolute."""
    mb = Mailbox()
    stop = threading.Event()

    def noise():
        while not stop.is_set():
            mb.put(0, 0, 99, None)        # wrong src: never matches
            time.sleep(0.02)

    t = threading.Thread(target=noise, daemon=True)
    t.start()
    t0 = time.monotonic()
    with pytest.raises(TimeoutError, match="src=1, tag=0"):
        mb.get(0, 0, 1, timeout=0.3)
    elapsed = time.monotonic() - t0
    stop.set()
    t.join()
    assert 0.25 <= elapsed < 2.0


def test_mailbox_blocking_get_wakes_on_arrival():
    mb = Mailbox()

    def later():
        time.sleep(0.05)
        mb.put(1, 2, 3, "payload")
    threading.Thread(target=later, daemon=True).start()
    assert mb.get(1, 2, 3, timeout=5.0) == "payload"


# ---------------------------------------------------------------------------
# receive_async: waiter registration, not thread-per-call
# ---------------------------------------------------------------------------

def test_get_async_immediate_and_deferred():
    mb = Mailbox()
    mb.put(0, 0, 1, "ready")
    fut = mb.get_async(0, 0, 1, timeout=1.0)
    assert fut.result(timeout=0) == "ready"      # already buffered

    fut = mb.get_async(0, 0, 2, timeout=5.0)     # registered waiter
    assert not fut.done()
    mb.put(0, 0, 2, "later")
    assert fut.result(timeout=1.0) == "later"
    assert not mb.waiters                        # waiter consumed


def test_get_async_timeout_sets_exception():
    mb = Mailbox()
    fut = mb.get_async(0, 5, 1, timeout=0.2)
    with pytest.raises(TimeoutError, match="tag=5"):
        fut.result(timeout=5.0)
    # an expired waiter must not swallow a late message
    mb.put(0, 5, 1, "late")
    assert mb.get(0, 5, 1, timeout=1.0) == "late"


def test_get_async_fifo_among_waiters():
    mb = Mailbox()
    f1 = mb.get_async(0, 0, 1, timeout=5.0)
    f2 = mb.get_async(0, 0, 1, timeout=5.0)
    mb.put(0, 0, 1, "first")
    mb.put(0, 0, 1, "second")
    assert f1.result(timeout=1.0) == "first"
    assert f2.result(timeout=1.0) == "second"


@pytest.mark.timeout(60)
def test_receive_async_stress_100_concurrent():
    """100 concurrent receive_async calls are serviced by waiter
    registration + one shared expiry thread -- not 100 parked threads."""
    N = 100
    before = threading.active_count()

    def closure(world):
        rank = world.get_rank()
        if rank == 0:
            futs = [world.receive_async(1, tag) for tag in range(N)]
            in_flight = threading.active_count()
            world.send(1, -1, "go")            # all futures registered
            vals = [f.result(timeout=30) for f in futs]
            return vals, in_flight
        world.receive(0, -1)                   # wait until all are pending
        for tag in range(N):
            world.send(0, tag, tag * tag)
        return None, 0

    out = parallelize_func(closure, timeout=60).execute(2)
    vals, in_flight = out[0]
    assert vals == [t * t for t in range(N)]
    # world threads + expiry thread, NOT +100 waiter threads
    assert in_flight - before < 10, (before, in_flight)

"""Buddy checkpointing protocol + elastic supervisor units (fast lane:
thread-mode SPMD worlds and unit-level supervisor helpers -- the real
process worlds live in test_elastic.py)."""
import os

import numpy as np
import pytest

from repro.core import groups as G
from repro.core import parallelize_func
from repro.core.cluster import ExecutorFailure
from repro.core.cluster.supervisor import ClusterSupervisor
from repro.train import buddy as B
from repro.train import checkpoint as CKPT
from repro.train import ft


# ---------------------------------------------------------------------------
# Group helpers for elastic membership
# ---------------------------------------------------------------------------

def test_group_elastic_helpers():
    assert G.buddy_rank(0, 4) == 1 and G.buddy_rank(3, 4) == 0
    assert G.buddy_rank(2, 4, offset=2) == 0
    assert G.buddy_rank(0, 1) == 0            # a world of one is its own buddy
    with pytest.raises(ValueError):
        G.buddy_rank(0, 0)
    m = G.survivor_map([0, 1, 2, 3], [1])
    assert m == {0: 0, 2: 1, 3: 2}            # contiguous, order-preserving
    assert G.remap_group((0, 2, 3), m) == (0, 1, 2)
    assert G.remap_group((1, 2), m) == (1,)   # dead members drop out
    with pytest.raises(ValueError):
        G.survivor_map([0, 1], [0, 1])


# ---------------------------------------------------------------------------
# Buddy snapshot/commit/recover protocol (thread-mode SPMD oracle)
# ---------------------------------------------------------------------------

def test_buddy_requires_two_epoch_history():
    with pytest.raises(ValueError, match="history"):
        B.BuddyCheckpointer("x", history=1)


def test_buddy_snapshot_commit_stages_peer_shard():
    B.reset("t-sc")

    def closure(comm):
        bc = B.BuddyCheckpointer("t-sc", history=3)
        r = comm.get_rank()
        outs = []
        for step in (1, 2):
            h = bc.snapshot(comm, step, np.full(3, 10.0 * r + step))
            bc.commit(comm, h)
            outs.append(bc.latest_committed(r))
        return outs

    assert parallelize_func(closure).execute(4) == [[1, 2]] * 4
    # every rank holds its left neighbor's shard (it is that rank's buddy)
    for r in range(4):
        e = B._store("t-sc", r)["epochs"][2]
        assert e["committed"] and e["peer_src"] == (r - 1) % 4
        np.testing.assert_array_equal(
            e["peer"], np.full(3, 10.0 * ((r - 1) % 4) + 2))
    B.reset("t-sc")


def _stage_world(ns, n=4, committed=(1, 2), torn=3):
    """Run an n-rank world that commits some epochs and leaves one
    staged-but-uncommitted (the snapshot 'interrupted' by a failure)."""
    def closure(comm):
        bc = B.BuddyCheckpointer(ns, history=8)
        r = comm.get_rank()
        for step in committed:
            bc.commit(comm, bc.snapshot(comm, step,
                                        np.full(2, 100.0 * r + step)))
        if torn is not None:
            h = bc.snapshot(comm, torn, np.full(2, 100.0 * r + torn))
            # transfers complete, but the world-wide commit never happens
            if h.recv_req is not None:
                h.recv_req.wait(timeout=10)
                h.send_req.wait(timeout=10)
        return bc.latest_committed(r)
    return parallelize_func(closure).execute(n)


def test_buddy_recover_skips_torn_epoch_and_rebuilds_dead_shard():
    B.reset("t-rec")
    assert _stage_world("t-rec") == [2] * 4
    # rank 1 dies; survivors [0, 2, 3] renumber to a world of 3

    def recover(comm):
        bc = B.BuddyCheckpointer("t-rec")
        step, shards = bc.recover(comm, old_size=4, old_rank_of=[0, 2, 3],
                                  dead_old_ranks=[1])
        return step, sorted(shards), float(shards[1][0])

    for step, keys, dead_val in parallelize_func(recover).execute(3):
        assert step == 2                  # torn epoch 3 is unreachable
        assert keys == [0, 1, 2, 3]       # full old-world coverage
        assert dead_val == 100.0 * 1 + 2  # from the buddy's staged copy
    B.reset("t-rec")


def test_buddy_owner_and_buddy_both_dead_raises_shard_lost():
    B.reset("t-dbl")
    _stage_world("t-dbl")
    # ranks 1 and 2 die together: shard 1 lived only at its buddy (2)

    def recover(comm):
        bc = B.BuddyCheckpointer("t-dbl")
        with pytest.raises(B.BuddyShardLost, match=r"old rank\(s\) \[1\]"):
            bc.recover(comm, old_size=4, old_rank_of=[0, 3],
                       dead_old_ranks=[1, 2])
        return "lost"

    assert parallelize_func(recover).execute(2) == ["lost"] * 2
    B.reset("t-dbl")


def test_buddy_recover_without_any_commit_raises():
    B.reset("t-none")
    _stage_world("t-none", committed=(), torn=1)

    def recover(comm):
        bc = B.BuddyCheckpointer("t-none")
        with pytest.raises(B.BuddyShardLost, match="no committed"):
            bc.recover(comm, old_size=4, old_rank_of=[0, 1, 2],
                       dead_old_ranks=[3])
        return "none"

    assert parallelize_func(recover).execute(3) == ["none"] * 3
    B.reset("t-none")


def test_buddy_single_rank_world_snapshot():
    B.reset("t-one")

    def closure(comm):
        bc = B.BuddyCheckpointer("t-one")
        bc.commit(comm, bc.snapshot(comm, 1, np.arange(3.0)))
        return bc.latest_committed(comm.get_rank())

    assert parallelize_func(closure).execute(1) == [1]
    B.reset("t-one")


# ---------------------------------------------------------------------------
# Checkpoint crash safety: torn step dirs are never restored
# ---------------------------------------------------------------------------

def test_latest_step_skips_torn_checkpoint(tmp_path):
    d = str(tmp_path)
    CKPT.save(d, 1, {"w": np.arange(4.0)})
    CKPT.save(d, 2, {"w": np.arange(4.0) * 2})
    assert CKPT.latest_step(d) == 2
    # tear step 2: a leaf its manifest names goes missing
    os.unlink(os.path.join(d, "step_00000002", "w.npy"))
    assert CKPT.latest_step(d) == 1
    flat, _, step = CKPT.load(d)
    assert step == 1
    np.testing.assert_array_equal(flat["w"], np.arange(4.0))
    # a stray .tmp dir (kill before the atomic rename) is invisible
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert CKPT.latest_step(d) == 1


def test_latest_step_skips_corrupt_manifest(tmp_path):
    d = str(tmp_path)
    CKPT.save(d, 1, {"w": np.zeros(2)})
    CKPT.save(d, 2, {"w": np.ones(2)})
    man = os.path.join(d, "step_00000002", "manifest.json")
    with open(man, "w") as f:
        f.write('{"step": 2, "leaves": {"w"')      # torn mid-write
    assert CKPT.latest_step(d) == 1
    os.unlink(os.path.join(d, "step_00000001", "manifest.json"))
    assert CKPT.latest_step(d) is None             # nothing restorable


def test_async_checkpointer_finish_is_idempotent(tmp_path):
    ck = CKPT.AsyncCheckpointer(str(tmp_path))
    ck.submit(3, {"w": np.full(2, 3.0)})
    ck.finish()
    ck.finish()                                    # supervisor's flush
    _, _, step = CKPT.load(str(tmp_path))
    assert step == 3


# ---------------------------------------------------------------------------
# Supervisor units: result persistence, straggler feed, suspicion
# ---------------------------------------------------------------------------

def _sup(tmp_path, **kw):
    return ClusterSupervisor(str(tmp_path), **kw)


def test_run_ctx_elastic_fields_default_inert(tmp_path):
    sup = _sup(tmp_path)
    ctx = sup._run_ctx(0, 0, 4)
    assert ctx.world_size == 4 and ctx.shrink_info is None
    assert ctx.backend_for(1) == "ring"


def test_results_persist_atomic_and_pruned(tmp_path):
    sup = _sup(tmp_path, keep_results=2)
    for s in (1, 2, 3):
        sup._save_results(s, [s * 10, s * 20])
    files = sorted(f for f in os.listdir(tmp_path)
                   if f.startswith("results_step_"))
    assert files == ["results_step_00000002.pkl",
                     "results_step_00000003.pkl"]
    assert sup._recover_results(3) == [30, 60]


def test_recover_results_falls_back_to_checkpoint_meta(tmp_path):
    sup = _sup(tmp_path)
    CKPT.save(str(tmp_path), 5, {"w": np.zeros(2)},
              meta={"results": [1, 2, 3]})
    assert sup._recover_results(5) == [1, 2, 3]
    with pytest.raises(RuntimeError, match="results were lost"):
        sup._recover_results(6)


def test_supervisor_feeds_straggler_detector(tmp_path):
    seen = []
    det = ft.StragglerDetector(alpha=0.5, threshold=3.0, warmup=1)
    sup = _sup(tmp_path, straggler_detector=det,
               on_straggler=lambda step, dt, pool: seen.append((step, dt)))
    for s in range(1, 5):
        sup._observe_step(s, 1.0, None)
    sup._observe_step(5, 30.0, None)
    assert sup.state.straggler_events == 1        # no longer write-only
    assert det.events and seen == [(5, 30.0)]
    sup._observe_step(6, 1.0, None)               # EWMA not poisoned
    assert sup.state.straggler_events == 1


class _FakePool:
    """rank_health/fail_ranks surface of ExecutorPool, one stale rank."""

    def __init__(self):
        self.failed = None

    def rank_health(self):
        return [{"rank": 0, "world_rank": 0, "alive": True,
                 "conn_dead": False, "last_seen_age": 0.01, "rtt": 1e-4},
                {"rank": 2, "world_rank": 1, "alive": True,
                 "conn_dead": False, "last_seen_age": 9.0, "rtt": None}]

    def fail_ranks(self, ranks, reason):
        self.failed = (list(ranks), reason)
        raise ExecutorFailure(list(ranks), reason)


def test_suspect_check_triggers_proactive_failure(tmp_path):
    pool = _FakePool()
    _sup(tmp_path)._suspect_check(pool)           # off by default: no-op
    assert pool.failed is None
    sup = _sup(tmp_path, suspect_after=1.0)
    with pytest.raises(ExecutorFailure):
        sup._suspect_check(pool)
    assert pool.failed[0] == [2]                  # the stale slot, by slot id
    assert "suspected dead" in pool.failed[1]


def test_supervisor_flushes_async_checkpointer(tmp_path):
    ck = CKPT.AsyncCheckpointer(str(tmp_path))
    ck.submit(7, {"w": np.full(2, 7.0)})
    sup = _sup(tmp_path, async_ckpt=ck)
    sup._flush_async_ckpt()
    assert CKPT.latest_step(str(tmp_path)) == 7
    sup._flush_async_ckpt()                       # idempotent via finish()

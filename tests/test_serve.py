"""Continuous-batching engine: greedy generations through the slot engine
must equal direct prefill+decode on the same model; slots recycle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import Model
from repro.parallel import axes as A
from repro.parallel.ops import ParallelConfig, make_ops
from repro.serve.engine import Engine

AXES1 = A.MeshAxes(1, 1, 1)
PCFG = ParallelConfig(path="mpignite", sequence_parallel=False, remat="none")


def build(arch="qwen3-4b", s_max=48, slots=3):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              dtype=jnp.float32)
    model = Model(cfg, AXES1, PCFG)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    ops = make_ops(AXES1, PCFG)

    @jax.jit
    def prefill_fn(params, batch):
        return model.prefill(ops, params, batch, s_max=s_max)

    @jax.jit
    def decode_fn(params, caches, tokens, pos):
        return model.decode(ops, params, caches, tokens, pos)

    eng = Engine(model, params, prefill_fn, decode_fn, max_slots=slots,
                 s_max=s_max)
    return cfg, model, params, ops, eng


def reference_generate(model, params, ops, prompt, n_new, s_max):
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    logits, caches = model.prefill(ops, params, batch, s_max=s_max)
    toks = [int(np.argmax(np.asarray(logits)[0]))]
    pos = len(prompt)
    for i in range(n_new - 1):
        logits, caches = model.decode(
            ops, params, caches,
            jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos + i], jnp.int32))
        toks.append(int(np.argmax(np.asarray(logits)[0])))
    return toks


def test_engine_matches_direct_decode():
    cfg, model, params, ops, eng = build()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 7)]
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    out = eng.run()
    for uid, prompt in zip(uids, prompts):
        want = reference_generate(model, params, ops, prompt, 6, eng.s_max)
        assert out[uid] == want, (uid, out[uid], want)


def test_engine_continuous_batching_recycles_slots():
    cfg, model, params, ops, eng = build(slots=2)
    rng = np.random.default_rng(1)
    uids = [eng.submit(rng.integers(0, cfg.vocab, 4).astype(np.int32),
                       max_new_tokens=3 + i) for i in range(5)]
    out = eng.run()
    assert set(out) == set(uids)
    assert [len(out[u]) for u in uids] == [3, 4, 5, 6, 7]
    assert eng.stats.prefills == 5
    assert max(eng.stats.batch_occupancy) == 2   # both slots were used


def test_engine_eos_stops_early():
    cfg, model, params, ops, eng = build()
    prompt = np.arange(5, dtype=np.int32)
    want = reference_generate(model, params, ops, prompt, 8, eng.s_max)
    eos = want[2]
    uid = eng.submit(prompt, max_new_tokens=8, eos_id=eos)
    out = eng.run()
    assert out[uid] == want[:3]   # stops at first appearance of eos

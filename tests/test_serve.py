"""Continuous-batching engine: greedy generations through the slot engine
must equal direct prefill+decode on the same model; slots recycle;
termination (EOS / budget / context cap) is honored at prefill and at
decode; speculative decoding is bit-identical to plain greedy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.common import ParamSpec
from repro.models.model import Model
from repro.parallel import axes as A
from repro.parallel.ops import ParallelConfig, make_ops
from repro.serve.cluster import ClusterServer
from repro.serve.engine import OCCUPANCY_TAIL, Engine
from repro.serve.spec import SpecDecoder

AXES1 = A.MeshAxes(1, 1, 1)
PCFG = ParallelConfig(path="mpignite", sequence_parallel=False, remat="none")


def build(arch="qwen3-4b", s_max=48, slots=3, gamma=0, draft="self"):
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              dtype=jnp.float32)
    model = Model(cfg, AXES1, PCFG)
    params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    ops = make_ops(AXES1, PCFG)

    @jax.jit
    def prefill_fn(params, batch):
        return model.prefill(ops, params, batch, s_max=s_max)

    @jax.jit
    def decode_fn(params, caches, tokens, pos):
        return model.decode(ops, params, caches, tokens, pos)

    spec = None
    if gamma:
        if draft == "self":       # draft == target: accepts everything
            dmodel, dparams = model, params
        else:                     # genuinely smaller, disagreeing draft
            dcfg = dataclasses.replace(cfg, n_layers=1,
                                       name=cfg.name + "-draft")
            dmodel = Model(dcfg, AXES1, PCFG)
            dparams = dmodel.init(jax.random.PRNGKey(1), dtype=jnp.float32)
        spec = SpecDecoder(model, ops, dmodel, dparams, s_max=s_max,
                           gamma=gamma)
    eng = Engine(model, params, prefill_fn, decode_fn, max_slots=slots,
                 s_max=s_max, spec=spec)
    return cfg, model, params, ops, eng


def reference_generate(model, params, ops, prompt, n_new, s_max):
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    logits, caches = model.prefill(ops, params, batch, s_max=s_max)
    toks = [int(np.argmax(np.asarray(logits)[0]))]
    pos = len(prompt)
    for i in range(n_new - 1):
        logits, caches = model.decode(
            ops, params, caches,
            jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos + i], jnp.int32))
        toks.append(int(np.argmax(np.asarray(logits)[0])))
    return toks


def test_engine_matches_direct_decode():
    cfg, model, params, ops, eng = build()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 7)]
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    out = eng.run()
    for uid, prompt in zip(uids, prompts):
        want = reference_generate(model, params, ops, prompt, 6, eng.s_max)
        assert out[uid] == want, (uid, out[uid], want)


def test_engine_continuous_batching_recycles_slots():
    cfg, model, params, ops, eng = build(slots=2)
    rng = np.random.default_rng(1)
    uids = [eng.submit(rng.integers(0, cfg.vocab, 4).astype(np.int32),
                       max_new_tokens=3 + i) for i in range(5)]
    out = eng.run()
    assert set(out) == set(uids)
    assert [len(out[u]) for u in uids] == [3, 4, 5, 6, 7]
    assert eng.stats.prefills == 5
    assert max(eng.stats.batch_occupancy) == 2   # both slots were used


def test_engine_eos_stops_early():
    cfg, model, params, ops, eng = build()
    prompt = np.arange(5, dtype=np.int32)
    want = reference_generate(model, params, ops, prompt, 8, eng.s_max)
    eos = want[2]
    uid = eng.submit(prompt, max_new_tokens=8, eos_id=eos)
    out = eng.run()
    assert out[uid] == want[:3]   # stops at first appearance of eos


# ---------------------------------------------------------------------------
# Termination at prefill (regression: a first token that is already
# terminal used to occupy a slot, burn a decode step, and over-generate)
# ---------------------------------------------------------------------------

def test_prefill_finish_eos_and_budget_of_one():
    cfg, model, params, ops, eng = build()
    prompt = np.arange(5, dtype=np.int32)
    first = reference_generate(model, params, ops, prompt, 1, eng.s_max)[0]
    u_eos = eng.submit(prompt, max_new_tokens=8, eos_id=first)
    u_one = eng.submit(prompt, max_new_tokens=1)
    out = eng.run()
    assert out[u_eos] == [first]      # exactly one token, not one extra
    assert out[u_one] == [first]
    assert eng.stats.decode_steps == 0          # never touched a slot
    assert eng.stats.prefill_finishes == 2
    assert eng.stats.tokens_out == 2
    assert not out[u_eos].truncated and not out[u_one].truncated
    assert not any(eng.active) and not eng.queue


def test_prefill_finish_frees_slot_for_next_in_queue():
    cfg, model, params, ops, eng = build(slots=1)
    prompt = np.arange(5, dtype=np.int32)
    want = reference_generate(model, params, ops, prompt, 3, eng.s_max)
    u_one = eng.submit(prompt, max_new_tokens=1)    # finishes at prefill
    u_norm = eng.submit(prompt, max_new_tokens=3)
    out = eng.run()
    # the single slot was re-admitted in the same step the first request
    # finished at prefill -- both prefills before any decode progress
    assert eng.stats.prefills == 2
    assert out[u_one] == want[:1]
    assert out[u_norm] == want


# ---------------------------------------------------------------------------
# Context-budget truncation is distinguishable from EOS
# ---------------------------------------------------------------------------

def test_truncated_flag_pins_context_cap():
    cfg, model, params, ops, eng = build(s_max=16)
    prompt = np.arange(5, dtype=np.int32)
    uid = eng.submit(prompt, max_new_tokens=100)
    out = eng.run()
    assert out[uid].truncated is True
    assert len(out[uid]) == 11          # pos 5 -> 15 == s_max - 1
    assert eng.stats.truncations == 1
    # a natural budget finish is NOT flagged
    uid2 = eng.submit(prompt, max_new_tokens=3)
    out2 = eng.run()
    assert out2[uid2].truncated is False and len(out2[uid2]) == 3
    assert eng.stats.truncations == 1


def test_truncated_at_prefill():
    cfg, model, params, ops, eng = build(s_max=16)
    prompt = np.arange(15, dtype=np.int32)      # already at s_max - 1
    uid = eng.submit(prompt, max_new_tokens=8)
    out = eng.run()
    assert out[uid].truncated is True and len(out[uid]) == 1
    assert eng.stats.decode_steps == 0
    assert eng.stats.truncations == 1


# ---------------------------------------------------------------------------
# Toy model with a deliberately ambiguous cache layout: a singleton
# "head" axis BEFORE batch -- (1, B, s_max). The first-size-1-dim
# heuristic widens/splices axis 0 here and silently corrupts other
# slots' caches (jnp clamps the out-of-range batch indices); the
# cache_specs shape-diff must pick axis 1.
# ---------------------------------------------------------------------------

TOY_VOCAB = 11


class ToyModel:
    def __init__(self, s_max):
        self.s_max = s_max

    def cache_specs(self, batch, s_max):
        return {"kv": ParamSpec((1, batch, s_max))}


def toy_fns(s_max):
    def prefill_fn(params, batch):
        toks = batch["tokens"]                      # (1, S)
        S = toks.shape[1]
        c = jnp.zeros((1, 1, s_max), jnp.int32)
        c = c.at[0, 0, :S].set(toks[0] + 1)         # +1: zero means empty
        nxt = (toks.sum() * 7 + S) % TOY_VOCAB
        return jax.nn.one_hot(nxt, TOY_VOCAB)[None], {"kv": c}

    def decode_fn(params, caches, tokens, pos):
        c = caches["kv"]                            # (1, B, s_max)
        B = tokens.shape[0]
        c = c.at[0, jnp.arange(B), pos].set(tokens[:, 0] + 1)
        s = (c[0].sum(axis=1) * 7 + pos + 1) % TOY_VOCAB
        return jax.nn.one_hot(s, TOY_VOCAB), {"kv": c}

    return prefill_fn, decode_fn


def toy_reference(prompt, n_new, s_max):
    store = np.zeros(s_max, np.int64)
    S = len(prompt)
    store[:S] = np.asarray(prompt, np.int64) + 1
    toks = [int((np.asarray(prompt).sum() * 7 + S) % TOY_VOCAB)]
    pos = S
    for _ in range(n_new - 1):
        store[pos] = toks[-1] + 1
        toks.append(int((store.sum() * 7 + pos + 1) % TOY_VOCAB))
        pos += 1
    return toks


def test_batch_axis_detected_from_cache_specs():
    s_max = 24
    pf, df = toy_fns(s_max)
    eng = Engine(ToyModel(s_max), None, pf, df, max_slots=3, s_max=s_max)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, TOY_VOCAB, n).astype(np.int32)
               for n in (4, 6, 5)]
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    out = eng.run()
    for uid, p in zip(uids, prompts):
        assert out[uid] == toy_reference(p, 6, s_max), uid
    # the metadata pinned the real batch axis despite the leading 1
    assert jax.tree_util.tree_leaves(eng._axis_tree) == [1]


def test_batch_axis_explicit_override_without_metadata():
    s_max = 24
    pf, df = toy_fns(s_max)
    # no model => no cache_specs; the ambiguous layout must be pinned
    # explicitly (the heuristic would pick axis 0 and corrupt slots)
    eng = Engine(None, None, pf, df, max_slots=3, s_max=s_max,
                 batch_axes=1)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, TOY_VOCAB, n).astype(np.int32)
               for n in (5, 3, 7)]
    uids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    out = eng.run()
    for uid, p in zip(uids, prompts):
        assert out[uid] == toy_reference(p, 5, s_max), uid


# ---------------------------------------------------------------------------
# O(1) occupancy stats
# ---------------------------------------------------------------------------

def test_occupancy_stats_are_bounded():
    s_max = 32
    pf, df = toy_fns(s_max)
    eng = Engine(ToyModel(s_max), None, pf, df, max_slots=2, s_max=s_max)
    rng = np.random.default_rng(4)
    for _ in range(80):
        eng.submit(rng.integers(0, TOY_VOCAB, 4).astype(np.int32),
                   max_new_tokens=8)
    eng.run()
    assert eng.stats.decode_steps > OCCUPANCY_TAIL
    assert len(eng.stats.batch_occupancy) == OCCUPANCY_TAIL   # bounded
    assert eng.stats.occupancy_steps == eng.stats.decode_steps
    assert 1.0 < eng.stats.mean_occupancy <= 2.0
    assert max(eng.stats.batch_occupancy) == 2    # back-compat surface


# ---------------------------------------------------------------------------
# Speculative decoding: bit-identical to greedy, acceptance telemetry
# ---------------------------------------------------------------------------

def test_spec_decode_identical_draft_accepts_everything():
    cfg, model, params, ops, eng = build(gamma=3, draft="self")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 7)]
    uids = [eng.submit(p, max_new_tokens=10) for p in prompts]
    out = eng.run()
    for uid, p in zip(uids, prompts):
        want = reference_generate(model, params, ops, p, 10, eng.s_max)
        assert out[uid] == want, uid
        assert out[uid].accept_ratio == 1.0
    assert eng.acceptance.ratio == 1.0
    # gamma+1 tokens per verified dispatch: 10 tokens in ceil(9/4)=3
    # target dispatches instead of 9
    assert eng.stats.spec_rounds == 3
    assert eng.stats.decode_steps == 3
    assert eng.acceptance.live == {}       # per-request state popped


def test_spec_decode_small_draft_still_bit_exact():
    cfg, model, params, ops, eng = build(gamma=3, draft="small")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 9, 7)]
    uids = [eng.submit(p, max_new_tokens=10) for p in prompts]
    out = eng.run()
    for uid, p in zip(uids, prompts):
        want = reference_generate(model, params, ops, p, 10, eng.s_max)
        assert out[uid] == want, uid        # rejections change cost only
    assert eng.stats.spec_rounds >= 3
    assert 0.0 <= eng.acceptance.ratio <= 1.0


def test_spec_decode_falls_back_near_context_budget():
    # s_max=16: slots run out of headroom for gamma+1 writes near the
    # end, so the engine must degrade to single-token steps and still
    # truncate exactly where the plain path does
    cfg, model, params, ops, eng = build(s_max=16, gamma=3, draft="self")
    prompt = np.arange(5, dtype=np.int32)
    uid = eng.submit(prompt, max_new_tokens=100)
    out = eng.run()
    cfg2, model2, params2, ops2, plain = build(s_max=16)
    uid2 = plain.submit(prompt, max_new_tokens=100)
    out2 = plain.run()
    assert list(out[uid]) == list(out2[uid2])
    assert out[uid].truncated and len(out[uid]) == 11
    assert eng.stats.spec_rounds > 0                 # spec ran early on
    assert eng.stats.decode_steps > eng.stats.spec_rounds   # then fell back


# ---------------------------------------------------------------------------
# Cluster front-end, local mode: the routing/ack/merge machinery over
# in-process engines (the cluster lane exercises the pooled real thing)
# ---------------------------------------------------------------------------

def test_cluster_server_local_mode_routes_and_drains():
    s_max = 24

    def build_engine(params, replica_id):
        pf, df = toy_fns(s_max)
        return Engine(ToyModel(s_max), None, pf, df, max_slots=2,
                      s_max=s_max)

    srv = ClusterServer(2, build_engine, mode="local", quantum=4)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, TOY_VOCAB, 3 + i % 4).astype(np.int32)
               for i in range(7)]
    uids = [srv.submit(p, max_new_tokens=5 + i % 3)
            for i, p in enumerate(prompts)]
    out = srv.run_until_drained()
    assert set(out) == set(uids)
    for i, (uid, p) in enumerate(zip(uids, prompts)):
        assert list(out[uid]) == toy_reference(p, 5 + i % 3, s_max), uid
        assert srv.latency(uid) is not None
    assert srv.rounds >= 2                  # quantum forced multi-round
    prefills = [srv.replica_stats[s]["stats"]["prefills"]
                for s in sorted(srv.replica_stats)]
    assert sum(prefills) == 7 and all(p > 0 for p in prefills)

"""Multi-host bootstrap: pluggable launchers, the module-entry executor
CLI, routable binds, and the HMAC handshake that authenticates every
control- and data-plane connection.

The acceptance path: a world whose executors are *spawned* as plain
subprocesses through ``CommandLauncher`` (no fork), bound on a
non-loopback-hardcoded interface, completes the paper's listing-2 ring
exchange with auth enabled and produces results identical to
``ForkLauncher`` -- while wrong-secret and legacy no-secret dials are
refused on both planes.
"""
import os
import signal
import socket
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.cluster import (ClusterPool, ClusterSupervisor,
                                CommandLauncher, ExecutorFailure,
                                ForkLauncher, wire)
from repro.train import ft

pytestmark = pytest.mark.cluster       # own CI job: spawned worlds


def _make_ring():
    """The paper's listing-2 token ring, built as a *nested* function:
    cloudpickle ships those by value, which is what lets a closure
    defined here run inside a spawned interpreter that cannot import
    this test module (the real remote-executor constraint)."""
    def ring(world):
        rank, size = world.get_rank(), world.get_size()
        if rank == 0:
            world.send(1, 0, 42)
            return world.receive(size - 1, 0)
        token = world.receive(rank - 1, 0)
        world.send((rank + 1) % size, 0, token)
        return token
    return ring


# ---------------------------------------------------------------------------
# Spawn-and-connect bootstrap (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.timeout(180)
def test_command_launcher_matches_fork():
    """Executors spawned via the module-entry CLI (real subprocesses, no
    fork), bound on all interfaces instead of a hardcoded loopback,
    complete listing-2 with HMAC auth and match ForkLauncher exactly."""
    with ClusterPool(3, launcher=ForkLauncher(), timeout=60) as pool:
        want = pool.run(_make_ring())
    with ClusterPool(3, launcher=CommandLauncher(), bind_host="0.0.0.0",
                     timeout=120) as pool:
        got = pool.run(_make_ring())
        # the world advertised concrete routable addresses, not the
        # wildcard it bound
        assert all(a[0] not in ("0.0.0.0", "::", "") and a[1] > 0
                   for a in pool.data_addrs)
        # and the data plane stayed direct: no msg frame hit the driver
        assert pool.frame_counts.get("msg", 0) == 0
        assert pool.rejected_dials == 0
    assert got == want == [42, 42, 42]


@pytest.mark.timeout(180)
def test_command_launcher_warm_pool_collectives():
    """A spawned world is a full citizen: persistent across jobs, both
    collective backends, arbitrary payloads."""
    with ClusterPool(2, launcher=CommandLauncher(), timeout=120) as pool:
        pids = pool.pids
        out1 = pool.run(lambda c: c.allgather(c.get_rank()))
        out2 = pool.run(
            lambda c: float(c.allreduce(np.float64(1.0), lambda a, b: a + b)),
            backend="ring")
        assert pool.pids == pids          # same subprocesses, second job
    assert out1 == [[0, 1], [0, 1]]
    assert out2 == [2.0, 2.0]


def test_executor_cli_argument_contract():
    """The module entry exists and fails loudly on a bad invocation --
    no secret means no boot."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop(wire.SECRET_ENV, None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.core.cluster.executor",
         "--rank", "0", "--world", "1", "--driver", "127.0.0.1:1"],
        capture_output=True, text=True, timeout=60, env=env)
    assert r.returncode != 0
    assert "secret" in r.stderr.lower()
    r = subprocess.run(
        [sys.executable, "-m", "repro.core.cluster.executor",
         "--rank", "0", "--world", "1", "--driver", "not-an-address"],
        capture_output=True, text=True, timeout=60, env=env)
    assert r.returncode != 0
    assert "HOST:PORT" in r.stderr


@pytest.mark.timeout(120)
def test_bootstrap_fails_fast_on_wrong_executor_secret(tmp_path):
    """Executors launched with the wrong shared secret exit on the
    refused handshake; the bootstrap must surface that exit (code 3)
    within seconds, not stall out the whole connect timeout."""
    from repro.core.cluster.launcher import DEFAULT_COMMAND_TEMPLATE
    bad = tmp_path / "wrong.secret"
    bad.write_bytes(b"not-the-drivers-secret")
    tmpl = [str(bad) if part == "{secret_file}" else part
            for part in DEFAULT_COMMAND_TEMPLATE]
    t0 = time.time()
    with pytest.raises(ExecutorFailure,
                       match="exited before registering") as ei:
        ClusterPool(2, launcher=CommandLauncher(tmpl), timeout=60)
    assert time.time() - t0 < 45        # way under the 60s timeout
    assert "3" in str(ei.value)         # the auth-refused exit code


# ---------------------------------------------------------------------------
# Auth: wrong-secret and legacy dials are refused on both planes
# ---------------------------------------------------------------------------

def test_wrong_secret_control_dial_rejected():
    """A dialer with the wrong secret fails the control-plane handshake;
    the pool notes the rejection and keeps serving."""
    with ClusterPool(2, timeout=30) as pool:
        sock = socket.create_connection(pool.control_addr, timeout=10)
        with pytest.raises(wire.AuthError):
            wire.client_handshake(sock, b"not-the-secret", timeout=10)
        sock.close()
        deadline = time.time() + 5
        while pool.rejected_dials < 1 and time.time() < deadline:
            time.sleep(0.05)
        assert pool.rejected_dials >= 1
        assert pool.run(lambda c: c.get_rank()) == [0, 1]


def test_wrong_secret_data_dial_rejected():
    """A dialer with the wrong secret fails the data-plane handshake at
    the executor's listener; legitimate traffic is unaffected."""
    with ClusterPool(2, timeout=30) as pool:
        addr = pool.data_addrs[0]
        assert addr is not None
        sock = socket.create_connection(addr, timeout=10)
        with pytest.raises(wire.AuthError):
            wire.client_handshake(sock, b"not-the-secret", timeout=10)
        sock.close()
        assert pool.run(_make_ring()) == [42, 42]


def test_legacy_no_secret_dial_fails_closed():
    """A pre-auth client that leads with a bare hello frame (no
    handshake) is disconnected on both planes: the protocol fails
    closed, it does not fall back to cleartext registration."""
    def legacy_dial(addr, hello):
        sock = socket.create_connection(addr, timeout=10)
        try:
            sock.settimeout(10)
            # server speaks first (the challenge); a legacy client
            # barrels ahead with its hello anyway
            wire.send_frame(sock, hello)
            saw_eof = False
            for _ in range(4):      # challenge frame, then EOF
                if sock.recv(4096) == b"":
                    saw_eof = True
                    break
            return saw_eof
        finally:
            sock.close()

    with ClusterPool(2, timeout=30) as pool:
        assert legacy_dial(pool.control_addr,
                           {"kind": "hello", "rank": 0, "data_addr": None})
        assert legacy_dial(pool.data_addrs[1], {"kind": "hello", "src": 0})
        assert pool.run(lambda c: c.get_size()) == [2, 2]


def test_malformed_handshake_does_not_kill_listener():
    """Attacker-controlled JSON of the wrong shape (int nonce, array
    header) must be rejected like any bad dial -- and the driver's
    lifetime rejection thread must survive to refuse the next one."""
    def dropped(sock):
        try:
            return sock.recv(4096) == b""
        except ConnectionError:
            return True

    with ClusterPool(2, timeout=30) as pool:
        for bad_reply in ({"kind": "auth_reply", "nonce": 42, "mac": 7},
                          {"kind": "auth_reply", "nonce": "zz", "mac": "x"},
                          ["not", "a", "dict"]):
            sock = socket.create_connection(pool.control_addr, timeout=10)
            sock.settimeout(10)
            challenge = wire.recv_frame(sock)
            assert challenge[0]["kind"] == "auth"
            wire.send_frame(sock, bad_reply)
            assert dropped(sock)
            sock.close()
        # the reject loop survived every malformed dial: a fresh dial
        # still gets challenged and refused
        sock = socket.create_connection(pool.control_addr, timeout=10)
        with pytest.raises(wire.AuthError):
            wire.client_handshake(sock, b"wrong-secret", timeout=10)
        sock.close()
        assert pool.run(lambda c: c.get_rank()) == [0, 1]


def test_replayed_hello_rejected_on_data_plane():
    """The hello MAC is bound to the handshake transcript: a correctly
    authenticated connection presenting a hello MAC'd under a *different*
    transcript (a replayed registration) is dropped, while a fresh MAC
    keeps the connection open."""
    with ClusterPool(2, timeout=30) as pool:
        addr = pool.data_addrs[0]

        # replay: valid handshake, stale-transcript hello -> EOF
        sock = socket.create_connection(addr, timeout=10)
        wire.client_handshake(sock, pool.secret, timeout=10)
        hello = {"kind": "hello", "src": 1}
        hello["mac"] = wire.hello_mac(pool.secret, b"stale-transcript",
                                      hello)
        wire.send_frame(sock, hello)
        sock.settimeout(10)
        assert sock.recv(4096) == b""         # executor dropped us
        sock.close()

        # control: fresh transcript-bound hello -> connection stays open
        sock = socket.create_connection(addr, timeout=10)
        transcript = wire.client_handshake(sock, pool.secret, timeout=10)
        hello = {"kind": "hello", "src": 1}
        hello["mac"] = wire.hello_mac(pool.secret, transcript, hello)
        wire.send_frame(sock, hello)
        sock.settimeout(0.5)
        with pytest.raises(socket.timeout):
            sock.recv(4096)                   # no EOF: we were admitted
        sock.close()


def test_preauth_frame_cap_and_secret_normalization():
    """A rogue dialer claiming a gigabyte frame before authenticating
    must be refused without the buffer ever being allocated; and a
    secret read with a trailing newline must derive the same key as the
    stripped file the executors load."""
    import struct
    a, b = socket.socketpair()
    try:
        b.sendall(struct.pack(">IQ", 1 << 30, 0))     # 1 GiB header claim
        with pytest.raises(wire.AuthError):
            wire.server_handshake(a, b"s", timeout=5.0)
    finally:
        a.close()
        b.close()
    assert wire.load_secret(b"secret\n") == b"secret"
    assert wire.load_secret("  secret  ") == b"secret"


def test_warm_pool_key_includes_transport_config():
    """get_pool must never hand back a cached pool whose launcher,
    binds, or secret differ from what the caller asked for -- those
    shape the world itself, unlike the per-job backend."""
    from repro.core.cluster import get_pool
    p1 = get_pool(2)
    assert get_pool(2) is p1                          # same config: cached
    assert get_pool(2, launcher=ForkLauncher()) is p1  # None == default fork
    p2 = get_pool(2, secret=b"explicitly-different")
    assert p2 is not p1                               # new credentials
    assert get_pool(2) is p1                          # original still cached
    assert p2.run(lambda c: c.get_rank()) == [0, 1]
    # launcher identity is part of the key via cache_key()
    a = CommandLauncher(["{python}", "-m", "x", "--rank", "{rank}"])
    b = CommandLauncher(["{python}", "-m", "x", "--rank", "{rank}"])
    assert a.cache_key() == b.cache_key()
    assert a.cache_key() != CommandLauncher().cache_key()
    assert ForkLauncher().cache_key() != CommandLauncher().cache_key()


def test_secret_resolution_order(tmp_path, monkeypatch):
    """Explicit secret > secret file > environment; hex survives all."""
    path = tmp_path / "cluster.secret"
    path.write_bytes(b"file-secret\n")
    monkeypatch.setenv(wire.SECRET_ENV, "env-secret")
    assert wire.load_secret(b"arg-secret", str(path)) == b"arg-secret"
    assert wire.load_secret(None, str(path)) == b"file-secret"
    assert wire.load_secret() == b"env-secret"
    monkeypatch.delenv(wire.SECRET_ENV)
    assert wire.load_secret() is None
    assert len(wire.generate_secret()) == 32


# ---------------------------------------------------------------------------
# Supervisor recovery through the launcher abstraction
# ---------------------------------------------------------------------------

@pytest.mark.timeout(300)
def test_supervisor_recovers_command_launched_rank(tmp_path):
    """Regression for fork-only recovery: SIGKILL a *spawned* (module
    entry subprocess) rank between steps; the supervisor must relaunch
    through the same CommandLauncher and finish with correct results."""
    total, n, kill_after = 4, 2, 2
    killed = []

    def make_step(run, step):
        def closure(comm):
            rank = comm.get_rank()
            restored = run.restore()
            acc = 0.0 if restored is None else float(restored[0]["acc"][0])
            acc += float(comm.allreduce(np.float64(rank * step),
                                        lambda a, b: a + b))
            if rank == 0:
                run.save(step, {"acc": np.array([acc])})
            return acc
        return closure

    def on_step(step, pool):
        if step == kill_after and not killed:
            killed.append(pool.pids[1])
            os.kill(pool.pids[1], signal.SIGKILL)
            time.sleep(0.2)

    policy = ft.RecoveryPolicy(degrade_backend="linear", recovery_steps=1,
                               max_restarts=2)
    sup = ClusterSupervisor(str(tmp_path), policy=policy,
                            fast_backend="ring", timeout=120,
                            hb_interval=0.05, hb_timeout=2.0,
                            launcher=CommandLauncher())
    out = sup.run_steps(make_step, n, total, on_step=on_step)

    assert killed and sup.state.restarts == 1
    assert sup.failures[0][0] == kill_after
    expect = float(sum(step * sum(range(n)) for step in range(1, total + 1)))
    assert out == [expect] * n

"""End-to-end behaviour of the paper's system: the four listings from
MPIgnite section 4, executed on the LocalComm runtime (the paper's
"local deployment") via parallelize_func(...).execute(n)."""
import numpy as np
import pytest

from repro.core import MPIgniteContext, parallelize_func


sc = MPIgniteContext()


def test_listing1_matvec():
    """Listing 1: matrix-vector multiply, no explicit communication."""
    mat = np.array([[1, 2, 3], [4, 5, 6], [7, 8, 9]])
    vec = np.array([1, 2, 3])

    res = sum(sc.parallelize_func(
        lambda world: int(mat[world.get_rank()] @ vec)
        if world.get_rank() < len(mat) else 0
    ).execute(8))
    assert res == int(mat @ vec @ np.ones(3)) == 96


def test_listing2_ring():
    """Listing 2: token passed around a ring; blocking receive."""
    def ring(world):
        rank, size = world.get_rank(), world.get_size()
        if rank == 0:
            token = 42
            world.send(rank + 1, 0, token)
            return world.receive(size - 1, 0)
        token = world.receive(rank - 1, 0)
        world.send((rank + 1) % size, 0, token + 1)
        return token

    out = parallelize_func(ring).execute(16)
    assert out[0] == 42 + 15                  # went all the way around
    assert out[1:] == [42 + i for i in range(15)]


def test_listing3_nonblocking_even_odd():
    """Listing 3: receiveAsync futures (MPI_Irecv / MPI_Wait)."""
    def even_odd(world):
        size, rank = world.get_size(), world.get_rank()
        half = size // 2
        if rank < half:
            world.send(rank + half, 0, rank)
            fut = world.receive_async(rank + half, 0)
            return fut.result(timeout=10)     # Await.result ~ MPI_Wait
        r = world.receive(rank - half, 0)
        world.send(rank - half, 0, r % 2 == 0)
        return None

    out = parallelize_func(even_odd).execute(10)
    assert out[:5] == [True, False, True, False, True]


def test_listing4_2d_matvec():
    """Listing 4: 2-D decomposition with split/broadcast/allReduce."""
    n = 3
    mat = np.arange(1, 10).reshape(3, 3)      # a[i,j] = 3i+j+1
    vec = np.array([1, 2, 3])

    def matvec2d(world):
        wr = world.get_rank()
        row = world.split(wr // n, wr)        # row communicator
        col = world.split(wr % n, wr)         # column communicator
        i, j = wr // n, wr % n
        a = mat[i, j]
        # distribute vector entries down the columns from row 0
        x_j = col.broadcast(0, int(vec[j]) if i == 0 else None)
        partial = int(a) * x_j
        return row.allreduce(partial, lambda p, q: p + q)

    out = parallelize_func(matvec2d).execute(n * n)
    want = mat @ vec
    for i in range(n):
        assert out[i * n:(i + 1) * n] == [want[i]] * n


def test_closures_are_first_class_and_reusable():
    """Section 3.2: closures can be wrapped, passed, reused -- run the
    same function at two widths and via a parameterizing wrapper."""
    def total_ranks(world):
        return world.allreduce(world.get_rank(), lambda a, b: a + b)

    assert parallelize_func(total_ranks).execute(4)[0] == 6
    assert parallelize_func(total_ranks).execute(8)[0] == 28

    def scaled(factor):
        def f(world):
            return factor * world.get_rank()
        return f
    assert parallelize_func(scaled(10)).execute(3) == [0, 10, 20]


def test_tag_and_context_isolation():
    """Messages match on (source, tag, context): a message sent on a
    sub-communicator is not visible to the world communicator."""
    def f(world):
        rank = world.get_rank()
        sub = world.split(color=rank % 2, key=rank)
        if rank == 0:
            sub.send(1, 7, "ctx-isolated")    # to world rank 2 (sub rank 1)
            world.send(1, 7, "world-msg")     # to world rank 1
        if rank == 1:
            return world.receive(0, 7)
        if rank == 2:
            return sub.receive(0, 7)
        return None

    out = parallelize_func(f).execute(4)
    assert out[1] == "world-msg"
    assert out[2] == "ctx-isolated"


def test_arbitrary_objects_and_reductions():
    """Section 3.4: first-class (serializable) objects as messages;
    allReduce with an arbitrary user reduction."""
    def f(world):
        rank = world.get_rank()
        obj = {"rank": rank, "payload": [rank] * rank}
        if rank == 0:
            world.send(1, 0, obj)
        if rank == 1:
            got = world.receive(0, 0)
            assert got["payload"] == []
        # arbitrary reduction: elementwise max of dicts (collectives are
        # collective -- every rank participates, exactly as in MPI)
        return world.allreduce(
            {"m": rank}, lambda a, b: {"m": max(a["m"], b["m"])})["m"]

    out = parallelize_func(f).execute(4)
    assert out == [3, 3, 3, 3]


def test_deadlock_detection():
    """The implicit end-of-closure barrier: a closure that never
    completes raises instead of hanging the driver."""
    def f(world):
        if world.get_rank() == 0:
            world.receive(1, 99)   # never sent
        return 1

    with pytest.raises((TimeoutError, Exception)):
        parallelize_func(f, timeout=1.5).execute(2)

"""Data layer: deterministic training pipelines and the Spark-shaped
partitioned-dataset runtime.

- ``dataset``  : :class:`DataContext` / :class:`PartitionedDataset` --
  lazy DAGs of fused narrow stages with shuffles on the runtime's own
  collectives and per-partition lineage recovery (``docs/dataset.md``).
- ``pipeline`` : stateless-by-step token sources
  (:class:`SyntheticTokens`, :class:`MemmapTokens`),
  :func:`make_batch`, :class:`Prefetcher`, and :func:`batch_shards`
  re-expressing the shards as a dataset.
"""
from .dataset import DataContext, PartitionedDataset
from .pipeline import (MemmapTokens, Prefetcher, SyntheticTokens,
                       batch_shards, make_batch)

__all__ = ["DataContext", "MemmapTokens", "PartitionedDataset",
           "Prefetcher", "SyntheticTokens", "batch_shards", "make_batch"]

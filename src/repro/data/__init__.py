from .pipeline import MemmapTokens, Prefetcher, SyntheticTokens, make_batch

__all__ = ["MemmapTokens", "Prefetcher", "SyntheticTokens", "make_batch"]

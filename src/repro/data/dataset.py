"""Spark-shaped partitioned datasets whose shuffle rides the runtime's
own collectives.

The source paper brings MPI's peer communication *into* Spark; this
module completes the inverse: a lazily-evaluated, partitioned dataset
API (``parallelize / map / filter / flatMap / reduceByKey / groupByKey /
sortByKey / collect / cache``) built *on* the MPI-shaped runtime, so
ETL-style jobs, eval sweeps and training data prep share one world with
training and serving.

Execution model
---------------
A :class:`PartitionedDataset` is a node in a lazy DAG. ``collect()``
compiles the DAG into **stages**: maximal chains of narrow ops (map /
filter / flatMap -- partition-local, no data movement) fused into a
single closure, separated by **wide** (shuffle) boundaries
(reduceByKey / groupByKey / sortByKey). One pooled job evaluates every
stage; within a wide stage the repartitioning runs on the runtime's own
``ialltoall`` / ``ireducescatter`` between the executors' warm peer
channels -- records never transit the driver. (A deliberately naive
``collect(shuffle="gather")`` baseline *does* route every record
through the driver; ``benchmarks/run.py`` gates the collectives path
>= 2x faster.)

Shuffle rounds are pipelined: the collective for map partition *k* is
in flight while partition *k+1*'s map side computes, and the round
count is ``groups.shuffle_rounds`` -- uniform across ranks -- so
collective call order always matches.

Lineage and elasticity
----------------------
Every shuffle output partition (and every ``cache()``-ed partition) is
materialized in its owner executor's process memory, keyed by
``(namespace, dataset uid, partition)``. Placement is the pure function
``groups.partition_owner(part, nparts, size)``. When a rank dies
mid-job the pool raises ``ExecutorFailure``; ``collect`` retries
through :meth:`ClusterSupervisor.run_job`, which shrinks the pool to
the survivors and passes ``shrink_info`` into the re-dispatched job.
The retry then:

1. **invalidates** store entries for ``groups.lost_partitions(...)``
   derived from ``shrink_info`` (the dead ranks' partitions),
2. **rebalances**: each wide stage starts with an ``allgather`` of
   per-rank holdings; surviving partitions whose owner moved under the
   new world size are shipped to their new owner in one ``alltoall``
   instead of being recomputed,
3. **recomputes only the truly lost partitions** from their surviving
   parents: the map side re-runs the fused closure chain over its
   owned parent partitions and sends buckets *only* for the lost
   outputs.

Results are bit-exact across recovery paths (and across single / local
/ cluster modes) because every shuffle payload is tagged with its map
partition id and merged in ascending map-partition order -- the fold
order never depends on world size, timing, or which ranks survived.

Quickstart
----------
::

    from repro.data import DataContext

    with DataContext(4, mode="cluster") as ctx:
        lines = ctx.parallelize(open("corpus.txt").read().splitlines())
        counts = (lines.flatMap(str.split)
                       .map(lambda w: (w, 1))
                       .reduceByKey(lambda a, b: a + b)
                       .sortByKey())
        print(counts.collect()[:10])

See ``docs/dataset.md`` for the full API reference and
``docs/architecture.md`` for where this layer sits in the runtime.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
import operator
import os
import tempfile
import threading
from typing import Any, Callable, Iterable, Sequence

from ..core import groups as G

__all__ = ["DataContext", "PartitionedDataset"]

_SAMPLES_PER_PART = 32      # sortByKey splitter sample floor per partition
_SAMPLE_EVERY = 64          # +1 sample per this many records above the floor
_MAX_SAMPLES_PER_PART = 1024
_CTX_SEQ = itertools.count()
_UID_SEQ = itertools.count()

# ---------------------------------------------------------------------------
# Partition store: materialized partitions living in *executor process
# memory*, surviving across pooled jobs (same pattern as train.buddy's
# snapshot stores). Keyed (namespace, dataset uid, partition). In local
# mode the ranks are threads of the driver, so they share one store; in
# cluster mode each executor naturally holds only what it materialized.
# ---------------------------------------------------------------------------
_STORE: dict[tuple[str, str, int], list] = {}
_STORE_LOCK = threading.Lock()


def _store_get(key: tuple) -> list | None:
    with _STORE_LOCK:
        return _STORE.get(key)


def _store_put(key: tuple, records: list) -> None:
    with _STORE_LOCK:
        _STORE[key] = records


def _store_drop(ns: str, uid: str | None = None,
                parts: Iterable[int] | None = None) -> int:
    """Drop store entries for a namespace (optionally one dataset /
    some partitions). Returns how many entries were dropped."""
    pset = None if parts is None else set(parts)
    with _STORE_LOCK:
        doomed = [k for k in _STORE
                  if k[0] == ns
                  and (uid is None or k[1] == uid)
                  and (pset is None or k[2] in pset)]
        for k in doomed:
            del _STORE[k]
    return len(doomed)


def _store_parts(ns: str, uid: str) -> list[int]:
    """Partitions of ``uid`` materialized in this process, ascending."""
    with _STORE_LOCK:
        return sorted(k[2] for k in _STORE if k[0] == ns and k[1] == uid)


# ---------------------------------------------------------------------------
# Plan representation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _PlanNode:
    kind: str                       # root | map | filter | flatMap | shuffle
    uid: str
    parent: "_PlanNode | None"
    nparts: int
    fn: Callable | None = None      # narrow op / reduceByKey combiner
    how: str | None = None          # shuffle flavor
    ascending: bool = True          # sortByKey order
    root_kind: str | None = None    # "data" | "range"
    data: Any = None                # driver payload ("data") or stop ("range")
    cached: bool = False


@dataclasses.dataclass
class _ShuffleSpec:
    how: str
    fn: Callable | None
    nparts: int
    ascending: bool
    uid: str


@dataclasses.dataclass
class _Stage:
    """A maximal fused chain of narrow ops between two boundaries.

    The input boundary is either the plan root (``root`` set) or a
    previous stage's shuffle output (``input_uid``); the output boundary
    is a shuffle (``out``) or -- for the final stage -- the collect
    result itself (``out is None``)."""
    input_uid: str | None
    root: "_PlanNode | None"
    in_nparts: int
    ops: list[_PlanNode]
    out: _ShuffleSpec | None


def _compile(node: _PlanNode) -> list[_Stage]:
    chain: list[_PlanNode] = []
    n: _PlanNode | None = node
    while n is not None:
        chain.append(n)
        n = n.parent
    chain.reverse()
    root = chain[0]
    stages: list[_Stage] = []
    input_uid: str | None = None
    cur_root: _PlanNode | None = root
    in_nparts = root.nparts
    ops: list[_PlanNode] = []
    for nd in chain[1:]:
        if nd.kind == "shuffle":
            spec = _ShuffleSpec(nd.how, nd.fn, nd.nparts, nd.ascending,
                                nd.uid)
            stages.append(_Stage(input_uid, cur_root, in_nparts, ops, spec))
            input_uid, cur_root, ops = nd.uid, None, []
            in_nparts = nd.nparts
        else:
            ops.append(nd)
    stages.append(_Stage(input_uid, cur_root, in_nparts, ops, None))
    return stages


# ---------------------------------------------------------------------------
# Pure stage evaluation -- shared verbatim by the single-process oracle,
# the thread runtime, the cluster executors, and the driver-gather
# baseline, which is what makes cross-mode conformance bit-exact.
# ---------------------------------------------------------------------------

def _concat(a: list, b: list) -> list:
    return a + b


def _root_records(root: _PlanNode, part: int) -> list:
    if root.root_kind == "range":
        b = G.chunk_bounds(root.data, root.nparts)
        return list(range(b[part], b[part + 1]))
    b = G.chunk_bounds(len(root.data), root.nparts)
    return list(root.data[b[part]:b[part + 1]])


def _apply_ops(ops: Sequence[_PlanNode], records: list, ns: str | None,
               part: int, start: int = 0) -> list:
    """Run the fused narrow chain; ``ns`` set => tee ``cache()``-ed
    intermediate partitions into the store as they stream past."""
    for op in ops[start:]:
        fn = op.fn
        if op.kind == "map":
            records = [fn(r) for r in records]
        elif op.kind == "filter":
            records = [r for r in records if fn(r)]
        else:                       # flatMap
            out: list = []
            for r in records:
                out.extend(fn(r))
            records = out
        if op.cached and ns is not None:
            _store_put((ns, op.uid, part), records)
    return records


def _input_records(stage: _Stage, ns: str, part: int) -> list:
    """One input partition of a stage through its fused op chain,
    restarting from the deepest ``cache()`` hit (lineage shortcut)."""
    for i in range(len(stage.ops) - 1, -1, -1):
        op = stage.ops[i]
        if op.cached:
            hit = _store_get((ns, op.uid, part))
            if hit is not None:
                return _apply_ops(stage.ops, hit, ns, part, start=i + 1)
    if stage.root is not None:
        base = None
        if stage.root.cached:
            base = _store_get((ns, stage.root.uid, part))
        if base is None:
            base = _root_records(stage.root, part)
            if stage.root.cached:
                _store_put((ns, stage.root.uid, part), base)
    else:
        base = _store_get((ns, stage.input_uid, part))
        if base is None:
            raise RuntimeError(
                f"partition {part} of boundary {stage.input_uid} is not "
                "materialized on its owner; shuffle invariant broken")
    return _apply_ops(stage.ops, base, ns, part)


def _as_pairs(records: list, how: str) -> list[tuple]:
    try:
        return [(k, v) for k, v in records]
    except (TypeError, ValueError):
        raise TypeError(
            f"{how} needs (key, value) records; got a partition whose "
            "records do not unpack into pairs") from None


def _partition_samples(pairs: list[tuple]) -> list:
    """Evenly spaced key samples from one map partition (sorted keys),
    feeding the deterministic sortByKey splitters. The sample count
    scales with partition size (one per ``_SAMPLE_EVERY`` records above
    the floor, capped): a fixed per-partition count would weight a
    10x-bigger partition the same as a tiny one in the pooled
    quantiles, which is exactly how skewed inputs used to produce
    skewed output partitions."""
    ks = sorted(k for k, _ in pairs)
    if not ks:
        return []
    want = max(_SAMPLES_PER_PART, len(ks) // _SAMPLE_EVERY)
    want = min(want, len(ks), _MAX_SAMPLES_PER_PART)
    return [ks[i * len(ks) // want] for i in range(want)]


def _splitters_from_samples(samples: list[tuple[int, list]],
                            nparts: int) -> list:
    """Range-partition splitters from ``(map partition, samples)`` pairs.
    Pure function of the sample multiset, so every rank -- and every
    execution mode -- derives the identical partitioning.

    Two skew defenses on top of plain quantiles:

    - cut positions snap to *run boundaries* (positions where the sorted
      sample key changes). ``_bucket_of`` sends a key equal to a
      splitter right, so a cut inside a run of equal keys is a no-op
      that silently merges its bucket into the next -- snapping makes
      every cut effective and walls hot keys off into their own bucket.
    - buckets still holding more than 2x the mean sample mass are
      rebalanced: the heaviest bucket is split at its interior run
      boundary nearest its middle, and the lightest adjacent pair is
      merged to keep the bucket count (bounded greedy walk; a bucket
      that is one giant run cannot be split -- equal keys are
      inseparable under range partitioning)."""
    keys = sorted(k for _, ks in samples for k in ks)
    n = len(keys)
    if not keys or nparts <= 1:
        return []
    change = [i for i in range(1, n) if keys[i] != keys[i - 1]]
    if not change:
        return []                   # one distinct key: one bucket
    cuts: list[int] = []
    for j in range(1, nparts):
        ideal = j * n // nparts
        pos = min(change, key=lambda c: (abs(c - ideal), c))
        if not cuts or pos > cuts[-1]:
            cuts.append(pos)

    def _loads(cs: list[int]) -> list[int]:
        edges = [0] + cs + [n]
        return [edges[i + 1] - edges[i] for i in range(len(edges) - 1)]

    mean = n / nparts
    for _ in range(4 * nparts):
        ld = _loads(cuts)
        heavy = max(range(len(ld)), key=ld.__getitem__)
        if ld[heavy] <= 2.0 * mean:
            break
        edges = [0] + cuts + [n]
        lo, hi = edges[heavy], edges[heavy + 1]
        mid = (lo + hi) // 2
        inner = [c for c in change if lo < c < hi]
        if not inner:
            break                   # a single run: cannot split further
        cuts = sorted(cuts + [min(inner, key=lambda c: (abs(c - mid), c))])
        if len(cuts) > nparts - 1:
            ld2 = _loads(cuts)
            drop = min(range(len(cuts)),
                       key=lambda i: (ld2[i] + ld2[i + 1], i))
            del cuts[drop]
    return [keys[c] for c in cuts]


def _bucket_of(how: str, key: Any, nparts: int, splitters: list | None,
               ascending: bool) -> int:
    if how == "sortByKey":
        idx = bisect.bisect_right(splitters, key) if splitters else 0
        return idx if ascending else nparts - 1 - idx
    return G.stable_key_hash(key) % nparts


def _map_buckets(spec: _ShuffleSpec, pairs: list[tuple],
                 needed: set[int], splitters: list | None) -> dict[int, Any]:
    """Map-side shuffle payloads for one input partition, restricted to
    the ``needed`` output partitions (lineage-driven partial shuffle).
    reduceByKey payloads are map-side-combined dicts; groupByKey payloads
    are key->values dicts; sortByKey payloads are raw record lists."""
    per: dict[int, Any] = {}
    if spec.how == "reduceByKey":
        fn = spec.fn
        for k, v in pairs:
            p = _bucket_of(spec.how, k, spec.nparts, splitters,
                           spec.ascending)
            if p not in needed:
                continue
            d = per.setdefault(p, {})
            d[k] = fn(d[k], v) if k in d else v
    elif spec.how == "groupByKey":
        for k, v in pairs:
            p = _bucket_of(spec.how, k, spec.nparts, splitters,
                           spec.ascending)
            if p not in needed:
                continue
            per.setdefault(p, {}).setdefault(k, []).append(v)
    else:                           # sortByKey
        for k, v in pairs:
            p = _bucket_of(spec.how, k, spec.nparts, splitters,
                           spec.ascending)
            if p not in needed:
                continue
            per.setdefault(p, []).append((k, v))
    return per


def _merge_payloads(spec: _ShuffleSpec, payloads: list) -> list:
    """Reduce-side merge of one output partition's payloads, already in
    ascending map-partition order -- the only order-sensitive fold in
    the system, and it is independent of world size by construction."""
    if spec.how == "reduceByKey":
        fn = spec.fn
        acc: dict = {}
        for d in payloads:
            for k, v in d.items():
                acc[k] = fn(acc[k], v) if k in acc else v
        return list(acc.items())
    if spec.how == "groupByKey":
        gac: dict = {}
        for d in payloads:
            for k, vs in d.items():
                gac.setdefault(k, []).extend(vs)
        return list(gac.items())
    recs = [r for pl in payloads for r in pl]
    recs.sort(key=operator.itemgetter(0), reverse=not spec.ascending)
    return recs


def _merge_entries(spec: _ShuffleSpec,
                   entries: list[tuple[int, int, Any]]) -> dict[int, list]:
    """(out partition, map partition, payload) entries -> merged
    partitions, folding each partition's payloads in map-partition
    order."""
    by_part: dict[int, list[tuple[int, Any]]] = {}
    for p, mp, payload in entries:
        by_part.setdefault(p, []).append((mp, payload))
    out = {}
    for p, plist in by_part.items():
        plist.sort(key=operator.itemgetter(0))
        out[p] = _merge_payloads(spec, [pl for _, pl in plist])
    return out


# ---------------------------------------------------------------------------
# The per-rank plan runner (one closure per collect)
# ---------------------------------------------------------------------------

def _shuffle_stage(comm, stage: _Stage, ns: str, rank: int, size: int,
                   lost: dict | None, stats: dict) -> None:
    """Evaluate one wide stage: rebalance surviving partitions to their
    (possibly re-homed) owners, agree on which output partitions are
    missing, then recompute exactly those via pipelined collectives."""
    spec = stage.out
    out_uid, out_np = spec.uid, spec.nparts

    # shrink_info-driven invalidation: partitions whose materialized
    # copy died with their previous-epoch owner cannot be trusted to
    # exist anywhere -- drop any local leftovers so the store reflects
    # lineage truth before the holdings exchange.
    if lost:
        doomed = G.lost_partitions(out_np, lost["dead_old_ranks"],
                                   lost["old_size"])
        _store_drop(ns, out_uid, doomed)

    owned = G.owned_partitions(rank, out_np, size)

    # 1. holdings exchange: who has which materialized output partition
    mine_have = _store_parts(ns, out_uid)
    gathered = comm.allgather(mine_have) if size > 1 else [mine_have]

    # 2. rebalance: a surviving partition whose owner moved (shrink
    #    re-homed it) is shipped, not recomputed. One uniform alltoall,
    #    skipped only when *every* rank agrees there is nothing to move.
    holder: dict[int, int] = {}
    for r in range(len(gathered) - 1, -1, -1):
        for p in gathered[r]:
            holder[p] = r
    moves = [(p, h, G.partition_owner(p, out_np, size))
             for p, h in sorted(holder.items())
             if p not in gathered[G.partition_owner(p, out_np, size)]]
    if moves and size > 1:
        chunks: list[list] = [[] for _ in range(size)]
        for p, h, o in moves:
            if h == rank:
                chunks[o].append((p, _store_get((ns, out_uid, p))))
        for src_chunk in comm.alltoall(chunks):
            for p, records in src_chunk:
                _store_put((ns, out_uid, p), records)
        stats["rebalanced"].setdefault(out_uid, []).extend(
            sorted(p for p, _, o in moves if o == rank))

    # 3. needed set: owned output partitions materialized nowhere --
    #    exactly the lineage-lost set on a post-shrink retry, all of
    #    them on a first run. Deterministic from the gathered holdings,
    #    so every rank agrees without another message.
    everywhere = set(holder)
    need_local = sorted(set(owned) - everywhere)
    needed = {p for p in range(out_np) if p not in everywhere}
    if need_local:
        stats["recomputed"].setdefault(out_uid, []).extend(need_local)
    if not needed:
        return

    owned_in = G.owned_partitions(rank, stage.in_nparts, size)
    rounds = G.shuffle_rounds(stage.in_nparts, size)

    # sortByKey needs global splitters before any bucketing: materialize
    # the map side once, sample each partition, allgather the samples.
    splitters: list | None = None
    map_cache: dict[int, list] | None = None
    if spec.how == "sortByKey":
        map_cache = {mp: _as_pairs(_input_records(stage, ns, mp), spec.how)
                     for mp in owned_in}
        samples = [(mp, _partition_samples(map_cache[mp]))
                   for mp in owned_in]
        allsamp = (comm.allgather(samples) if size > 1 else [samples])
        flat = sorted((s for lst in allsamp for s in lst),
                      key=operator.itemgetter(0))
        splitters = _splitters_from_samples(flat, out_np)

    # 4. pipelined exchange: the collective for round k is in flight
    #    while round k+1's map side computes. reduceByKey rides
    #    ireducescatter (fold = concatenation of per-rank entry lists,
    #    associative); the others ride ialltoall.
    entries: list[tuple[int, int, Any]] = []
    reqs = []
    for rnd in range(rounds):
        mp = rank + rnd * size
        per: dict[int, Any] = {}
        if mp < stage.in_nparts:
            pairs = (map_cache[mp] if map_cache is not None
                     else _as_pairs(_input_records(stage, ns, mp),
                                    spec.how))
            per = _map_buckets(spec, pairs, needed, splitters)
        chunks = [[] for _ in range(size)]
        for p, payload in per.items():
            chunks[G.partition_owner(p, out_np, size)].append(
                (p, mp, payload))
        if size == 1:
            entries.extend(chunks[0])
        elif spec.how == "reduceByKey":
            reqs.append(comm.ireducescatter(chunks, _concat))
        else:
            reqs.append(comm.ialltoall(chunks))
    for rq in reqs:
        got = rq.wait()
        if spec.how == "reduceByKey":
            entries.extend(got)         # already this rank's fold
        else:
            for src_chunk in got:
                entries.extend(src_chunk)

    # 5. reduce-side merge in map-partition order, materialize at owner
    for p, records in _merge_entries(spec, entries).items():
        _store_put((ns, out_uid, p), records)
    for p in need_local:
        if _store_get((ns, out_uid, p)) is None:
            _store_put((ns, out_uid, p), [])    # no records hashed here


def _run_plan(comm, stages: list[_Stage], ns: str,
              lost: dict | None = None) -> dict:
    """The one closure ``collect`` dispatches: every rank walks the
    stages in order, evaluating wide boundaries on collectives and
    returning its owned partitions of the final stage (plus lineage
    stats). ``comm=None`` runs the same code as the single-process
    oracle."""
    rank = comm.get_rank() if comm is not None else 0
    size = comm.get_size() if comm is not None else 1
    stats: dict = {"recomputed": {}, "rebalanced": {}, "rank": rank,
                   "size": size}
    for stage in stages:
        if stage.out is not None:
            _shuffle_stage(comm, stage, ns, rank, size, lost, stats)
            lost = None     # consumed: later boundaries derive from store
    final = stages[-1]
    parts = {mp: _input_records(final, ns, mp)
             for mp in G.owned_partitions(rank, final.in_nparts, size)}
    return {"parts": parts, "stats": stats}


# ---------------------------------------------------------------------------
# Naive driver-gather baseline: every shuffle routes all raw records
# through the driver's control plane and merges single-threaded. Same
# pure merge functions => bit-exact with the collectives path; the
# benchmark exists to show how much slower this is.
# ---------------------------------------------------------------------------

def _run_gather_map(comm, stage: _Stage, ns: str,
                    boundary: dict[int, list] | None) -> Any:
    rank = comm.get_rank() if comm is not None else 0
    size = comm.get_size() if comm is not None else 1
    out = {}
    for mp in G.owned_partitions(rank, stage.in_nparts, size):
        base = (_root_records(stage.root, mp) if stage.root is not None
                else boundary[mp])
        out[mp] = _apply_ops(stage.ops, base, None, mp)
    if stage.out is None:
        return out
    return [(mp, _as_pairs(recs, stage.out.how))
            for mp, recs in out.items()]


def _merge_gathered(spec: _ShuffleSpec,
                    raw: list[tuple[int, list]]) -> dict[int, list]:
    """Driver-side merge of the gathered raw records: bucket with the
    same splitter/hash math the executors use, then the same
    map-partition-ordered fold."""
    raw = sorted(raw, key=operator.itemgetter(0))
    splitters = None
    if spec.how == "sortByKey":
        samples = [(mp, _partition_samples(pairs)) for mp, pairs in raw]
        splitters = _splitters_from_samples(samples, spec.nparts)
    entries = []
    allparts = set(range(spec.nparts))
    for mp, pairs in raw:
        for p, payload in _map_buckets(spec, pairs, allparts,
                                       splitters).items():
            entries.append((p, mp, payload))
    return _merge_entries(spec, entries)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

class PartitionedDataset:
    """A lazy, partitioned collection of records (the paper-side RDD
    analogue). Transformations build a DAG; ``collect()`` compiles and
    runs it on the context's runtime. See ``docs/dataset.md``."""

    def __init__(self, ctx: "DataContext", node: _PlanNode):
        self._ctx = ctx
        self._node = node

    # -- narrow transformations (fused, no data movement) -------------------
    def _narrow(self, kind: str, fn: Callable) -> "PartitionedDataset":
        node = _PlanNode(kind, f"n{next(_UID_SEQ)}", self._node,
                         self._node.nparts, fn=fn)
        return PartitionedDataset(self._ctx, node)

    def map(self, fn: Callable) -> "PartitionedDataset":
        """Record-wise transform."""
        return self._narrow("map", fn)

    def filter(self, fn: Callable) -> "PartitionedDataset":
        """Keep records where ``fn(record)`` is truthy."""
        return self._narrow("filter", fn)

    def flatMap(self, fn: Callable) -> "PartitionedDataset":    # noqa: N802
        """Record -> iterable of records, flattened."""
        return self._narrow("flatMap", fn)

    # -- wide transformations (shuffle on collectives) ----------------------
    def _wide(self, how: str, fn: Callable | None, nparts: int | None,
              ascending: bool = True) -> "PartitionedDataset":
        np_ = self._node.nparts if nparts is None else int(nparts)
        if np_ < 1:
            raise ValueError(f"need at least one partition, got {np_}")
        node = _PlanNode("shuffle", f"n{next(_UID_SEQ)}", self._node, np_,
                         fn=fn, how=how, ascending=ascending)
        return PartitionedDataset(self._ctx, node)

    def reduceByKey(self, fn: Callable,                         # noqa: N802
                    nparts: int | None = None) -> "PartitionedDataset":
        """Combine (key, value) records per key with associative ``fn``;
        map-side combining runs before any byte moves."""
        return self._wide("reduceByKey", fn, nparts)

    def groupByKey(self,                                        # noqa: N802
                   nparts: int | None = None) -> "PartitionedDataset":
        """(key, value) records -> (key, [values]) in deterministic
        (map-partition, record) order."""
        return self._wide("groupByKey", None, nparts)

    def sortByKey(self, ascending: bool = True,                 # noqa: N802
                  nparts: int | None = None) -> "PartitionedDataset":
        """Globally sort (key, value) records via deterministic sampled
        range partitioning; ties keep their pre-sort order."""
        return self._wide("sortByKey", None, nparts, ascending=ascending)

    # -- persistence / actions ----------------------------------------------
    def cache(self) -> "PartitionedDataset":
        """Materialize this dataset's partitions in executor memory on
        first evaluation; later collects (and lineage recoveries) start
        from the cached copies instead of recomputing upstream."""
        self._node.cached = True
        return self

    @property
    def nparts(self) -> int:
        return self._node.nparts

    def lineage(self) -> list[dict]:
        """Root-to-here plan description -- uids here match the
        ``recomputed`` / ``rebalanced`` stats on ``ctx.last_stats``."""
        chain = []
        n: _PlanNode | None = self._node
        while n is not None:
            chain.append({"uid": n.uid, "kind": n.kind,
                          "how": n.how, "nparts": n.nparts,
                          "cached": n.cached})
            n = n.parent
        return list(reversed(chain))

    def collect(self, shuffle: str = "collectives") -> list:
        """Evaluate the DAG and return every record, partitions
        concatenated in order. ``shuffle="gather"`` selects the naive
        driver-relay baseline (benchmarks only; no lineage recovery)."""
        if shuffle not in ("collectives", "gather"):
            raise ValueError(f"unknown shuffle mode {shuffle!r}")
        stages = _compile(self._node)
        if shuffle == "gather":
            parts = self._ctx._collect_gather(stages)
        else:
            parts = self._ctx._collect_collectives(stages)
        out: list = []
        for p in range(stages[-1].in_nparts):
            out.extend(parts.get(p, []))
        return out

    def count(self) -> int:
        return len(self.collect())

    def take(self, n: int) -> list:
        """First ``n`` records. Narrow-only plans (no shuffle boundary)
        evaluate partitions incrementally on the driver and stop as soon
        as ``n`` records are ready -- partitions past the cutoff are
        never computed. Plans with a wide boundary (whose first output
        record depends on every input record anyway) fall back to the
        full ``collect``."""
        if n <= 0:
            return []
        self._ctx._check_open()
        stages = _compile(self._node)
        stage = stages[0]
        if len(stages) == 1 and stage.root is not None:
            out: list = []
            for part in range(stage.in_nparts):
                recs = _apply_ops(stage.ops,
                                  _root_records(stage.root, part),
                                  None, part)
                out.extend(recs)
                if len(out) >= n:
                    break
            return out[:n]
        return self.collect()[:n]

    def first(self) -> Any:
        """The first record (streaming, via :meth:`take`); raises
        ``ValueError`` on an empty dataset."""
        got = self.take(1)
        if not got:
            raise ValueError("first() on an empty dataset")
        return got[0]


class DataContext:
    """Owns the world a dataset evaluates on: ``mode`` is ``"single"``
    (in-process oracle), ``"local"`` (threads), or ``"cluster"``
    (pooled executor processes with shrink-to-survivors lineage
    recovery). Usable as a context manager; ``close()`` releases the
    pool and this context's cached partitions."""

    def __init__(self, n: int = 2, mode: str = "local", *,
                 backend: str = "ring", timeout: float = 60.0,
                 max_restarts: int = 4, min_ranks: int = 1,
                 pool: Any = None, hb_interval: float = 0.1,
                 hb_timeout: float = 2.0):
        if mode not in ("single", "local", "cluster"):
            raise ValueError(
                f"unknown mode {mode!r}; expected single|local|cluster")
        if n < 1:
            raise ValueError("need at least one rank")
        self.n = int(n)
        self.mode = mode
        self.backend = backend
        self.timeout = timeout
        self.max_restarts = max_restarts
        self.min_ranks = min_ranks
        self.hb_interval = hb_interval
        self.hb_timeout = hb_timeout
        self._ns = f"ds{os.getpid():x}.{next(_CTX_SEQ)}"
        self._pool = pool
        self._pool_external = pool is not None
        self._sup = None
        self._closed = False
        #: lineage stats of the most recent collectives collect:
        #: {"recomputed": {uid: [parts]}, "rebalanced": {...},
        #:  "shrinks": int, "world_size": int}
        self.last_stats: dict | None = None

    # -- plumbing -----------------------------------------------------------
    def __enter__(self) -> "DataContext":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        _store_drop(self._ns)
        if self._pool is not None and not self._pool_external:
            self._pool.shutdown()
        self._pool = None

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("DataContext is closed")

    def _ensure_pool(self):
        from ..core.cluster import ExecutorPool
        if self._pool is None:
            self._pool = ExecutorPool(
                self.n, backend=self.backend, timeout=self.timeout,
                hb_interval=self.hb_interval, hb_timeout=self.hb_timeout)
        return self._pool

    # -- dataset constructors -----------------------------------------------
    def parallelize(self, data: Sequence,
                    nparts: int | None = None) -> PartitionedDataset:
        """Slice a driver-side sequence into ``nparts`` partitions
        (default: the context's world size)."""
        self._check_open()
        np_ = self.n if nparts is None else int(nparts)
        if np_ < 1:
            raise ValueError(f"need at least one partition, got {np_}")
        node = _PlanNode("root", f"n{next(_UID_SEQ)}", None, np_,
                         root_kind="data", data=list(data))
        return PartitionedDataset(self, node)

    def range(self, stop: int,
              nparts: int | None = None) -> PartitionedDataset:
        """``range(stop)`` as a dataset. The root is regenerated
        executor-side from the bounds alone -- nothing ships from the
        driver -- which is the right base for synthetic/ETL pipelines."""
        self._check_open()
        np_ = self.n if nparts is None else int(nparts)
        if np_ < 1:
            raise ValueError(f"need at least one partition, got {np_}")
        node = _PlanNode("root", f"n{next(_UID_SEQ)}", None, np_,
                         root_kind="range", data=int(stop))
        return PartitionedDataset(self, node)

    # -- execution ----------------------------------------------------------
    def _collect_collectives(self, stages: list[_Stage]) -> dict[int, list]:
        self._check_open()
        ns = self._ns
        if self.mode == "single":
            res = _run_plan(None, stages, ns)
            self.last_stats = {**res["stats"], "shrinks": 0,
                               "world_size": 1}
            return res["parts"]
        if self.mode == "local":
            from ..core.local import ParallelFuncRDD
            closure = lambda comm: _run_plan(comm, stages, ns)  # noqa: E731
            outs = ParallelFuncRDD(closure, timeout=self.timeout,
                                   backend=self.backend).execute(self.n)
            return self._fold_outs(outs, shrinks=0)
        return self._collect_cluster(stages)

    def _collect_cluster(self, stages: list[_Stage]) -> dict[int, list]:
        from ..core.cluster import ClusterSupervisor
        from ..train import ft
        pool = self._ensure_pool()
        if self._sup is None:
            self._sup = ClusterSupervisor(
                ckpt_dir=os.path.join(
                    tempfile.gettempdir(), f"mpignite-{self._ns}-ckpt"),
                policy=ft.RecoveryPolicy(max_restarts=self.max_restarts),
                fast_backend=self.backend, timeout=self.timeout,
                elastic=True, min_ranks=self.min_ranks)
        ns = self._ns
        shrinks0 = self._sup.state.shrinks

        def make_job(run_ctx):
            lost = None
            if run_ctx.shrink_info is not None:
                info = run_ctx.shrink_info
                lost = {"dead_old_ranks": list(info["dead_old_ranks"]),
                        "old_size": info["old_size"]}
            return lambda comm: _run_plan(comm, stages, ns, lost=lost)

        outs = self._sup.run_job(make_job, pool, timeout=self.timeout)
        return self._fold_outs(outs,
                               shrinks=self._sup.state.shrinks - shrinks0)

    def _fold_outs(self, outs: list, shrinks: int) -> dict[int, list]:
        parts: dict[int, list] = {}
        stats = {"recomputed": {}, "rebalanced": {}}
        for res in outs:
            parts.update(res["parts"])
            for kind in ("recomputed", "rebalanced"):
                for uid, ps in res["stats"][kind].items():
                    stats[kind].setdefault(uid, []).extend(ps)
        for kind in ("recomputed", "rebalanced"):
            stats[kind] = {uid: sorted(ps)
                           for uid, ps in stats[kind].items()}
        stats["shrinks"] = shrinks
        stats["world_size"] = len(outs)
        self.last_stats = stats
        return parts

    def _execute_gather(self, closure: Callable) -> list:
        if self.mode == "single":
            return [closure(None)]
        if self.mode == "local":
            from ..core.local import ParallelFuncRDD
            return ParallelFuncRDD(closure, timeout=self.timeout,
                                   backend=self.backend).execute(self.n)
        return self._ensure_pool().run(closure, timeout=self.timeout)

    def _collect_gather(self, stages: list[_Stage]) -> dict[int, list]:
        self._check_open()
        ns = self._ns
        boundary: dict[int, list] | None = None
        for stage in stages:
            st, cap = stage, boundary

            def closure(comm, st=st, cap=cap):
                return _run_gather_map(comm, st, ns, cap)

            outs = self._execute_gather(closure)
            if stage.out is None:
                parts: dict[int, list] = {}
                for out in outs:
                    parts.update(out)
                return parts
            raw = [entry for out in outs for entry in out]
            boundary = _merge_gathered(stage.out, raw)
            for p in range(stage.out.nparts):
                boundary.setdefault(p, [])
        raise AssertionError("unreachable: compile always emits a final "
                             "stage")

    def clear_cache(self) -> None:
        """Drop every partition this context materialized (all ranks +
        driver); the next collect recomputes from the roots."""
        self._check_open()
        ns = self._ns
        _store_drop(ns)
        if self.mode == "cluster" and self._pool is not None:
            self._pool.run(lambda comm: _store_drop(ns),
                           timeout=self.timeout)

"""Deterministic, restart-safe data pipeline.

Design-for-1000-nodes property (DESIGN.md section 8): the pipeline is
*stateless by global step* -- batch(step) is a pure function of
(seed, step), so restart/elastic-rescale never needs pipeline state in
the checkpoint, and any host can compute any shard's slice. Sources:

- ``SyntheticTokens``: Philox-keyed synthetic stream (benchmarks, tests).
- ``MemmapTokens``: fixed binary token file, block-shuffled by step.

``Prefetcher`` overlaps host batch assembly with device compute, and
``batch_shards`` re-expresses the whole pipeline as a
``data.dataset.PartitionedDataset`` so training-data prep shares the
shuffle/lineage runtime with ETL and eval sweeps.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable

import numpy as np

from ..models.common import ModelConfig


class SyntheticTokens:
    def __init__(self, vocab: int, seq: int, global_batch: int,
                 seed: int = 0):
        self.vocab, self.seq, self.gb, self.seed = vocab, seq, global_batch, seed

    def batch(self, step: int) -> np.ndarray:
        rng = np.random.Generator(np.random.Philox(key=[self.seed, step]))
        return rng.integers(0, self.vocab, (self.gb, self.seq),
                            dtype=np.int32)


class MemmapTokens:
    """Token stream from a flat binary file of int32 tokens."""

    def __init__(self, path: str, vocab: int, seq: int, global_batch: int,
                 seed: int = 0):
        self.arr = np.memmap(path, dtype=np.int32, mode="r")
        self.vocab, self.seq, self.gb, self.seed = vocab, seq, global_batch, seed
        self.n_windows = len(self.arr) // (seq + 1)
        if self.n_windows < global_batch:
            raise ValueError("token file too small for one batch")

    def batch(self, step: int) -> np.ndarray:
        rng = np.random.Generator(np.random.Philox(key=[self.seed, step]))
        idx = rng.choice(self.n_windows, self.gb, replace=False)
        out = np.empty((self.gb, self.seq), np.int32)
        for i, w in enumerate(idx):
            out[i] = self.arr[w * (self.seq + 1): w * (self.seq + 1) + self.seq]
        return np.clip(out, 0, self.vocab - 1)


def make_batch(cfg: ModelConfig, source, step: int) -> dict:
    """Assemble the model-specific batch dict for one step."""
    rng = np.random.Generator(np.random.Philox(key=[7, step]))
    tokens = source.batch(step)
    B, S = tokens.shape
    if cfg.input_mode == "frames":
        return {"frames": rng.standard_normal((B, S, cfg.d_model))
                .astype(np.float32) * 0.02,
                "labels": tokens}
    batch = {"tokens": tokens}
    if cfg.cross_attn_every:
        batch["image_emb"] = rng.standard_normal(
            (B, cfg.n_image_tokens, cfg.vision_d)).astype(np.float32) * 0.02
    return batch


def batch_shards(ctx, cfg: ModelConfig, source, steps: int,
                 nparts: int | None = None, start_step: int = 1):
    """The tokenized training shards as a ``PartitionedDataset`` of
    ``(step, batch_dict)`` records over ``DataContext`` ``ctx``.

    Because every source is *stateless by step* (``batch(step)`` is a
    pure function of ``(seed, step)``), the dataset's root is nothing
    but the step ids: each rank assembles its own shard locally, no
    batch bytes ship from the driver, and a shard partition lost to
    rank death recomputes from the step range alone -- lineage recovery
    for free. Downstream ``filter``/``map``/``groupByKey`` stages turn
    the same object into ETL or eval-sweep inputs.

    Note: ``MemmapTokens`` pickles by materializing its array; prefer
    opening the memmap inside a ``map`` closure (or use
    ``SyntheticTokens``) for cluster-mode shards."""
    ds = ctx.parallelize(list(range(start_step, start_step + steps)),
                         nparts)
    return ds.map(lambda step: (step, make_batch(cfg, source, step)))


class Prefetcher:
    """Host-side prefetch: compute batch(step+1..step+depth) on a thread."""

    def __init__(self, fn: Callable[[int], dict], start_step: int,
                 depth: int = 2):
        self.fn = fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.next_step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self.next_step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.fn(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def get(self) -> tuple[int, dict]:
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)

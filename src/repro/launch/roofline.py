"""Roofline table from dry-run artifacts (EXPERIMENTS.md section Roofline).

Per (arch x shape) cell on the single-pod mesh:
  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip          [s]
  memory     = HLO_bytes_per_device / HBM_bandwidth                [s]
  collective = collective_wire_bytes_per_device / ICI_link_bw      [s]
(The artifact quantities are per-device; dividing per-device work by
per-chip rates is identical to the assignment's global/(chips*rate).)

Terms are *structural* estimates from the compiled 512-way SPMD program on
the CPU backend (same partitioner, no TPU codegen) -- stated prominently
in EXPERIMENTS.md. The dominant term is the bottleneck the perf loop
(section Perf) iterates on; MODEL_FLOPS/HLO_FLOPs flags padding, remat
recompute and causal-masking waste.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HW = {
    "peak_flops": 197e12,     # TPU v5e bf16 per chip
    "hbm_bw": 819e9,          # B/s per chip
    "ici_bw": 50e9,           # B/s per link
}


def terms(art: dict) -> dict:
    nd = art["n_devices"]
    flops_dev = art["hlo"]["flops"]
    # fused-executor model is the TPU-realistic memory estimate; the
    # CPU-fusion-granularity figure is kept as an upper bound.
    mem_dev = art["hlo"].get("mem_bytes_fused") or art["hlo"]["mem_bytes"]
    coll_dev = art["hlo"]["coll_wire_bytes"]
    t_c = flops_dev / HW["peak_flops"]
    t_m = mem_dev / HW["hbm_bw"]
    t_x = coll_dev / HW["ici_bw"]
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    bound = max(t_c, t_m, t_x)
    mf = art["model_flops"]
    ratio = mf / (flops_dev * nd) if flops_dev else 0.0
    # roofline fraction: useful model flops vs what the bottleneck permits
    frac = (mf / nd / HW["peak_flops"]) / bound if bound else 0.0
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "memory_upper_s": art["hlo"]["mem_bytes"] / HW["hbm_bw"],
            "bottleneck": dom, "bound_s": bound,
            "model_flops_ratio": ratio, "roofline_fraction": frac}


MOVE_NOTE = {
    "compute": "cut non-model FLOPs: remat policy, causal block skipping "
               "(Pallas flash kernel), head-padding waste",
    "memory": "fuse / shrink materialized intermediates; larger per-step "
              "arithmetic intensity (bigger blocks, fused attention)",
    "collective": "resharding: fewer/smaller collectives, sequence-parallel "
                  "instead of allreduce, overlap via native backend",
}


def load_artifacts(out_dir: str, mesh: str = "single") -> list[dict]:
    arts = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            a = json.load(f)
        if a.get("mesh") == mesh:
            a["_file"] = os.path.basename(p)
            arts.append(a)
    return arts


def table(arts: list[dict], fmt: str = "md") -> str:
    rows = []
    for a in arts:
        if a.get("skip"):
            rows.append({"arch": a["arch"], "shape": a["shape"],
                         "skip": a["skip"]})
            continue
        t = terms(a)
        rows.append({
            "arch": a["arch"], "shape": a["shape"],
            "path": f'{a["path"]}/{a["backend"]}',
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "bottleneck": t["bottleneck"],
            "mf_ratio": t["model_flops_ratio"],
            "roofline_frac": t["roofline_fraction"],
            "hbm_gib": a["memory"]["peak_bytes_est"] / 2 ** 30,
            "skip": None})
    if fmt == "csv":
        hdr = ("arch,shape,path,compute_s,memory_s,collective_s,"
               "bottleneck,model_flops_ratio,roofline_frac,hbm_gib")
        lines = [hdr]
        for r in rows:
            if r.get("skip"):
                lines.append(f'{r["arch"]},{r["shape"]},SKIP({r["skip"]})')
            else:
                lines.append(
                    f'{r["arch"]},{r["shape"]},{r["path"]},'
                    f'{r["compute_s"]:.4e},{r["memory_s"]:.4e},'
                    f'{r["collective_s"]:.4e},{r["bottleneck"]},'
                    f'{r["mf_ratio"]:.3f},{r["roofline_frac"]:.3f},'
                    f'{r["hbm_gib"]:.2f}')
        return "\n".join(lines)
    # markdown
    lines = ["| arch | shape | path | compute s | memory s | collective s |"
             " bottleneck | 6ND/HLO | roofline frac | HBM GiB |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skip"):
            lines.append(f'| {r["arch"]} | {r["shape"]} | — | — | — | — | '
                         f'SKIP: {r["skip"]} | — | — | — |')
        else:
            lines.append(
                f'| {r["arch"]} | {r["shape"]} | {r["path"]} | '
                f'{r["compute_s"]:.3e} | {r["memory_s"]:.3e} | '
                f'{r["collective_s"]:.3e} | **{r["bottleneck"]}** | '
                f'{r["mf_ratio"]:.3f} | {r["roofline_frac"]:.3f} | '
                f'{r["hbm_gib"]:.2f} |')
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--fmt", choices=["md", "csv"], default="md")
    args = ap.parse_args(argv)
    arts = load_artifacts(args.artifacts, args.mesh)
    print(table(arts, args.fmt))
    for a in arts:
        if a.get("skip"):
            continue
        t = terms(a)
        print(f'\n{a["arch"]} x {a["shape"]}: bottleneck={t["bottleneck"]}'
              f' -> {MOVE_NOTE[t["bottleneck"]]}')
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())

"""Fault-tolerant training driver.

Runs the step loop under a supervisor implementing the paper's recovery
story (DESIGN.md / train/ft.py): on (injected) node failure, restore the
latest checkpoint and rebuild the train step with the *degraded*
master-relay comm backend (paper phase-1 "linear"), run a recovery
window, then swap back to the fast backend -- demonstrating the comm-mode
degrade <-> restore cycle end to end. Stragglers are detected with an
EWMA step-time monitor.

CPU-scale by default (smoke configs); the same driver lowers unchanged
onto the production mesh when more devices exist.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
from jax.sharding import NamedSharding

from ..configs import get_config
from ..core import compat
from ..data.pipeline import SyntheticTokens, make_batch
from ..models.model import Model
from ..parallel import axes as A
from ..parallel.ops import ParallelConfig
from ..train import checkpoint as CKPT
from ..train import ft
from ..train.optim import OptConfig, Optimizer
from ..train.step import init_opt_state, make_train_step


def build(cfg, mesh, pcfg, opt_cfg, global_batch):
    axes = A.MeshAxes.from_mesh(mesh)
    model = Model(cfg, axes, pcfg)
    opt = Optimizer(opt_cfg)
    step, ps = make_train_step(model, opt, mesh, global_batch)
    return model, opt, step, ps


def shard_tree(tree, mesh, pspecs):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, pspecs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--parallel-path", dest="path", default="mpignite")
    ap.add_argument("--backend", default="native")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--recovery-steps", type=int, default=5)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    n_dev = args.data * args.model_par
    if n_dev > len(jax.devices()):
        raise SystemExit(f"need {n_dev} devices, have {len(jax.devices())} "
                         "(set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    from .mesh import make_test_mesh
    mesh = make_test_mesh(data=args.data, model=args.model_par)
    pcfg = ParallelConfig(path=args.path, backend=args.backend,
                          sequence_parallel=args.model_par > 1,
                          remat="block")
    opt_cfg = OptConfig(lr_peak=args.lr, warmup_steps=5,
                        total_steps=args.steps)
    policy = ft.RecoveryPolicy(recovery_steps=args.recovery_steps)
    injector = ft.FailureInjector(frozenset(args.fail_at))
    detector = ft.StragglerDetector()
    sup = ft.SupervisorState()

    model, opt, step_fn, ps = build(cfg, mesh, pcfg, opt_cfg,
                                    args.global_batch)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(model, opt, params)
    start = 0
    if args.resume and CKPT.latest_step(args.ckpt_dir) is not None:
        flat, meta, start = CKPT.load(args.ckpt_dir)
        params = CKPT.restore_sharded(params, flat_sub(flat, "params"),
                                      mesh, ps["params"])
        opt_state = CKPT.restore_sharded(opt_state, flat_sub(flat, "opt"),
                                         mesh, ps["opt"])
        print(f"[train] resumed from step {start}")
    params = shard_tree(params, mesh, ps["params"])
    opt_state = shard_tree(opt_state, mesh, ps["opt"])

    source = SyntheticTokens(cfg.vocab, args.seq, args.global_batch,
                             args.seed)
    ckpter = CKPT.AsyncCheckpointer(args.ckpt_dir)
    cur_backend = args.backend
    step = start
    losses = []
    while step < args.steps:
        try:
            batch = make_batch(cfg, source, step)
            batch = {k: jax.device_put(v, NamedSharding(
                mesh, model.batch_specs(args.global_batch, args.seq)[1][k]))
                for k, v in batch.items()}
            injector.check(step)
            t0 = time.time()
            with compat.set_mesh(mesh):
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
            dt = time.time() - t0
            if detector.observe(step, dt):
                sup.straggler_events += 1
                print(f"[ft] straggler at step {step}: {dt:.2f}s vs "
                      f"ewma {detector.ewma:.2f}s", flush=True)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['gnorm']):.3f} "
                      f"backend={cur_backend} {dt*1000:.0f}ms", flush=True)
            step += 1
            if step % args.ckpt_every == 0:
                ckpter.submit(step, {"params": params, "opt": opt_state},
                              {"arch": cfg.name})
            # restore fast backend after the recovery window
            want = sup.backend_for(step, args.backend, policy)
            if want != cur_backend:
                print(f"[ft] backend {cur_backend} -> {want}", flush=True)
                cur_backend = want
                pcfg2 = pcfg.replace(backend=want)
                model, opt, step_fn, ps = build(cfg, mesh, pcfg2, opt_cfg,
                                                args.global_batch)
        except ft.SimulatedFailure as e:
            print(f"[ft] {e}; restoring + degrading comm to "
                  f"{policy.degrade_backend}", flush=True)
            cur_backend = sup.on_failure(step, policy)
            pcfg2 = pcfg.replace(backend=cur_backend)
            model, opt, step_fn, ps = build(cfg, mesh, pcfg2, opt_cfg,
                                            args.global_batch)
            last = CKPT.latest_step(args.ckpt_dir)
            if last is not None:
                flat, _, step = CKPT.load(args.ckpt_dir)
                params = CKPT.restore_sharded(
                    model.init(jax.random.PRNGKey(args.seed)),
                    flat_sub(flat, "params"), mesh, ps["params"])
                opt_state = CKPT.restore_sharded(
                    init_opt_state(model, opt, params),
                    flat_sub(flat, "opt"), mesh, ps["opt"])
                print(f"[ft] restored step {step}", flush=True)
            else:
                print("[ft] no checkpoint yet; restarting from init",
                      flush=True)
                params = shard_tree(model.init(
                    jax.random.PRNGKey(args.seed)), mesh, ps["params"])
                opt_state = shard_tree(init_opt_state(model, opt, params),
                                       mesh, ps["opt"])
                step = 0
    ckpter.finish()
    print(f"[train] done: {len(losses)} steps, loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}, restarts={sup.restarts}, "
          f"stragglers={sup.straggler_events}")
    return 0


def flat_sub(flat: dict, prefix: str) -> dict:
    pl = prefix + CKPT.SEP
    return {k[len(pl):]: v for k, v in flat.items() if k.startswith(pl)}


if __name__ == "__main__":
    sys.exit(main())

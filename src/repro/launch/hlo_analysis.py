"""Optimized-HLO cost analysis with loop trip-count accounting.

XLA's built-in ``compiled.cost_analysis()`` visits every instruction once
-- a ``while`` body (how lax.scan lowers the layer stack) is counted for a
*single* iteration. This module re-derives the three roofline inputs from
``compiled.as_text()`` with multiplicities:

- FLOPs: ``dot`` ops cost 2 * prod(result) * contracted_size; everything
  else is approximated at 1 flop/element of its result (dots dominate all
  ten architectures).
- Collective wire bytes per device, converted per op type from operand
  bytes and the replica-group size parsed from the op.
- Memory bytes: a *fusion-boundary* HBM traffic model -- each top-level
  executed instruction (including fusions, whose internals stay in
  registers/VMEM) reads its operands and writes its result once, with two
  in-loop refinements: a fusion operand consumed only through
  ``dynamic-slice`` is charged at slice size (a scan body reads one layer
  of the stacked weights, not all L); a buffer that is updated in place by
  ``dynamic-update-slice`` is charged at update size (XLA aliases the
  carry). Aliasing ops (copy/bitcast/tuple/get-tuple-element) are skipped:
  XLA:CPU materializes loop-carried copies a TPU would alias away.

bf16 normalization: the CPU backend has no native bf16 and legalizes all
bf16 compute to f32, doubling every byte count relative to the TPU-target
program. With ``norm_float_bytes=2`` (the dry-run default), floating
dtypes are counted at min(native, 2) bytes. This restores the intended
bf16 sizes exactly for activations/params/grads/collectives and
*undercounts* the (genuinely fp32) optimizer-state traffic 2x -- a ~1%
effect, stated in EXPERIMENTS.md.

While multipliers come from the ``known_trip_count`` backend_config that
XLA attaches after loop analysis (verified emitted by the CPU backend);
a while without one counts once. All quantities are per-device (the SPMD
program is identical everywhere); multiply by chip count for totals.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
INSTR_RE = re.compile(
    r"^\s+(ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.+?\)?)\s+([\w\-]+)\(")
TRIP_RE = re.compile(r'known_trip_count\\?":\s*\{\\?"n\\?":\\?"(\d+)')
CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[\d,\s]*?(?:\},\{[\d,\s]*?)*\}\}|\[[\d,]+\]<=\[[\d,]*\])")
CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# collective opcodes sometimes print with suffixes (-start/-done)
COLL_CANON = {}
for c in COLLECTIVES:
    COLL_CANON[c] = c
    COLL_CANON[c + "-start"] = c


FLOAT_DTYPES = {"f64", "f32", "bf16", "f16"}


def shape_bytes(shape_str: str, norm_float: int = 0) -> int:
    total = 0
    for m in SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = DTYPE_BYTES[dt]
        if norm_float and dt in FLOAT_DTYPES:
            b = min(b, norm_float)
        total += n * b
    return total


def shape_elems(shape_str: str) -> int:
    total = 0
    for m in SHAPE_RE.finditer(shape_str):
        if m.group(1) not in DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n
    return total


def shape_dims(shape_str: str) -> list[int]:
    m = SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _operand_section(line: str, opcode: str) -> str:
    i = line.index(opcode + "(") + len(opcode)
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[i + 1:j]
    return line[i + 1:]


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    shape_str: str
    operands: list[str]
    attrs: str
    calls: list[str]
    trip: int
    is_root: bool = False


def parse_computations(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    for line in text.splitlines():
        if not line.startswith(" ") and "->" in line and \
                line.rstrip().endswith("{"):
            tok = line.split()
            name = tok[1] if tok[0] == "ENTRY" else tok[0]
            comps[name.lstrip("%")] = cur = []
            continue
        if cur is None:
            continue
        mi = INSTR_RE.match(line)
        if mi is None:
            continue
        root, name, shape_str, opcode = mi.groups()
        ops_text = _operand_section(line, opcode)
        operands = OPERAND_RE.findall(ops_text)
        calls, trip = [], 1
        if opcode == "while":
            mb = BODY_RE.search(line)
            if mb:
                calls.append(mb.group(1))
            mt = TRIP_RE.search(line)
            trip = int(mt.group(1)) if mt else 1
        elif opcode == "conditional":
            mb = BRANCHES_RE.search(line)
            if mb:
                calls += [c.strip().lstrip("%") for c in mb.group(1).split(",")]
        elif opcode in ("fusion", "call"):
            mc = CALLS_RE.search(line)
            if mc:
                calls.append(mc.group(1))
        cur.append(Instr(name, opcode, shape_str, operands, line, calls,
                         trip, is_root=bool(root)))
    return comps


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    mem_bytes: float = 0.0        # CPU-fusion-granularity (upper bound)
    mem_bytes_fused: float = 0.0  # ideal-fusion model: dots/colls/DUS/params
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))

    @property
    def coll_wire_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    def as_dict(self) -> dict:
        return {"flops": self.flops, "mem_bytes": self.mem_bytes,
                "mem_bytes_fused": self.mem_bytes_fused,
                "coll_wire_bytes": self.coll_wire_bytes,
                "coll_bytes": dict(self.coll_bytes),
                "coll_count": dict(self.coll_count)}


def _group_size(attrs: str, default: int) -> int:
    m = GROUPS_RE.search(attrs)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return len([x for x in first.split(",") if x.strip() != ""])
    dims = g[1:g.index("]")].split(",")
    return int(dims[-1]) if len(dims) >= 2 else default


def wire_bytes(op: str, operand_bytes: int, result_bytes: int,
               p: int) -> float:
    """Bytes each device puts on ICI links for one collective (ring)."""
    if p <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * operand_bytes * (p - 1) / p
    if op == "all-gather":
        return result_bytes * (p - 1) / p
    if op == "reduce-scatter":
        return operand_bytes * (p - 1) / p
    if op == "all-to-all":
        return operand_bytes * (p - 1) / p
    if op == "collective-permute":
        return float(operand_bytes)
    return 0.0


_SKIP_MEM = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "copy", "while", "conditional", "call",
             "after-all", "add-dependency"}
_ZERO_FLOP = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "copy", "while", "conditional", "call", "fusion",
              "broadcast", "reshape", "transpose", "slice", "concatenate",
              "dynamic-slice", "dynamic-update-slice", "iota", "pad",
              "reverse", "after-all", "add-dependency", "gather", "scatter",
              "rng-bit-generator"}


def _fusion_mem(body: list[Instr], table: dict, operand_shapes: list[str],
                norm: int) -> float:
    """Fusion-boundary traffic with dynamic-slice / in-place-DUS awareness.

    Reads: body parameter i (bound to operand_shapes[i]) is charged at
    (a) 0 if it is a buffer updated in place by a dynamic-update-slice,
    (b) the sum of its dynamic-slice results if only read through slices,
    (c) full size otherwise.
    Writes: update sizes of DUS roots, else the root result size.
    """
    consumers: dict[str, list[Instr]] = defaultdict(list)
    params: dict[str, int] = {}
    for ins in body:
        if ins.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.attrs)
            params[ins.name] = int(m.group(1)) if m else len(params)
        for o in ins.operands:
            consumers[o].append(ins)

    dus_list = [i for i in body if i.opcode == "dynamic-update-slice"]
    dus_buffers = set()
    for d in dus_list:
        if d.operands:
            # walk through bitcast/copy chains back to a parameter
            src = d.operands[0]
            seen = 0
            while src not in params and seen < 4:
                producers = [i for i in body if i.name == src]
                if producers and producers[0].opcode in ("bitcast", "copy") \
                        and producers[0].operands:
                    src = producers[0].operands[0]
                    seen += 1
                else:
                    break
            if src in params:
                dus_buffers.add(src)

    read = 0.0
    for pname, pidx in params.items():
        if pname in dus_buffers:
            continue                      # aliased in place
        cons = consumers.get(pname, [])
        through = []
        only_slices = bool(cons)
        for c in cons:
            if c.opcode in ("bitcast", "copy"):
                c2 = consumers.get(c.name, [])
                through.extend(c2)
            else:
                through.append(c)
        only_slices = bool(through) and all(
            t.opcode == "dynamic-slice" for t in through)
        full = shape_bytes(operand_shapes[pidx], norm) \
            if pidx < len(operand_shapes) else 0
        if only_slices:
            read += min(sum(shape_bytes(t.shape_str, norm)
                            for t in through), full)
        else:
            read += full

    if dus_list:
        write = sum(shape_bytes(table.get(d.operands[1], ""), norm)
                    if len(d.operands) > 1 else 0 for d in dus_list)
    else:
        roots = [i for i in body if i.is_root]
        write = shape_bytes(roots[-1].shape_str, norm) if roots else 0
    return read + write


def summarize(text: str, n_devices: int,
              norm_float_bytes: int = 2) -> CostSummary:
    comps = parse_computations(text)
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    entry = m.group(1) if m else next(iter(comps))
    norm = norm_float_bytes

    tables = {name: {i.name: i.shape_str for i in instrs}
              for name, instrs in comps.items()}

    memo: dict[tuple, CostSummary] = {}

    def flops_of(name: str) -> float:
        """FLOPs of a computation, recursing into every call."""
        key = ("f", name)
        if key in memo:
            return memo[key]
        memo[key] = 0.0
        total = 0.0
        table = tables.get(name, {})
        for ins in comps.get(name, []):
            mult = ins.trip
            res_e = shape_elems(ins.shape_str)
            if ins.opcode == "dot":
                mcon = CONTRACT_RE.search(ins.attrs)
                contracted = 1
                if mcon and ins.operands:
                    lhs_dims = shape_dims(table.get(ins.operands[0], ""))
                    for ci in mcon.group(1).split(","):
                        if ci != "" and int(ci) < len(lhs_dims):
                            contracted *= lhs_dims[int(ci)]
                total += 2.0 * res_e * contracted * mult
            elif ins.calls:
                total += sum(flops_of(c) for c in ins.calls) * mult
            elif ins.opcode not in _ZERO_FLOP:
                total += float(res_e) * mult
        memo[key] = total
        return total

    def cost_of(name: str) -> CostSummary:
        key = ("c", name)
        if key in memo:
            return memo[key]
        memo[key] = CostSummary()
        total = CostSummary()
        table = tables.get(name, {})
        for ins in comps.get(name, []):
            mult = ins.trip
            res_b = shape_bytes(ins.shape_str, norm)
            op_b = sum(shape_bytes(table.get(o, ""), norm)
                       for o in ins.operands)
            opc = COLL_CANON.get(ins.opcode, ins.opcode)
            if opc in COLLECTIVES:
                p = _group_size(ins.attrs, n_devices)
                total.coll_bytes[opc] += wire_bytes(opc, op_b, res_b, p) * mult
                total.coll_count[opc] += mult
                total.mem_bytes += (op_b + res_b) * mult
            elif ins.opcode == "fusion":
                total.flops += sum(flops_of(c) for c in ins.calls) * mult
                body = comps.get(ins.calls[0], []) if ins.calls else []
                operand_shapes = [table.get(o, "") for o in ins.operands]
                total.mem_bytes += _fusion_mem(
                    body, tables.get(ins.calls[0], {}), operand_shapes,
                    norm) * mult
            elif ins.calls:   # while / conditional / call
                for c in ins.calls:
                    sub = cost_of(c)
                    total.flops += sub.flops * mult
                    total.mem_bytes += sub.mem_bytes * mult
                    for k, v in sub.coll_bytes.items():
                        total.coll_bytes[k] += v * mult
                    for k, v in sub.coll_count.items():
                        total.coll_count[k] += v * mult
            elif ins.opcode == "dot":
                mcon = CONTRACT_RE.search(ins.attrs)
                contracted = 1
                if mcon and ins.operands:
                    lhs_dims = shape_dims(table.get(ins.operands[0], ""))
                    for ci in mcon.group(1).split(","):
                        if ci != "" and int(ci) < len(lhs_dims):
                            contracted *= lhs_dims[int(ci)]
                total.flops += 2.0 * res_e_of(ins) * contracted * mult
                total.mem_bytes += (op_b + res_b) * mult
            else:
                if ins.opcode not in _ZERO_FLOP:
                    total.flops += float(res_e_of(ins)) * mult
                if ins.opcode not in _SKIP_MEM:
                    total.mem_bytes += (op_b + res_b) * mult
        memo[key] = total
        return total

    def res_e_of(ins: Instr) -> int:
        return shape_elems(ins.shape_str)

    def fused_mem_of(name: str) -> float:
        """Ideal-fusion HBM traffic: dots, collectives, and in-place
        updates only -- every elementwise op assumed fused away (what the
        TPU backend actually does). Recurses into fusion bodies so dots
        fused with epilogues still count."""
        key = ("fm", name)
        if key in memo:
            return memo[key]
        memo[key] = 0.0
        total = 0.0
        table = tables.get(name, {})
        for ins in comps.get(name, []):
            mult = ins.trip
            opc = COLL_CANON.get(ins.opcode, ins.opcode)
            if ins.opcode == "dot":
                op_b = sum(shape_bytes(table.get(o, ""), norm)
                           for o in ins.operands)
                total += (op_b + shape_bytes(ins.shape_str, norm)) * mult
            elif opc in COLLECTIVES:
                op_b = sum(shape_bytes(table.get(o, ""), norm)
                           for o in ins.operands)
                total += (op_b + shape_bytes(ins.shape_str, norm)) * mult
            elif ins.opcode == "dynamic-update-slice":
                if len(ins.operands) > 1:
                    total += 2 * shape_bytes(
                        table.get(ins.operands[1], ""), norm) * mult
            for c in ins.calls:
                total += fused_mem_of(c) * mult
        memo[key] = total
        return total

    out = cost_of(entry)
    param_bytes = sum(shape_bytes(i.shape_str, norm)
                      for i in comps.get(entry, [])
                      if i.opcode == "parameter")
    out.mem_bytes_fused = fused_mem_of(entry) + param_bytes
    return out


def collective_schedule(text: str, n_devices: int,
                        norm_float_bytes: int = 2) -> list[dict]:
    """Flat list of collectives with multiplicity (for EXPERIMENTS.md)."""
    comps = parse_computations(text)
    tables = {name: {i.name: i.shape_str for i in instrs}
              for name, instrs in comps.items()}
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    entry = m.group(1) if m else next(iter(comps))
    out: list[dict] = []
    norm = norm_float_bytes

    def walk(name: str, mult: int):
        table = tables.get(name, {})
        for ins in comps.get(name, []):
            opc = COLL_CANON.get(ins.opcode, ins.opcode)
            if opc in COLLECTIVES:
                op_b = sum(shape_bytes(table.get(o, ""), norm)
                           for o in ins.operands)
                res_b = shape_bytes(ins.shape_str, norm)
                p = _group_size(ins.attrs, n_devices)
                out.append({"op": opc, "operand_bytes": op_b,
                            "result_bytes": res_b, "group": p,
                            "times": mult,
                            "wire_bytes": wire_bytes(opc, op_b, res_b, p)
                            * mult})
            for c in ins.calls:
                walk(c, mult * ins.trip)
    walk(entry, 1)
    return out

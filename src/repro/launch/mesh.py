"""Production meshes. Functions, not module constants: importing this
module never touches jax device state (the dry-run sets the fake device
count before any jax initialization)."""
from __future__ import annotations

import jax


def _mk(shape, names):
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(names)
        return jax.make_mesh(shape, names, axis_types=axis_types)
    except (TypeError, AttributeError):  # older jax: no AxisType kwarg/enum
        return jax.make_mesh(shape, names)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 x 16 x 16 = 512 chips (pod, data, model) -- `pod` is
    pure cross-pod data parallelism over DCN/ICI-superpod links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, pod: int = 1):
    """Small mesh for CPU tests (requires forced host device count)."""
    if pod > 1:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def mesh_axes_of(mesh):
    from ..parallel import axes as A
    return A.MeshAxes.from_mesh(mesh)

"""Serving driver: run the continuous-batching engine against a config.

CPU-scale by default (smoke configs); on a real mesh the same driver
builds sharded prefill/decode steps (resident-weight layout,
``fsdp=False``) via train.step.make_*_step.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.model import Model
from ..parallel import axes as A
from ..parallel.ops import ParallelConfig, make_ops
from ..serve.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(get_config(args.arch, smoke=args.smoke),
                              dtype=jnp.float32)
    axes = A.MeshAxes(1, 1, 1)
    pcfg = ParallelConfig(sequence_parallel=False, remat="none",
                          fsdp=False)   # resident-weight serving layout
    model = Model(cfg, axes, pcfg)
    params = model.init(jax.random.PRNGKey(args.seed), dtype=jnp.float32)
    ops = make_ops(axes, pcfg)

    prefill_fn = jax.jit(lambda p, b: model.prefill(ops, p, b,
                                                    s_max=args.s_max))
    decode_fn = jax.jit(lambda p, c, t, pos: model.decode(ops, p, c, t,
                                                          pos))
    eng = Engine(model, params, prefill_fn, decode_fn,
                 max_slots=args.slots, s_max=args.s_max)

    rng = np.random.default_rng(args.seed)
    uids = [eng.submit(rng.integers(0, cfg.vocab, 4 + i % 7)
                       .astype(np.int32), max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = eng.run()
    dt = time.time() - t0
    for uid in uids:
        print(f"req {uid}: {out[uid]}")
    s = eng.stats
    occ = float(np.mean(s.batch_occupancy)) if s.batch_occupancy else 0.0
    print(f"\n{s.tokens_out} tokens in {dt:.2f}s "
          f"({s.tokens_out/dt:.1f} tok/s), {s.prefills} prefills, "
          f"{s.decode_steps} decode steps, mean occupancy "
          f"{occ:.2f}/{args.slots}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

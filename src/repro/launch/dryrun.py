import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import/initialization: jax locks the device count
# on first backend init; the dry-run (and only the dry-run) runs with 512
# placeholder host devices so the production meshes can be built.

import argparse          # noqa: E402
import gzip              # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from ..configs import SHAPES, get_config, skip_reason, cell_plan  # noqa: E402
from ..core.comm import cost_log                                  # noqa: E402
from ..core import compat                                         # noqa: E402
from ..models.model import Model                                  # noqa: E402
from ..parallel import axes as A                                  # noqa: E402
from ..parallel.ops import ParallelConfig                         # noqa: E402
from ..train.optim import OptConfig, Optimizer                    # noqa: E402
from ..train.step import (init_opt_state, make_decode_step,       # noqa: E402
                          make_prefill_step, make_train_step)
from . import hlo_analysis as H                                   # noqa: E402
from .mesh import make_production_mesh                            # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline inputs from the compiled artifact. No arrays are ever
allocated (ShapeDtypeStruct end to end); `memory_analysis()` proves the
program fits 16 GB/chip and `cost_analysis()` + the trip-count-aware HLO
parser (hlo_analysis.py) provide FLOPs/bytes/collective terms.

One cell per process (the --all driver spawns subprocesses): XLA compile
state for 512-way SPMD programs is large, and process isolation makes the
sweep resumable (existing artifact => skipped)."""


def _sds_with(tree_sds, tree_ps, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        tree_sds, tree_ps)


def opt_for(arch: str, lean: bool = False) -> Optimizer:
    # arctic-480b: Adam state (2 fp32 moments) would need ~7.5 GB/chip on
    # top of master+grads at 256 chips; Adafactor's factored stats fit.
    # ``lean`` additionally drops the fp32 master (T5X-style bf16 train).
    name = "adafactor" if arch == "arctic-480b" else "adamw"
    return Optimizer(OptConfig(name=name, master=not lean))


def build_lowerable(arch: str, shape_name: str, mesh, path: str,
                    backend: str, remat: str = "full",
                    seq_override: int | None = None,
                    compression: str = "none", microbatches: int = 1,
                    quant_gather: bool = False, fsdp: bool = True,
                    lean_opt: bool = False):
    """Returns (lower_fn, meta). lower_fn() -> lowered."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    axes = A.MeshAxes.from_mesh(mesh)
    pcfg = ParallelConfig(path=path, backend=backend,
                          sequence_parallel=(shape.step != "decode"),
                          remat=remat, grad_compression=compression,
                          microbatches=microbatches, fsdp=fsdp,
                          microbatch_dtype="bfloat16" if lean_opt
                          else "float32",
                          weight_gather_quant="int8" if quant_gather
                          else "none")
    model = Model(cfg, axes, pcfg)
    seq = seq_override or shape.seq_len
    gb = shape.global_batch

    params_sds = _sds_with(model.param_shapes(),
                           model.pspecs, mesh)

    if shape.step == "train":
        opt = opt_for(arch, lean=lean_opt)
        step, ps = make_train_step(model, opt, mesh, gb,
                                   use_compression=(compression == "int8"))
        opt_sds_raw = jax.eval_shape(
            lambda p: init_opt_state(model, opt, p, compression == "int8"),
            params_sds)
        opt_sds = _sds_with(opt_sds_raw, ps["opt"], mesh)
        batch_raw, batch_ps = model.batch_specs(gb, seq)
        batch_sds = _sds_with(batch_raw, batch_ps, mesh)
        tokens = gb * seq

        def lower():
            return step.lower(params_sds, opt_sds, batch_sds)
        mf = model.model_flops(tokens, train=True)
    elif shape.step == "prefill":
        step = make_prefill_step(model, mesh, gb, s_max=seq)
        batch_raw, batch_ps = model.batch_specs(gb, seq)
        batch_sds = _sds_with(batch_raw, batch_ps, mesh)

        def lower():
            return step.lower(params_sds, batch_sds)
        mf = model.model_flops(gb * seq, train=False)
    else:  # decode
        step = make_decode_step(model, mesh, gb, s_max=seq)
        from ..models.common import tree_shapes, tree_pspecs
        cache_specs = model.cache_specs(gb, seq)
        # per-leaf dtypes come from the specs (KV bf16, recurrent states f32)
        cache_sds = _sds_with(tree_shapes(cache_specs, axes),
                              tree_pspecs(cache_specs), mesh)
        bsp = model._bspec(gb)
        from jax.sharding import PartitionSpec as P
        tok_sds = jax.ShapeDtypeStruct((gb, 1), jnp.int32,
                                       sharding=NamedSharding(mesh, P(bsp, None)))
        pos_sds = jax.ShapeDtypeStruct((gb,), jnp.int32,
                                       sharding=NamedSharding(mesh, P(bsp)))

        def lower():
            return step.lower(params_sds, cache_sds, tok_sds, pos_sds)
        mf = model.model_flops(gb, train=False)

    meta = {"arch": arch, "shape": shape_name, "step": shape.step,
            "path": path, "backend": backend, "remat": remat,
            "seq": seq, "global_batch": gb,
            "n_devices": axes.n_devices,
            "n_params": model.n_params(),
            "n_params_active": model.n_params(active_only=True),
            "model_flops": mf}
    return lower, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, path: str,
             backend: str, out_path: str, remat: str = "full",
             save_hlo: bool = False, compression: str = "none",
             mesh_shape: str = "", microbatches: int = 1,
             quant_gather: bool = False, fsdp: bool = True,
             lean_opt: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = skip_reason(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    if skip:
        art = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skip": skip}
        _write(out_path, art)
        return art
    if mesh_shape:
        dims = tuple(int(x) for x in mesh_shape.split(","))
        names = ("pod", "data", "model")[-len(dims):]
        from .mesh import _mk
        mesh = _mk(dims, names)
        mesh_name = "custom" + mesh_shape.replace(",", "x")
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    lower_fn, meta = build_lowerable(arch, shape_name, mesh, path, backend,
                                     remat, compression=compression,
                                     microbatches=microbatches,
                                     quant_gather=quant_gather, fsdp=fsdp,
                                     lean_opt=lean_opt)
    t0 = time.time()
    with cost_log() as clog:
        with compat.set_mesh(mesh):
            lowered = lower_fn()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    ndev = meta["n_devices"]
    summary = H.summarize(txt, ndev)
    sched = H.collective_schedule(txt, ndev)
    sched.sort(key=lambda r: -r["wire_bytes"])

    analytic = {}
    for rec in clog:
        k = f"{rec.op}:{rec.backend}"
        analytic[k] = analytic.get(k, 0) + rec.bytes_per_device

    art = {
        **meta, "mesh": mesh_name, "skip": None,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "xla_cost": {"flops_static": ca.get("flops", -1.0),
                     "bytes_static": ca.get("bytes accessed", -1.0)},
        "hlo": summary.as_dict(),
        "collective_schedule_top": sched[:40],
        "analytic_comm_bytes": analytic,
        "hlo_text_bytes": len(txt),
    }
    _write(out_path, art)
    if save_hlo:
        with gzip.open(out_path.replace(".json", ".hlo.txt.gz"), "wt") as f:
            f.write(txt)
    return art


def _write(path: str, art: dict):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(art, f, indent=1)


def artifact_name(arch, shape, mesh_name, path, backend, remat="full",
                  compression="none", extra: str = ""):
    tag = f"{arch}__{shape}__{mesh_name}__{path}__{backend}"
    if remat != "full":
        tag += f"__remat-{remat}"
    if compression != "none":
        tag += f"__comp-{compression}"
    if extra:
        tag += f"__{extra}"
    return tag + ".json"


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--parallel-path", dest="path",
                    choices=["mpignite", "gspmd"], default="mpignite")
    ap.add_argument("--backend", default="native",
                    choices=["native", "ring", "linear"])
    ap.add_argument("--remat", default="full",
                    choices=["none", "block", "full"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--all", action="store_true",
                    help="run the full cell matrix in subprocesses")
    ap.add_argument("--timeout", type=float, default=2400)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true")
    # ---- perf-iteration knobs (section Perf of EXPERIMENTS.md) ----
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh dims, e.g. 256,1 (data,model)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant-gather", action="store_true",
                    help="ZeRO++-style int8 FSDP weight all-gathers")
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false",
                    help="resident weights (serving layout)")
    ap.add_argument("--lean-opt", action="store_true",
                    help="master-less Adafactor + bf16 grad accumulation")
    args = ap.parse_args(argv)

    if args.all:
        return _run_all(args)
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    extra = []
    if args.mesh_shape:
        extra.append("mesh" + args.mesh_shape.replace(",", "x"))
    if args.microbatches > 1:
        extra.append(f"mb{args.microbatches}")
    if args.quant_gather:
        extra.append("wgq8")
    if not args.fsdp:
        extra.append("nofsdp")
    if args.lean_opt:
        extra.append("lean")
    for mesh_name in meshes:
        out_path = os.path.join(args.out, artifact_name(
            args.arch, args.shape, mesh_name, args.path, args.backend,
            args.remat, args.compression, "-".join(extra)))
        art = run_cell(args.arch, args.shape, mesh_name == "multi",
                       args.path, args.backend, out_path, args.remat,
                       args.save_hlo, args.compression, args.mesh_shape,
                       args.microbatches, args.quant_gather, args.fsdp,
                       args.lean_opt)
        status = f"SKIP({art['skip']})" if art.get("skip") else \
            f"ok compile={art['compile_s']}s " \
            f"mem={art['memory']['peak_bytes_est']/2**30:.2f}GiB"
        print(f"[dryrun] {args.arch} x {args.shape} x {mesh_name} "
              f"x {args.path}/{args.backend}: {status}", flush=True)
    return 0


def _run_all(args) -> int:
    cells = cell_plan()
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    failures = []
    for cell in cells:
        for mesh_name in meshes:
            out_path = os.path.join(args.out, artifact_name(
                cell["arch"], cell["shape"], mesh_name, args.path,
                args.backend, args.remat, args.compression))
            if os.path.exists(out_path) and not args.force:
                print(f"[dryrun] resume-skip {out_path}", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", cell["arch"], "--shape", cell["shape"],
                   "--mesh", mesh_name, "--parallel-path", args.path,
                   "--backend", args.backend, "--remat", args.remat,
                   "--compression", args.compression, "--out", args.out]
            t0 = time.time()
            try:
                r = subprocess.run(cmd, timeout=args.timeout,
                                   capture_output=True, text=True)
                ok = r.returncode == 0
                if not ok:
                    failures.append((cell, mesh_name,
                                     r.stderr.strip()[-2000:]))
                print(f"[all] {cell['arch']} x {cell['shape']} x "
                      f"{mesh_name}: {'OK' if ok else 'FAIL'} "
                      f"({time.time()-t0:.0f}s)", flush=True)
            except subprocess.TimeoutExpired:
                failures.append((cell, mesh_name, "timeout"))
                print(f"[all] {cell['arch']} x {cell['shape']} x "
                      f"{mesh_name}: TIMEOUT", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for cell, mesh_name, err in failures:
            print(f"--- {cell['arch']} x {cell['shape']} x {mesh_name}\n"
                  f"{err}\n")
        return 1
    print("all cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Mesh-axis vocabulary + padding rules shared by both distribution paths.

Axis names are fixed across the framework:

- ``pod``   : cross-pod data parallelism (multi-pod meshes only).
- ``data``  : in-pod axis used for batch DP *and* FSDP parameter sharding
              (ZeRO-3: the FSDP dim of every weight is sharded here).
- ``model`` : tensor/expert parallelism (Megatron-style TP; MoE experts and
              the vocab dimension also live here).

Hardware-alignment padding (recorded in DESIGN.md; the MODEL_FLOPS/HLO_FLOPs
ratio in the roofline table surfaces the waste these introduce):

- attention heads are padded up to a multiple of the TP degree
  (e.g. arctic-480b 56 -> 64 query heads on a 16-way model axis);
- KV heads are *replicated* up to the TP degree when kv < tp
  (qwen3: 8 kv heads on 16 shards => each head stored twice);
- the vocabulary is padded to a multiple of ``VOCAB_ALIGN * tp``.
"""
from __future__ import annotations

import dataclasses
import math

from jax.sharding import PartitionSpec as P

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"

VOCAB_ALIGN = 32  # vocab padded to a multiple of tp * VOCAB_ALIGN


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Static description of the mesh a program is being built for."""
    data: int = 1
    model: int = 1
    pod: int = 1

    @property
    def dp_total(self) -> int:
        return self.data * self.pod

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pod

    @property
    def batch_axes(self) -> tuple[str, ...]:
        """Mesh axes a global-batch dimension is sharded over."""
        return (POD_AXIS, DATA_AXIS) if self.pod > 1 else (DATA_AXIS,)

    @staticmethod
    def from_mesh(mesh) -> "MeshAxes":
        shape = dict(mesh.shape)
        return MeshAxes(data=shape.get(DATA_AXIS, 1),
                        model=shape.get(MODEL_AXIS, 1),
                        pod=shape.get(POD_AXIS, 1))


def pad_to(n: int, mult: int) -> int:
    return (n + mult - 1) // mult * mult


def padded_heads(n_heads: int, tp: int) -> int:
    """Query heads padded so each model shard holds an equal head count."""
    return pad_to(n_heads, tp)


def replicated_kv_heads(n_kv: int, tp: int) -> int:
    """Effective stored KV heads: replicate each KV head ceil(tp/n_kv) times
    when tp > n_kv so the cache shards evenly; otherwise pad to tp multiple."""
    if n_kv >= tp:
        return pad_to(n_kv, tp)
    rep = math.ceil(tp / n_kv)
    return pad_to(n_kv * rep, tp)


def padded_vocab(vocab: int, tp: int) -> int:
    return pad_to(vocab, VOCAB_ALIGN * tp)


def batch_spec(axes: MeshAxes, *trailing) -> P:
    """PartitionSpec for a tensor whose leading dim is the global batch."""
    if axes.pod > 1:
        return P((POD_AXIS, DATA_AXIS), *trailing)
    return P(DATA_AXIS, *trailing)


def divisible(n: int, d: int, what: str) -> int:
    if n % d:
        raise ValueError(f"{what}={n} not divisible by {d}")
    return n


def local_dim(size: int, spec_entry, axes: MeshAxes) -> int:
    """Size of one shard of a dimension sharded per ``spec_entry``."""
    if spec_entry is None:
        return size
    names = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    denom = 1
    for name in names:
        denom *= {POD_AXIS: axes.pod, DATA_AXIS: axes.data,
                  MODEL_AXIS: axes.model}[name]
    return divisible(size, denom, "sharded dim")


def local_shape(shape: tuple[int, ...], spec: P, axes: MeshAxes
                ) -> tuple[int, ...]:
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    return tuple(local_dim(s, e, axes) for s, e in zip(shape, entries))

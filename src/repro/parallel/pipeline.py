"""GPipe-style pipeline parallelism on PeerComm.shift (paper's ring p2p).

Each mesh device along the ``pipe`` axis owns one contiguous stage of
layers; microbatches flow through the ring with one `comm.shift`
(= `lax.ppermute`, ICI collective-permute) per tick. The classic SPMD
formulation: T = M + S - 1 ticks, device s computes microbatch (t - s)
at tick t; bubbles are masked compute. Backward falls out of autodiff —
the transpose of `shift(+1)` is `shift(-1)`, so `jax.grad` through the
loop *is* the backward pipeline schedule.

This realizes the PP row of DESIGN.md section 3 with the same primitive
the paper's ring listing uses (Listing 2), scaled from a token to
activation tensors.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..core.comm import PeerComm, cost_scope


def gpipe(comm: PeerComm, stage_fn: Callable, stage_params, mbs,
          n_stages: int):
    """Run ``stage_fn(stage_params, x)`` as a pipeline.

    comm        : PeerComm over the `pipe` axis (size == n_stages).
    stage_fn    : (params_of_this_stage, x) -> y, shape-preserving.
    stage_params: this device's stage parameters (already sharded by the
                  caller via shard_map in_specs).
    mbs         : (M, ...) microbatch inputs, replicated on every stage
                  (only stage 0 reads them).
    Returns (M, ...) outputs, valid on the *last* stage (zeros elsewhere);
    callers typically follow with a broadcast or compute loss in place.
    """
    M = mbs.shape[0]
    rank = comm.rank()
    ticks = M + n_stages - 1
    state = jnp.zeros_like(mbs[0])
    outs = jnp.zeros_like(mbs)

    def tick(carry, t):
        state, outs = carry
        # stage 0 injects microbatch t (when one is due); other stages
        # consume what arrived from the previous stage last tick.
        inj = lax.dynamic_index_in_dim(mbs, jnp.clip(t, 0, M - 1), 0,
                                       keepdims=False)
        x = jnp.where(rank == 0, jnp.where(t < M, inj, jnp.zeros_like(inj)),
                      state)
        y = stage_fn(stage_params, x)
        # last stage banks microbatch (t - (S-1)) when valid
        out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
        bank = (rank == n_stages - 1) & (t >= n_stages - 1)
        outs = lax.cond(
            bank,
            lambda o: lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
            lambda o: o, outs)
        # rotate activations to the next stage
        state = comm.shift(y, 1)
        return (state, outs), None

    with cost_scope(ticks):
        (_, outs), _ = lax.scan(tick, (state, outs), jnp.arange(ticks))
    return outs


def stack_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-major view
    for sharding the leading dim over the `pipe` axis."""
    def leaf(p):
        L = p.shape[0]
        assert L % n_stages == 0, "layers must divide stages"
        return p.reshape((n_stages, L // n_stages) + p.shape[1:])
    return jax.tree.map(leaf, layer_params)

from . import axes
from .ops import GlobalOps, Ops, ParallelConfig, ShardOps, make_ops

__all__ = ["axes", "GlobalOps", "Ops", "ParallelConfig", "ShardOps",
           "make_ops"]

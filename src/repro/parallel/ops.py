"""The two distribution paths behind one model-code interface.

Model code is written once against ``Ops``; the path is selected by
``ParallelConfig.path``:

- ``ShardOps`` ("mpignite" path): the program is a ``shard_map`` body and
  every distributed movement is an *explicit* ``PeerComm`` call -- the
  paper's model, with its ``linear`` (phase-1 master relay), ``ring``
  (phase-2 peer-to-peer) and ``native`` (beyond-paper XLA collectives)
  backends all available per communicator.

- ``GlobalOps`` ("gspmd" path): the same model code runs on global arrays
  under ``jit``; collective insertion is delegated to the XLA SPMD
  partitioner via sharding constraints. This is the beyond-paper ceiling
  reference for the §Perf comparison.

Shape contract: under ``ShardOps`` every tensor a model function touches is
the *local shard*; under ``GlobalOps`` it is the full array. All head/ffn
counts therefore flow through ``ops.local_*`` helpers instead of config
fields.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.comm import PeerComm
from . import axes as A


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """User-facing knobs for the distribution layer."""
    path: str = "mpignite"            # "mpignite" | "gspmd"
    backend: str = "native"           # PeerComm backend (mpignite path)
    pod_backend: str | None = None    # override for cross-pod traffic
    sequence_parallel: bool = True    # keep activations seq-sharded between blocks
    fsdp: bool = True                 # ZeRO-3 parameter sharding over `data`
    remat: str = "block"              # "none" | "block" | "full"
    grad_compression: str = "none"    # "none" | "int8" (cross-pod allreduce)
    weight_gather_quant: str = "none" # "none" | "int8" (ZeRO++-style qwZ:
                                      # FSDP all-gathers move int8 + scales)
    microbatches: int = 1             # grad-accumulation chunks per step
    microbatch_dtype: str = "float32" # accumulator dtype ("bfloat16" halves
                                      # the grad buffer; lean-memory mode)
    scan_layers: bool = True          # lax.scan over stacked layer params

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)


class Ops:
    """Abstract distribution interface (see module docstring)."""

    axes: A.MeshAxes
    pcfg: ParallelConfig

    # ---- static sizes ----------------------------------------------------
    @property
    def tp(self) -> int:
        return self.axes.model

    @property
    def dp(self) -> int:
        return self.axes.dp_total

    def local_heads(self, n_padded: int) -> int:
        raise NotImplementedError

    def local_experts(self, n_experts: int) -> int:
        raise NotImplementedError

    # ---- weights ----------------------------------------------------------
    def weight(self, w: jax.Array, spec: P) -> jax.Array:
        """Materialize a weight for compute: gather FSDP (`data`) dims,
        keep TP (`model`) dims as-is."""
        raise NotImplementedError

    # ---- activation collectives (model/TP axis) ---------------------------
    def tp_psum(self, x):
        raise NotImplementedError

    def tp_reduce_scatter(self, x, dim: int):
        raise NotImplementedError

    def tp_all_gather(self, x, dim: int):
        raise NotImplementedError

    def tp_all_to_all(self, x, split_dim: int, concat_dim: int):
        raise NotImplementedError

    def tp_psum_scalar(self, x):
        """psum for scalars/small stats on the model axis."""
        raise NotImplementedError

    def dp_mean_scalar(self, x):
        """Mean over the full data-parallel extent (data [+ pod])."""
        raise NotImplementedError

    def tp_index(self):
        """This shard's model-axis index (0 under GlobalOps)."""
        raise NotImplementedError

    # ---- layout hints ------------------------------------------------------
    def constrain(self, x, spec: P):
        """Sharding hint; identity under ShardOps (layout already explicit)."""
        return x

    def seq_shard(self, x, dim: int = 1):
        """Sequence-parallel transition: scatter the sequence dim over
        `model` (no-op when sequence_parallel is off)."""
        raise NotImplementedError

    def seq_unshard(self, x, dim: int = 1):
        raise NotImplementedError

    def seq_slice(self, x, dim: int = 1):
        """Like seq_shard but for *replicated-computed* full tensors:
        take this shard's slice (no reduction)."""
        raise NotImplementedError


class ShardOps(Ops):
    """Explicit-communication path built on the paper's PeerComm."""

    def __init__(self, axes: A.MeshAxes, pcfg: ParallelConfig):
        self.axes = axes
        self.pcfg = pcfg
        be = pcfg.backend
        self.comm_model = PeerComm.world(A.MODEL_AXIS, axes.model, backend=be)
        self.comm_data = PeerComm.world(A.DATA_AXIS, axes.data, backend=be)
        self.comm_pod = (PeerComm.world(A.POD_AXIS, axes.pod,
                                        backend=pcfg.pod_backend or be)
                         if axes.pod > 1 else None)

    # ---- static sizes ----------------------------------------------------
    def local_heads(self, n_padded: int) -> int:
        return A.divisible(n_padded, self.tp, "padded heads") // self.tp

    def local_experts(self, n_experts: int) -> int:
        return A.divisible(n_experts, self.tp, "experts") // self.tp

    # ---- weights ----------------------------------------------------------
    def weight(self, w, spec: P):
        if not self.pcfg.fsdp:
            return w
        entries = tuple(spec) + (None,) * (w.ndim - len(spec))
        for dim, entry in enumerate(entries):
            names = entry if isinstance(entry, tuple) else (entry,)
            if A.DATA_AXIS in names and self.axes.data > 1:
                if self.pcfg.weight_gather_quant == "int8" and \
                        jnp.issubdtype(w.dtype, jnp.floating):
                    w = self._quantized_gather(w, dim)
                else:
                    w = self.comm_data.allgather(w, axis=dim, tiled=True)
        return w

    def _quantized_gather(self, w, dim: int):
        """ZeRO++-style quantized weight gather (qwZ, arXiv:2306.10209):
        the forward FSDP all-gather moves int8 payloads + one bf16 scale
        per sharded row -- half the bf16 wire bytes -- while the backward
        pass reduce-scatters cotangents exactly (the transpose of a full-
        precision gather), so only forward weights carry the ~0.4% RMS
        quantization error."""
        comm = self.comm_data
        dt = w.dtype

        @jax.custom_vjp
        def qgather(w):
            return _fwd(w)[0]

        def _fwd(w):
            shard = w.shape[dim]
            scale = jnp.max(jnp.abs(w), axis=dim, keepdims=True) / 127.0 \
                + 1e-12
            q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
            qg = comm.allgather(q, axis=dim, tiled=True)
            sg = comm.allgather(scale.astype(jnp.bfloat16), axis=dim,
                                tiled=True)          # one scale per shard
            sg = jnp.repeat(sg, shard, axis=dim)     # broadcast per block
            out = (qg.astype(jnp.float32) * sg.astype(jnp.float32)
                   ).astype(dt)
            return out, None

        def _bwd(_, g):
            return (comm.reducescatter(g, axis=dim),)

        qgather.defvjp(_fwd, _bwd)
        return qgather(w)

    # ---- activation collectives -------------------------------------------
    def tp_psum(self, x):
        return self.comm_model.allreduce(x) if self.tp > 1 else x

    def tp_reduce_scatter(self, x, dim: int):
        return (self.comm_model.reducescatter(x, axis=dim)
                if self.tp > 1 else x)

    def tp_all_gather(self, x, dim: int):
        return (self.comm_model.allgather(x, axis=dim, tiled=True)
                if self.tp > 1 else x)

    def tp_all_to_all(self, x, split_dim: int, concat_dim: int):
        return (self.comm_model.alltoall(x, split_axis=split_dim,
                                         concat_axis=concat_dim)
                if self.tp > 1 else x)

    def tp_psum_scalar(self, x):
        return self.tp_psum(x)

    def dp_mean_scalar(self, x):
        if self.axes.data > 1:
            x = self.comm_data.allreduce(x)
        if self.comm_pod is not None:
            x = self.comm_pod.allreduce(x)
        return x / self.dp

    def tp_index(self):
        return lax.axis_index(A.MODEL_AXIS) if self.tp > 1 else jnp.int32(0)

    # ---- layout -------------------------------------------------------------
    def seq_shard(self, x, dim: int = 1):
        if self.pcfg.sequence_parallel and self.tp > 1:
            return self.tp_reduce_scatter(x, dim)
        return self.tp_psum(x)

    def seq_unshard(self, x, dim: int = 1):
        if self.pcfg.sequence_parallel and self.tp > 1:
            return self.tp_all_gather(x, dim)
        return x

    def seq_slice(self, x, dim: int = 1):
        if self.pcfg.sequence_parallel and self.tp > 1:
            c = x.shape[dim] // self.tp
            return jax.lax.dynamic_slice_in_dim(x, self.tp_index() * c, c,
                                                axis=dim)
        return x

    # ---- gradient sync (called by the train step after jax.grad) ------------
    def sync_grads(self, grads, specs, compress=None, ef=None):
        """Reduce gradients across every mesh axis *absent* from a param's
        spec. FSDP dims are already reduce-scattered by the transpose of the
        just-in-time all-gather; what remains is (a) the TP group for
        replicated params (norms, routers) and (b) the cross-pod replicas.
        ``compress(comm, g, ef_leaf) -> (g, ef_new)`` optionally wraps the
        cross-pod allreduce (int8 + error feedback -- train/compress.py).
        Returns (grads, ef_new_or_None). All reductions are sums: the loss
        already carries the 1/dp_total factor, so summed shard losses
        telescope to the global mean."""
        leaves_g, tdef = jax.tree.flatten(grads)
        leaves_s = tdef.flatten_up_to(specs)
        leaves_e = (tdef.flatten_up_to(ef) if ef is not None
                    else [None] * len(leaves_g))
        out_g, out_e = [], []
        for g, spec, e in zip(leaves_g, leaves_s, leaves_e):
            entries = tuple(spec) + (None,) * (g.ndim - len(spec))
            flat = [n for ent in entries if ent is not None
                    for n in (ent if isinstance(ent, tuple) else (ent,))]
            if A.MODEL_AXIS not in flat and self.tp > 1:
                g = self.comm_model.allreduce(g)
            if A.DATA_AXIS not in flat and self.axes.data > 1:
                g = self.comm_data.allreduce(g)
            if self.comm_pod is not None:
                if compress is not None:
                    g, e = compress(self.comm_pod, g, e)
                else:
                    g = self.comm_pod.allreduce(g)
            out_g.append(g)
            out_e.append(e)
        grads = jax.tree.unflatten(tdef, out_g)
        ef_new = jax.tree.unflatten(tdef, out_e) if ef is not None else None
        return grads, ef_new


class GlobalOps(Ops):
    """GSPMD path: global arrays + sharding constraints, XLA partitions."""

    def __init__(self, axes: A.MeshAxes, pcfg: ParallelConfig):
        self.axes = axes
        self.pcfg = pcfg

    def local_heads(self, n_padded: int) -> int:
        return n_padded

    def local_experts(self, n_experts: int) -> int:
        return n_experts

    def weight(self, w, spec: P):
        return w

    def tp_psum(self, x):
        return x

    def tp_reduce_scatter(self, x, dim: int):
        return x

    def tp_all_gather(self, x, dim: int):
        return x

    def tp_all_to_all(self, x, split_dim: int, concat_dim: int):
        return x

    def tp_psum_scalar(self, x):
        return x

    def dp_mean_scalar(self, x):
        return x

    def tp_index(self):
        return jnp.int32(0)

    def constrain(self, x, spec: P):
        if self.axes.n_devices > 1:
            return lax.with_sharding_constraint(x, spec)
        return x

    def seq_shard(self, x, dim: int = 1):
        if self.pcfg.sequence_parallel and self.tp > 1:
            spec = [None] * x.ndim
            spec[0] = (A.POD_AXIS, A.DATA_AXIS) if self.axes.pod > 1 else A.DATA_AXIS
            spec[dim] = A.MODEL_AXIS
            return self.constrain(x, P(*spec))
        return x

    def seq_unshard(self, x, dim: int = 1):
        return x

    def seq_slice(self, x, dim: int = 1):
        return x

    def sync_grads(self, grads, specs, compress=None, ef=None):
        # GSPMD reduces via partitioning of the global graph
        return grads, (ef if ef is not None else None)


def make_ops(axes: A.MeshAxes, pcfg: ParallelConfig) -> Ops:
    if pcfg.path == "mpignite":
        return ShardOps(axes, pcfg)
    if pcfg.path == "gspmd":
        return GlobalOps(axes, pcfg)
    raise ValueError(f"unknown parallel path {pcfg.path!r}")


# ---------------------------------------------------------------------------
# Remat policies applied to the per-layer body inside the layer scan.
# ---------------------------------------------------------------------------

def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "block":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(f"unknown remat policy {policy!r}")

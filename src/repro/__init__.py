"""MPIgnite-JAX: MPI-style peer/collective communication as a first-class
layer of a multi-pod JAX training & serving framework.

See README.md / DESIGN.md. Public surface:

- ``repro.core``      -- the paper's contribution (communicators, closures)
- ``repro.models``    -- the 10 assigned architectures behind one Model
- ``repro.parallel``  -- ShardOps/GlobalOps distribution paths
- ``repro.train``     -- optimizers, steps, checkpointing, fault tolerance
- ``repro.serve``     -- continuous-batching engine
- ``repro.kernels``   -- Pallas TPU kernels (+ jnp oracles)
- ``repro.launch``    -- meshes, dry-run, roofline, drivers
"""

__version__ = "1.0.0"

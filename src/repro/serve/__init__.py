from .engine import Engine, EngineStats, Generation, Request

__all__ = ["Engine", "EngineStats", "Generation", "Request",
           "ClusterServer", "SpecDecoder"]


def __getattr__(name):
    # cluster/spec pull in the runtime and model stacks; keep plain
    # `from repro.serve import Engine` light by deferring those imports
    if name == "ClusterServer":
        from .cluster import ClusterServer
        return ClusterServer
    if name == "SpecDecoder":
        from .spec import SpecDecoder
        return SpecDecoder
    raise AttributeError(name)

"""Draft-model speculative decoding for the slot engine.

Classic two-model speculation (exemplar: SNIPPETS.md Snippet 2) adapted
to the engine's static-shape batch: every spec round, a small *draft*
model proposes ``gamma`` greedy tokens per slot from its own mirrored
slot cache, then the *target* verifies the whole proposal in ONE fused
dispatch -- a ``lax.scan`` of gamma+1 decode steps inside a single jit
call, so the per-step Python/dispatch overhead that dominates small-batch
decoding is paid once per round instead of once per token. The engine
accepts the longest prefix where the draft matched the target's greedy
choice and emits it plus the target's correction token, so the output
stream is bit-identical to plain greedy decoding -- speculation changes
cost, never content.

Cache-rollback safety comes for free from the attention layout:
``attn_decode`` masks cache entries at positions ``>= kv_len`` (the
per-slot ``pos``), so rejecting draft tokens is just *not advancing*
``pos`` -- the speculatively written KV entries beyond it are invisible
and get overwritten by the next round. This is a property of
position-indexed (attention) caches only: recurrent state (mamba/xLSTM
segments) cannot be rolled back by masking, so speculative decoding
requires an attention-only ``kind`` for both models.

The draft runs one extra scan step per round (gamma+1 total) so that on
a full acceptance its cache already holds KV for the last proposed
token -- otherwise the next round would resume over a cache hole.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def default_gamma() -> int:
    """Draft length; ``MPIGNITE_SPEC_GAMMA`` overrides the default 4."""
    try:
        return max(1, int(os.environ.get("MPIGNITE_SPEC_GAMMA", "4")))
    except ValueError:
        return 4


class SpecDecoder:
    """Bundles the draft model (params + its own jitted steps) and the
    fused propose/verify dispatches. Plug into ``Engine(spec=...)``.

    ``target_model``/``target_ops`` are the verified model (the engine's
    own); the verify scan closes over them so one jit call advances the
    target cache through gamma+1 positions. ``s_max`` must equal the
    engine's: draft and target caches are position-aligned.
    """

    def __init__(self, target_model, target_ops, draft_model, draft_params,
                 draft_ops=None, *, s_max: int, gamma: int | None = None):
        self.gamma = default_gamma() if gamma is None else int(gamma)
        self.draft_model = draft_model
        self.draft_params = draft_params
        self.s_max = s_max
        draft_ops = draft_ops if draft_ops is not None else target_ops
        gamma_ = self.gamma

        @jax.jit
        def _draft_prefill(params, batch):
            return draft_model.prefill(draft_ops, params, batch,
                                       s_max=s_max)

        @jax.jit
        def _draft_decode(params, caches, tokens, pos):
            return draft_model.decode(draft_ops, params, caches, tokens,
                                      pos)

        @jax.jit
        def _propose(params, caches, tok, pos):
            # gamma+1 greedy draft steps fused in one dispatch; the last
            # step only exists to land the final proposal's KV in the
            # draft cache for the full-accept case.
            def body(carry, _):
                cur, p, caches = carry
                logits, caches = draft_model.decode(
                    draft_ops, params, caches, cur[:, None], p)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (nxt, p + 1, caches), nxt

            (_, _, caches), toks = jax.lax.scan(
                body, (tok, pos, caches), None, length=gamma_ + 1)
            return toks[:gamma_].T, caches          # (B, gamma)

        @jax.jit
        def _verify(params, caches, tok, draft_toks, pos):
            # feed [current, d_1..d_gamma] through the target in one
            # fused scan; out[:, j] is the target's greedy choice after
            # seeing the prefix up to proposal j.
            seq = jnp.concatenate([tok[:, None], draft_toks], axis=1)

            def body(carry, x):
                caches, p = carry
                logits, caches = target_model.decode(
                    target_ops, params, caches, x[:, None], p)
                return (caches, p + 1), jnp.argmax(
                    logits, axis=-1).astype(jnp.int32)

            (caches, _), outs = jax.lax.scan(body, (caches, pos), seq.T)
            return outs.T, caches                   # (B, gamma+1)

        self._draft_prefill_fn = _draft_prefill
        self._draft_decode_fn = _draft_decode
        self._propose_fn = _propose
        self._verify_fn = _verify

    # ---- engine-facing surface ---------------------------------------------
    def draft_prefill(self, prompt: np.ndarray):
        """Prefill the draft on one prompt; returns its (1, ...) cache
        (the draft's logits are never used -- the target picks every
        emitted token)."""
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None, :]}
        _, cache1 = self._draft_prefill_fn(self.draft_params, batch)
        return cache1

    def draft_decode(self, caches, tokens, pos):
        """One plain draft step -- used by the engine's non-speculative
        fallback path to keep the draft cache position-aligned."""
        return self._draft_decode_fn(self.draft_params, caches, tokens,
                                     pos)

    def propose(self, caches, tok, pos):
        return self._propose_fn(self.draft_params, caches, tok, pos)

    def verify(self, params, caches, tok, draft_toks, pos):
        return self._verify_fn(params, caches, tok, draft_toks, pos)

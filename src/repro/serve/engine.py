"""Slot-based continuous-batching serving engine.

A fixed pool of ``max_slots`` sequence slots shares one decode step
(compiled once for the full batch); requests are admitted from a FIFO
queue as slots free up, prefilled individually (chunked prefill for long
prompts), and decoded together every engine step. Finished sequences
(EOS or budget) release their slot immediately -- the decode batch is
always full-width with a per-slot active mask, which is the standard
continuous-batching trick to keep the compiled shape static.

The engine is deliberately runtime-agnostic: ``prefill_fn``/``decode_fn``
are the compiled steps from train/step.py, so the same engine drives a
1-device CPU smoke test and a 512-chip mesh.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                # -1: never stops early
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    batch_occupancy: list = dataclasses.field(default_factory=list)


class Engine:
    def __init__(self, model, params, prefill_fn: Callable,
                 decode_fn: Callable, max_slots: int, s_max: int):
        self.model = model
        self.params = params
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.max_slots = max_slots
        self.s_max = s_max
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros((max_slots,), np.int32)      # next position
        self.cur_tok = np.zeros((max_slots,), np.int32)
        self.active = np.zeros((max_slots,), bool)
        self.caches = None                               # batched cache tree
        self.stats = EngineStats()
        self._uid = 0

    # ---- public API --------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: int = -1) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id))
        return self._uid

    def run(self) -> dict[int, list[int]]:
        """Drive to completion; returns {uid: generated tokens}."""
        out = {}
        while self.queue or any(self.active):
            finished = self.step()
            for r in finished:
                out[r.uid] = r.out_tokens
        return out

    # ---- engine step --------------------------------------------------------
    def step(self) -> list[Request]:
        self._admit()
        finished: list[Request] = []
        if not any(self.active):
            return finished
        tokens = jnp.asarray(self.cur_tok)[:, None]
        pos = jnp.asarray(self.pos)
        logits, self.caches = self.decode_fn(self.params, self.caches,
                                             tokens, pos)
        self.stats.decode_steps += 1
        self.stats.batch_occupancy.append(int(self.active.sum()))
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None or not self.active[i]:
                continue
            t = int(next_tok[i])
            req.out_tokens.append(t)
            self.stats.tokens_out += 1
            self.pos[i] += 1
            self.cur_tok[i] = t
            if (t == req.eos_id or
                    len(req.out_tokens) >= req.max_new_tokens or
                    self.pos[i] >= self.s_max - 1):
                req.done = True
                finished.append(req)
                self.active[i] = False
                self.slots[i] = None
        return finished

    # ---- admission + prefill -------------------------------------------------
    def _admit(self):
        for i in range(self.max_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_into(i, req)

    def _prefill_into(self, slot: int, req: Request):
        """Prefill one request and splice its cache into the batch cache."""
        batch = {"tokens": jnp.asarray(req.prompt)[None, :]}
        logits, cache1 = self.prefill_fn(self.params, batch)
        self.stats.prefills += 1
        first = int(np.argmax(np.asarray(logits)[0]))
        if self.caches is None:
            self.caches = jax.tree_util.tree_map_with_path(
                lambda path, c: self._widen(c, path), cache1)
        self.caches = jax.tree_util.tree_map_with_path(
            lambda path, full, one: self._splice(full, one, slot, path),
            self.caches, cache1)
        req.out_tokens.append(first)
        self.stats.tokens_out += 1
        self.slots[slot] = req
        self.active[slot] = True
        self.pos[slot] = len(req.prompt)
        self.cur_tok[slot] = first

    def _widen(self, c, path=()):
        """(1, ...)-batched single cache -> zeros of full slot width.
        Cache layouts carry batch at a known axis: we rely on the model's
        cache trees using batch as the axis right after any layer-stack
        dims; detection: the dim equal to 1."""
        axis = self._batch_axis(c, path)
        shape = list(c.shape)
        shape[axis] = self.max_slots
        return jnp.zeros(shape, c.dtype)

    def _splice(self, full, one, slot, path=()):
        axis = self._batch_axis(one, path)
        idx = [slice(None)] * one.ndim
        idx[axis] = slice(slot, slot + 1)
        return full.at[tuple(idx)].set(one)

    @staticmethod
    def _batch_axis(c, path=()) -> int:
        for i, s in enumerate(c.shape):
            if s == 1:
                return i
        leaf = jax.tree_util.keystr(path) if path else "<leaf>"
        raise ValueError(
            f"cannot locate batch axis in cache leaf {leaf}: no size-1 "
            f"dimension in shape {c.shape} (prefill caches must keep the "
            "single-request batch dim)")

"""Slot-based continuous-batching serving engine.

A fixed pool of ``max_slots`` sequence slots shares one decode step
(compiled once for the full batch); requests are admitted from a FIFO
queue as slots free up, prefilled individually (chunked prefill for long
prompts), and decoded together every engine step. Finished sequences
(EOS or budget) release their slot immediately -- the decode batch is
always full-width with a per-slot active mask, which is the standard
continuous-batching trick to keep the compiled shape static.

The engine is deliberately runtime-agnostic: ``prefill_fn``/``decode_fn``
are the compiled steps from train/step.py, so the same engine drives a
1-device CPU smoke test and a 512-chip mesh. ``serve/cluster.py`` shards
replicas of it across a warm ``ExecutorPool``; ``serve/spec.py`` plugs
draft-model speculative decoding into ``step()``.

Termination contract: a request finishes when its token hits ``eos_id``,
its ``max_new_tokens`` budget is spent, or its position runs out of
cache (``s_max``) -- the last case sets ``Request.truncated`` so callers
can tell a context-capped generation from a naturally finished one.
Finishing can happen *at prefill* (first token is EOS, or the budget is
one): such a request never occupies a slot and is returned by the next
``step()``/``run()``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.obs.metrics import AcceptanceStats

#: bounded debugging window of recent per-step occupancies kept by
#: EngineStats (the running sum/count is what long-lived replicas use)
OCCUPANCY_TAIL = 256


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                # -1: never stops early
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    #: finished because ``pos`` hit the cache budget (``s_max``), not
    #: EOS and not ``max_new_tokens`` -- the caller's signal that the
    #: generation was cut off rather than completed
    truncated: bool = False


class Generation(list):
    """A finished request's tokens. Compares equal to a plain list (so
    ``out[uid] == expected_tokens`` keeps working) and carries the
    per-request outcome flags alongside."""

    def __init__(self, tokens, uid: int, truncated: bool = False,
                 accept_ratio: float | None = None):
        super().__init__(tokens)
        self.uid = uid
        self.truncated = truncated
        #: mean speculative-decoding acceptance ratio over this
        #: request's spec rounds (None when spec decoding never ran)
        self.accept_ratio = accept_ratio


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    #: requests finished by the ``s_max`` cache budget (truncated)
    truncations: int = 0
    #: requests finished at prefill (first token was terminal)
    prefill_finishes: int = 0
    #: engine steps that ran the speculative (propose+verify) path
    spec_rounds: int = 0
    #: running occupancy aggregate -- O(1) however long the engine
    #: lives; ``occupancy_tail`` keeps a bounded recent window for
    #: debugging
    occupancy_sum: int = 0
    occupancy_steps: int = 0
    occupancy_tail: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=OCCUPANCY_TAIL))

    def record_occupancy(self, n: int) -> None:
        self.occupancy_sum += int(n)
        self.occupancy_steps += 1
        self.occupancy_tail.append(int(n))

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / max(self.occupancy_steps, 1)

    @property
    def batch_occupancy(self) -> list[int]:
        """Recent per-step occupancies (bounded window -- the unbounded
        list it replaces grew forever on serving replicas)."""
        return list(self.occupancy_tail)

    def summary(self) -> dict:
        return {"prefills": self.prefills,
                "decode_steps": self.decode_steps,
                "tokens_out": self.tokens_out,
                "truncations": self.truncations,
                "prefill_finishes": self.prefill_finishes,
                "spec_rounds": self.spec_rounds,
                "mean_occupancy": self.mean_occupancy}


class Engine:
    """``spec`` (optional) is a ``serve.spec.SpecDecoder``: when set and
    every active slot has cache headroom, ``step()`` proposes ``gamma``
    draft tokens per slot and verifies them in one fused target dispatch,
    emitting 1..gamma+1 tokens per slot per step (greedy outputs are
    bit-identical to the non-speculative path by construction).

    ``batch_axes`` optionally pins the cache batch axis (one int for
    every leaf, or a pytree of ints congruent with the cache); when
    omitted the engine derives each leaf's batch axis from the model's
    ``cache_specs`` metadata -- see ``_batch_axis_tree``."""

    def __init__(self, model, params, prefill_fn: Callable,
                 decode_fn: Callable, max_slots: int, s_max: int,
                 spec=None, batch_axes=None):
        self.model = model
        self.params = params
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.max_slots = max_slots
        self.s_max = s_max
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros((max_slots,), np.int32)      # next position
        self.cur_tok = np.zeros((max_slots,), np.int32)
        self.active = np.zeros((max_slots,), bool)
        self.caches = None                               # batched cache tree
        self.stats = EngineStats()
        self.acceptance = AcceptanceStats()
        self.spec = spec
        self._batch_axes = batch_axes
        self._axis_tree = None                  # resolved on first prefill
        self._draft_caches = None
        self._draft_axis_tree = None
        #: requests finished at prefill, to be returned by the next
        #: step()/run() -- they never occupied a slot
        self._prefill_finished: list[Request] = []
        #: live per-request spec accounting: uid -> [proposed, accepted]
        self._uid = 0

    # ---- public API --------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: int = -1, uid: int | None = None) -> int:
        """Queue one request. ``uid`` lets a front-end (serve/cluster.py)
        assign globally unique ids across replicas; left None, the
        engine numbers requests itself."""
        if uid is None:
            self._uid += 1
            uid = self._uid
        else:
            self._uid = max(self._uid, int(uid))
        self.queue.append(Request(uid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id))
        return uid

    def pending(self) -> int:
        """Queued + in-flight + finished-but-uncollected requests --
        the engine's load measure (what least-loaded routing compares)."""
        return (len(self.queue) + int(self.active.sum())
                + len(self._prefill_finished))

    def run(self) -> dict[int, Generation]:
        """Drive to completion; returns {uid: Generation} (a Generation
        compares equal to the plain token list and carries
        ``truncated``/``accept_ratio``)."""
        out: dict[int, Generation] = {}
        while self.queue or any(self.active) or self._prefill_finished:
            for r in self.step():
                out[r.uid] = self._generation(r)
        return out

    def _generation(self, req: Request) -> Generation:
        return Generation(req.out_tokens, req.uid, req.truncated,
                          self.acceptance.pop_request(req.uid))

    # ---- engine step --------------------------------------------------------
    def step(self) -> list[Request]:
        self._admit()
        finished: list[Request] = list(self._prefill_finished)
        self._prefill_finished.clear()
        if not any(self.active):
            return finished
        if self.spec is not None and self._spec_eligible():
            return finished + self._spec_step()
        tokens = jnp.asarray(self.cur_tok)[:, None]
        pos = jnp.asarray(self.pos)
        logits, self.caches = self.decode_fn(self.params, self.caches,
                                             tokens, pos)
        if self._draft_caches is not None:
            # keep the draft cache position-consistent: the draft decodes
            # the same token at the same position the target just did, so
            # a later spec round resumes from an aligned prefix
            _, self._draft_caches = self.spec.draft_decode(
                self._draft_caches, tokens, pos)
        self.stats.decode_steps += 1
        self.stats.record_occupancy(int(self.active.sum()))
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None or not self.active[i]:
                continue
            self.pos[i] += 1
            if self._emit(i, req, int(next_tok[i])):
                finished.append(req)
        return finished

    def _emit(self, slot: int, req: Request, tok: int) -> bool:
        """Append one generated token; apply the termination contract.
        Returns True (and frees the slot) when the request finished.
        Caller has already advanced ``pos`` past the token that
        *produced* ``tok``."""
        req.out_tokens.append(tok)
        self.stats.tokens_out += 1
        self.cur_tok[slot] = tok
        hit_eos = tok == req.eos_id
        hit_budget = len(req.out_tokens) >= req.max_new_tokens
        hit_ctx = bool(self.pos[slot] >= self.s_max - 1)
        if hit_eos or hit_budget or hit_ctx:
            req.done = True
            req.truncated = hit_ctx and not (hit_eos or hit_budget)
            if req.truncated:
                self.stats.truncations += 1
            self.active[slot] = False
            self.slots[slot] = None
            return True
        return False

    # ---- speculative decoding ----------------------------------------------
    def _spec_eligible(self) -> bool:
        """Every active slot must have cache headroom for gamma+1 writes
        (positions pos..pos+gamma all < s_max); otherwise this step falls
        back to the one-token path so near-budget requests still finish
        correctly."""
        gamma = self.spec.gamma
        act = self.active
        return bool(np.all(self.pos[act] + gamma < self.s_max))

    def _spec_step(self) -> list[Request]:
        """One speculative round: the draft proposes gamma tokens per
        slot, the target verifies them in one fused dispatch, and each
        slot emits its accepted prefix plus the target's correction
        token -- greedy acceptance, so the emitted stream is bit-equal
        to plain decoding."""
        sp = self.spec
        gamma = sp.gamma
        # inactive rows still flow through the batched scans; pin their
        # inputs to position 0 so the dead rows' writes never clamp
        pos_in = np.where(self.active, self.pos, 0).astype(np.int32)
        tok_in = np.where(self.active, self.cur_tok, 0).astype(np.int32)
        draft_toks, self._draft_caches = sp.propose(
            self._draft_caches, jnp.asarray(tok_in), jnp.asarray(pos_in))
        verified, self.caches = sp.verify(
            self.params, self.caches, jnp.asarray(tok_in), draft_toks,
            jnp.asarray(pos_in))
        self.stats.decode_steps += 1
        self.stats.spec_rounds += 1
        self.stats.record_occupancy(int(self.active.sum()))
        d = np.asarray(draft_toks)              # (B, gamma)
        v = np.asarray(verified)                # (B, gamma+1)
        finished: list[Request] = []
        for i, req in enumerate(self.slots):
            if req is None or not self.active[i]:
                continue
            # longest prefix where the draft guessed the target's token
            agree = d[i] == v[i, :gamma]
            n_acc = int(np.cumprod(agree).sum())
            self.acceptance.record(req.uid, gamma, n_acc)
            for tok in v[i, :n_acc + 1]:
                self.pos[i] += 1
                if self._emit(i, req, int(tok)):
                    finished.append(req)
                    break
        return finished

    # ---- admission + prefill -------------------------------------------------
    def _admit(self):
        for i in range(self.max_slots):
            # a request that finishes at prefill never takes the slot --
            # keep admitting into it until something survives prefill
            while self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_into(i, req)

    def _prefill_into(self, slot: int, req: Request):
        """Prefill one request and splice its cache into the batch cache.
        If the prefill token itself is terminal (EOS, a budget of one,
        or a prompt already at the cache limit), the request finishes
        here: it never occupies the slot, never costs a decode step, and
        is returned by the next ``step()``."""
        batch = {"tokens": jnp.asarray(req.prompt)[None, :]}
        logits, cache1 = self.prefill_fn(self.params, batch)
        self.stats.prefills += 1
        first = int(np.argmax(np.asarray(logits)[0]))
        req.out_tokens.append(first)
        self.stats.tokens_out += 1
        pos = len(req.prompt)
        hit_eos = first == req.eos_id
        hit_budget = req.max_new_tokens <= 1
        hit_ctx = pos >= self.s_max - 1
        if hit_eos or hit_budget or hit_ctx:
            req.done = True
            req.truncated = hit_ctx and not (hit_eos or hit_budget)
            if req.truncated:
                self.stats.truncations += 1
            self.stats.prefill_finishes += 1
            self._prefill_finished.append(req)
            return
        if self._axis_tree is None:
            self._axis_tree = self._batch_axis_tree(cache1, self.model)
        if self.caches is None:
            self.caches = jax.tree_util.tree_map(
                self._widen, cache1, self._axis_tree)
        self.caches = jax.tree_util.tree_map(
            lambda full, one, ax: self._splice(full, one, slot, ax),
            self.caches, cache1, self._axis_tree)
        if self.spec is not None:
            self._prefill_draft(slot, req)
        self.slots[slot] = req
        self.active[slot] = True
        self.pos[slot] = pos
        self.cur_tok[slot] = first

    def _prefill_draft(self, slot: int, req: Request):
        """Mirror the prefill into the draft model's slot cache."""
        dcache1 = self.spec.draft_prefill(req.prompt)
        if self._draft_axis_tree is None:
            self._draft_axis_tree = self._batch_axis_tree(
                dcache1, self.spec.draft_model)
        if self._draft_caches is None:
            self._draft_caches = jax.tree_util.tree_map(
                self._widen, dcache1, self._draft_axis_tree)
        self._draft_caches = jax.tree_util.tree_map(
            lambda full, one, ax: self._splice(full, one, slot, ax),
            self._draft_caches, dcache1, self._draft_axis_tree)

    # ---- cache layout -------------------------------------------------------
    def _batch_axis_tree(self, cache1, model):
        """Per-leaf batch axis of the cache tree.

        The prefill cache carries batch size 1, but a size-1 dim is NOT
        proof of batch-ness: a single-KV-head layout has a legitimate
        size-1 head axis *before* batch, and widening/splicing that axis
        silently corrupts other slots' caches. So the axis is derived
        from ground truth where available: the model's ``cache_specs``
        metadata evaluated at two batch sizes -- the axis whose extent
        follows the batch argument IS the batch axis, whatever size-1
        dims surround it. An explicit ``batch_axes`` constructor arg
        wins; the first-size-1 heuristic survives only as the fallback
        for models without cache metadata."""
        if self._batch_axes is not None:
            if isinstance(self._batch_axes, int):
                return jax.tree_util.tree_map(
                    lambda _: self._batch_axes, cache1)
            return self._batch_axes
        specs = getattr(model, "cache_specs", None)
        if specs is not None:
            try:
                s1, s3 = specs(1, self.s_max), specs(3, self.s_max)
                tree = jax.tree_util.tree_map(
                    lambda a, b, c: _axis_from_specs(a, b, c), s1, s3,
                    cache1)
                return tree
            except Exception:       # noqa: BLE001 -- metadata shape drift
                pass                # falls through to the heuristic
        return jax.tree_util.tree_map_with_path(_first_one_axis, cache1)

    def _widen(self, c, axis: int):
        """(1, ...)-batched single cache -> zeros of full slot width."""
        shape = list(c.shape)
        shape[axis] = self.max_slots
        return jnp.zeros(shape, c.dtype)

    def _splice(self, full, one, slot: int, axis: int):
        idx = [slice(None)] * one.ndim
        idx[axis] = slice(slot, slot + 1)
        return full.at[tuple(idx)].set(one)


def _axis_from_specs(spec1, spec3, leaf) -> int:
    """Batch axis = the dim whose extent tracked the batch argument
    across two ``cache_specs`` evaluations (1 vs 3)."""
    for i, (a, b) in enumerate(zip(spec1.shape, spec3.shape)):
        if a != b:
            return i
    return _first_one_axis((), leaf)


def _first_one_axis(path, c) -> int:
    """Fallback heuristic for metadata-less models: the first size-1
    dim. Ambiguous layouts (several size-1 dims) should pass
    ``batch_axes`` explicitly."""
    for i, s in enumerate(c.shape):
        if s == 1:
            return i
    leaf = jax.tree_util.keystr(path) if path else "<leaf>"
    raise ValueError(
        f"cannot locate batch axis in cache leaf {leaf}: no size-1 "
        f"dimension in shape {c.shape} (prefill caches must keep the "
        "single-request batch dim)")

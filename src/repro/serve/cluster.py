"""Multi-replica serving: Engine replicas sharded across a warm
ExecutorPool.

The single-process slot engine (serve/engine.py) tops out at one
process's decode throughput. This front-end runs ONE continuous-batching
engine per pool rank and keeps it alive in *executor process memory*
across dispatched jobs (the same pattern as the dataset layer's
partition store): the driver never holds model state, it only routes.

Life of a request::

    driver                                executors (one engine each)
    ------                                ---------------------------
    submit() -> pending queue
    step_round():
      least-loaded assignment      ---->  engine.submit() per replica
      one pooled job (quantum N)   ---->  up to N engine steps
      merge outboxes               <----  ALL unacked finished results
      ack                          ---->  (next round) outbox pruning

Three properties worth naming:

- **Weights cross the driver zero times in steady state.** At warm-up,
  rank 0 materializes the parameters and ``ibcast``\\ s them over the
  executor data plane (direct TCP / shm rings); after that, rounds move
  only token ids and stats. The driver stays a pure control plane.
- **Delivery is idempotent.** Executors keep every finished result in a
  per-replica outbox until the driver acknowledges it, and return the
  whole outbox each round; the driver dedups by uid. A round lost to a
  failure therefore never loses a finished generation that survived.
- **Failure shrinks, it doesn't restart.** On ``ExecutorFailure`` the
  driver calls ``pool.shrink_to_survivors()``: surviving replicas keep
  their processes (and their warm engines -- slot identity is stable),
  and requests owned by dead replicas are silently re-queued onto the
  survivors. Greedy decoding is deterministic, so a re-run request
  yields the identical generation.
"""
from __future__ import annotations

import os
import threading
import time
import uuid
from collections import deque

import numpy as np

from ..core.cluster.driver import ExecutorFailure, ExecutorPool
from ..core.cluster.launcher import CommandLauncher
from .engine import Generation

__all__ = ["ClusterServer", "serve_quantum", "smoke_engine_spec"]


def serve_quantum() -> int:
    """Decode steps each replica runs per dispatched round.
    ``MPIGNITE_SERVE_QUANTUM`` overrides the default 8: higher amortizes
    dispatch overhead better, lower tightens admission latency."""
    try:
        return max(1, int(os.environ.get("MPIGNITE_SERVE_QUANTUM", "8")))
    except ValueError:
        return 8


# ---------------------------------------------------------------------------
# Replica registry: engines living in *executor process memory*, surviving
# across pooled jobs (same pattern as data/dataset.py's partition store).
# Keyed by server namespace so concurrent servers on one pool never
# collide. The outbox holds finished-but-unacknowledged results per
# namespace -- the idempotent-delivery half of the protocol.
# ---------------------------------------------------------------------------
_REPLICAS: dict[str, object] = {}
_OUTBOXES: dict[str, dict[int, dict]] = {}
_REG_LOCK = threading.Lock()


def _replica_put(ns: str, eng) -> None:
    with _REG_LOCK:
        _REPLICAS[ns] = eng
        _OUTBOXES[ns] = {}


def _replica_get(ns: str):
    """(engine, outbox) for one namespace, or (None, None). A module
    function (not a closure capture) so shipped closures reference it
    by import -- the lock itself never rides the wire."""
    with _REG_LOCK:
        return _REPLICAS.get(ns), _OUTBOXES.get(ns)


def _numpy_tree(tree):
    import jax
    return jax.tree_util.tree_map(np.asarray, tree)


def _warmup_closure(ns: str, build_engine, load_params):
    def run(comm):
        params = None
        if load_params is not None:
            if comm.get_rank() == 0:
                params = _numpy_tree(load_params())
            if comm.get_size() > 1:
                # weights ride the executor data plane (direct TCP/shm),
                # not the driver control plane -- the one and only bulk
                # transfer this server ever does
                params = comm.ibcast(0, params).wait()
        eng = build_engine(params, comm.get_rank())
        _replica_put(ns, eng)
        return {"rank": comm.get_rank(), "slots": eng.max_slots}
    return run


def _round_closure(ns: str, admits: dict, acks: list, quantum: int):
    """One serving round on every replica: admit this round's
    assignments (keyed by world rank), prune acknowledged results, run
    up to ``quantum`` engine steps, and return the full outbox plus a
    load figure for the driver's next routing decision."""
    def run(comm):
        eng, outbox = _replica_get(ns)
        if eng is None:
            raise RuntimeError(
                f"serve replica {ns!r} missing on rank {comm.get_rank()} "
                "(warm-up never ran here?)")
        for uid in acks:
            outbox.pop(uid, None)
        for spec in admits.get(comm.get_rank(), ()):  # keys: world ranks
            eng.submit(np.asarray(spec["prompt"], np.int32),
                       spec["max_new_tokens"], spec["eos_id"],
                       uid=spec["uid"])
        steps = 0
        while steps < quantum and eng.pending() > 0:
            for req in eng.step():
                gen = eng._generation(req)
                outbox[gen.uid] = {"uid": gen.uid, "tokens": list(gen),
                                   "truncated": gen.truncated,
                                   "accept_ratio": gen.accept_ratio}
            steps += 1
        obs = getattr(comm, "_obs", None)
        if obs is not None:
            # acceptance + occupancy land in the job's traced snapshot
            # (JobTrace.counters) alongside the runtime's own counters
            eng.acceptance.publish(obs)
            obs.counters["serve.tokens_out"] = eng.stats.tokens_out
            obs.counters["serve.truncations"] = eng.stats.truncations
            obs.counters["serve.mean_occupancy"] = round(
                eng.stats.mean_occupancy, 3)
        return {"finished": list(outbox.values()), "load": eng.pending(),
                "stats": eng.stats.summary(),
                "acceptance": eng.acceptance.summary()}
    return run


class ClusterServer:
    """Driver-side front-end sharding requests over engine replicas.

    ``build_engine(params, replica_id) -> Engine`` runs once per rank at
    warm-up (inside the executor; ship configs, not models).
    ``load_params() -> pytree`` runs on rank 0 only; its result is
    broadcast to every replica. Leave it None when ``build_engine``
    derives parameters itself (e.g. deterministic seeded init).

    ``mode="local"`` runs the same admission/routing/ack machinery over
    in-process engines -- no pool, no processes -- which is what the
    fast test lane exercises; ``mode="cluster"`` is the real thing.

    Pools default to a ``CommandLauncher`` (fresh spawned interpreters):
    serving executors run jax, and running jax in *forked* children of a
    driver that already initialized jax is unsafe.
    """

    def __init__(self, n: int, build_engine, load_params=None, *,
                 mode: str = "cluster", pool: ExecutorPool | None = None,
                 quantum: int | None = None, backend: str = "ring",
                 round_timeout: float = 180.0,
                 warmup_timeout: float = 600.0, trace: bool = False,
                 pool_kwargs: dict | None = None):
        if mode not in ("cluster", "local"):
            raise ValueError(f"unknown mode {mode!r}")
        self.mode = mode
        self.ns = f"serve-{uuid.uuid4().hex[:10]}"
        self.quantum = serve_quantum() if quantum is None else int(quantum)
        self.round_timeout = round_timeout
        self.trace = trace
        self._pending: deque[dict] = deque()
        self._inflight: dict[int, dict] = {}        # uid -> record
        self._results: dict[int, Generation] = {}
        self._to_ack: set[int] = set()
        self._submit_t: dict[int, float] = {}
        self._finish_t: dict[int, float] = {}
        self._uid = 0
        #: replica load estimate, keyed by stable slot id (cluster) or
        #: replica index (local); refreshed from each round's returns
        self._load: dict[int, int] = {}
        self.replica_stats: dict[int, dict] = {}
        self.rerouted = 0           # requests re-queued off dead replicas
        self.rounds = 0
        self._own_pool = False
        self.pool = pool

        if mode == "local":
            params = _numpy_tree(load_params()) if load_params else None
            self._engines = [build_engine(params, i) for i in range(n)]
            self._load = {i: 0 for i in range(n)}
            return

        if self.pool is None:
            kw = dict(backend=backend, timeout=round_timeout,
                      launcher=CommandLauncher(),
                      hb_interval=0.25, hb_timeout=30.0)
            kw.update(pool_kwargs or {})
            self.pool = ExecutorPool(n, **kw)
            self._own_pool = True
        self.pool.run(_warmup_closure(self.ns, build_engine, load_params),
                      timeout=warmup_timeout)
        self._load = {slot: 0 for slot in self.pool.world}

    # ---- request surface ---------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: int = -1) -> int:
        self._uid += 1
        uid = self._uid
        self._pending.append({"uid": uid,
                              "prompt": np.asarray(prompt, np.int32),
                              "max_new_tokens": int(max_new_tokens),
                              "eos_id": int(eos_id)})
        self._submit_t[uid] = time.monotonic()
        return uid

    def outstanding(self) -> int:
        return len(self._pending) + len(self._inflight)

    def results(self) -> dict[int, Generation]:
        return dict(self._results)

    def latency(self, uid: int) -> float | None:
        """Seconds from submit to the driver observing the result."""
        t1 = self._finish_t.get(uid)
        return None if t1 is None else t1 - self._submit_t[uid]

    # ---- rounds ------------------------------------------------------------
    def step_round(self) -> list[int]:
        """Assign pending requests least-loaded, run one pooled round,
        merge results. Returns uids newly finished this round. On a
        replica failure: shrink to survivors, re-queue the dead
        replica's requests, and report nothing finished (survivor
        outboxes re-deliver next round)."""
        if self.outstanding() == 0:
            return []
        self.rounds += 1
        if self.mode == "local":
            return self._local_round()
        world = self.pool.world
        admits: dict[int, list] = {}
        for slot in world:
            self._load.setdefault(slot, 0)
        sent: list[dict] = []
        while self._pending:
            rec = self._pending.popleft()
            slot = min(world, key=lambda s: self._load[s])
            rec["slot"] = slot
            admits.setdefault(world.index(slot), []).append(rec)
            self._load[slot] += 1
            self._inflight[rec["uid"]] = rec
            sent.append(rec)
        acks = sorted(self._to_ack)
        closure = _round_closure(self.ns, admits, acks, self.quantum)
        try:
            outs = self.pool.run(closure, timeout=self.round_timeout,
                                 trace=True if self.trace else None)
        except ExecutorFailure:
            self._recover(sent)
            return []
        self._to_ack.clear()
        done = []
        for w, out in enumerate(outs):
            slot = world[w]
            self._load[slot] = out["load"]
            self.replica_stats[slot] = {"stats": out["stats"],
                                        "acceptance": out["acceptance"]}
            for rec in out["finished"]:
                done.extend(self._collect(rec))
        return done

    def _collect(self, rec: dict) -> list[int]:
        uid = rec["uid"]
        self._to_ack.add(uid)                   # prune outboxes next round
        if uid in self._results:                # duplicate re-delivery
            return []
        self._results[uid] = Generation(rec["tokens"], uid,
                                        rec["truncated"],
                                        rec.get("accept_ratio"))
        self._finish_t[uid] = time.monotonic()
        self._inflight.pop(uid, None)
        return [uid]

    def _recover(self, sent: list[dict]) -> None:
        info = self.pool.shrink_to_survivors()
        dead = set(info["dead_slots"])
        dead_owned = [rec for rec in self._inflight.values()
                      if rec.get("slot") in dead]
        # requests assigned in the failed round have unconfirmed
        # delivery -- re-queue them too. A survivor that DID admit one
        # before the failure will just see a duplicate submit later;
        # the uid-keyed outbox and driver dedup make that harmless.
        requeue = {rec["uid"]: rec for rec in dead_owned + sent
                   if rec["uid"] in self._inflight}
        # preserve submission order: older uids re-enter the queue first
        for uid in sorted(requeue, reverse=True):
            rec = requeue[uid]
            self._inflight.pop(uid)
            rec.pop("slot", None)
            self._pending.appendleft(rec)
        self.rerouted += len(dead_owned)
        for s in dead:
            self._load.pop(s, None)

    def _local_round(self) -> list[int]:
        replicas = sorted(self._load)
        while self._pending:
            rec = self._pending.popleft()
            slot = min(replicas, key=lambda s: self._load[s])
            rec["slot"] = slot
            self._inflight[rec["uid"]] = rec
            self._load[slot] += 1
            eng = self._engines[slot]
            eng.submit(rec["prompt"], rec["max_new_tokens"],
                       rec["eos_id"], uid=rec["uid"])
        done = []
        for slot, eng in enumerate(self._engines):
            steps = 0
            while steps < self.quantum and eng.pending() > 0:
                for req in eng.step():
                    gen = eng._generation(req)
                    done.extend(self._collect(
                        {"uid": gen.uid, "tokens": list(gen),
                         "truncated": gen.truncated,
                         "accept_ratio": gen.accept_ratio}))
                steps += 1
            self._load[slot] = eng.pending()
            self.replica_stats[slot] = {
                "stats": eng.stats.summary(),
                "acceptance": eng.acceptance.summary()}
        self._to_ack.clear()        # no outboxes to prune in local mode
        return done

    def run_until_drained(self, max_rounds: int = 10_000):
        """Drive rounds until every submitted request has a result;
        returns {uid: Generation}."""
        rounds = 0
        while self.outstanding() > 0:
            self.step_round()
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError(
                    f"serving failed to drain within {max_rounds} rounds "
                    f"({self.outstanding()} outstanding)")
        return self.results()

    # ---- aggregate telemetry ----------------------------------------------
    def acceptance_summary(self) -> dict:
        """Pool-wide speculative acceptance, summed over replicas."""
        tot = {"proposed": 0, "accepted": 0, "rounds": 0}
        for rs in self.replica_stats.values():
            for k in tot:
                tot[k] += rs["acceptance"][k]
        tot["ratio"] = tot["accepted"] / max(tot["proposed"], 1)
        return tot

    # ---- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self._own_pool and self.pool is not None:
            self.pool.shutdown()

    def __enter__(self) -> "ClusterServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Canonical smoke-model replica spec: what tests, benchmarks and the
# example use. Returns (build_engine, load_params) closures that import
# models lazily -- nothing heavy is shipped, each executor rebuilds the
# model from config and receives the broadcast parameters.
# ---------------------------------------------------------------------------
def smoke_engine_spec(arch: str = "qwen3-4b", *, s_max: int = 64,
                      slots: int = 4, seed: int = 0, gamma: int = 0,
                      draft_layers: int | None = None):
    """``gamma > 0`` enables speculative decoding on every replica with
    a draft of ``draft_layers`` layers (None: clone the target config --
    a draft identical to the target accepts everything, which is the
    determinism-pinning configuration)."""

    def _cfg_model():
        import dataclasses
        import jax.numpy as jnp
        from ..configs import get_config
        from ..models.model import Model
        from ..parallel import axes as A
        from ..parallel.ops import ParallelConfig, make_ops
        cfg = dataclasses.replace(get_config(arch, smoke=True),
                                  dtype=jnp.float32)
        axes1 = A.MeshAxes(1, 1, 1)
        pcfg = ParallelConfig(path="mpignite", sequence_parallel=False,
                              remat="none")
        return cfg, Model(cfg, axes1, pcfg), make_ops(axes1, pcfg), axes1, \
            pcfg

    def load_params():
        import jax
        import jax.numpy as jnp
        cfg, model, _, axes1, pcfg = _cfg_model()
        tree = {"target": model.init(jax.random.PRNGKey(seed),
                                     dtype=jnp.float32)}
        if gamma > 0 and draft_layers is not None:
            import dataclasses
            from ..models.model import Model
            dcfg = dataclasses.replace(cfg, n_layers=draft_layers,
                                       name=cfg.name + "-draft")
            draft = Model(dcfg, axes1, pcfg)
            tree["draft"] = draft.init(jax.random.PRNGKey(seed + 1),
                                       dtype=jnp.float32)
        return tree

    def build_engine(params, replica_id):
        import jax
        import jax.numpy as jnp
        from .engine import Engine
        from .spec import SpecDecoder
        cfg, model, ops, axes1, pcfg = _cfg_model()
        params = jax.tree_util.tree_map(jnp.asarray, params)

        @jax.jit
        def prefill_fn(p, batch):
            return model.prefill(ops, p, batch, s_max=s_max)

        @jax.jit
        def decode_fn(p, caches, tokens, pos):
            return model.decode(ops, p, caches, tokens, pos)

        spec = None
        if gamma > 0:
            if draft_layers is None:
                draft_model, draft_params = model, params["target"]
            else:
                import dataclasses
                from ..models.model import Model
                dcfg = dataclasses.replace(cfg, n_layers=draft_layers,
                                           name=cfg.name + "-draft")
                draft_model, draft_params = Model(dcfg, axes1, pcfg), \
                    params["draft"]
            spec = SpecDecoder(model, ops, draft_model, draft_params,
                               s_max=s_max, gamma=gamma)
        return Engine(model, params["target"], prefill_fn, decode_fn,
                      max_slots=slots, s_max=s_max, spec=spec)

    return build_engine, load_params

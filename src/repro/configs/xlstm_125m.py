"""xlstm-125m [ssm]: 12L d_model=768 4H vocab=50304 [arXiv:2405.04517].

mLSTM (matrix-memory, chunkwise-parallel) blocks with one sLSTM
(scalar-memory, sequential) block every 8 layers; no FFN (d_ff=0) --
mixing capacity lives in the blocks' up/down projections (proj_factor 2).
Recurrent state => runs long_500k. TP note (DESIGN.md): 4 heads < 16-way
model axis, mixers are replicated over `model` (FSDP over `data` only).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", kind="xlstm", n_layers=12, d_model=768,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    slstm_every=8, proj_factor=2.0, ssm_chunk=64, long_context_ok=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", kind="xlstm", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=103,
    slstm_every=2, proj_factor=2.0, ssm_chunk=16, long_context_ok=True,
)

"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (kv=8) d_ff=6912
vocab=32000 [arXiv:2401.16818]. Llama+Mistral mix with sliding-window
attention (window 4096) => bounded KV, runs long_500k.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", kind="dense", n_layers=24, d_model=2560,
    n_heads=32, n_kv_heads=8, d_ff=6912, vocab=32000,
    window=4096, long_context_ok=True,
)

SMOKE = ModelConfig(
    name="h2o-danube-smoke", kind="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=103,
    window=32, long_context_ok=True,
)

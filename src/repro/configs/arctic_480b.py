"""arctic-480b [moe]: 35L d_model=7168 56H (kv=8) vocab=32000.

Snowflake Arctic [hf:Snowflake/snowflake-arctic-base]: 128 experts top-2
(d_ff=4864 each) in *parallel* with a dense residual MLP (d_ff=4864).
56 query heads pad to 64 slots on a 16-way model axis (per-KV-group
padding -- see models.common.gqa_layout); kv=8 replicates 2x.
Optimizer: Adafactor (factored second moments) -- Adam state would not
fit 480B params on 256 x 16GB chips; see train/optim.py and DESIGN.md.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", kind="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, n_shared_experts=0, moe_d_ff=4864,
    dense_residual=True, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="arctic-smoke", kind="moe", n_layers=2, d_model=64,
    n_heads=7, n_kv_heads=1, d_ff=96, vocab=103,
    n_experts=8, top_k=2, n_shared_experts=0, moe_d_ff=96,
    dense_residual=True, capacity_factor=1.5,
)

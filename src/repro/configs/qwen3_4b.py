"""qwen3-4b [dense]: 36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936
[hf:Qwen/Qwen3-8B family]. Per-head QK-RMSNorm; explicit head_dim=128
(> d_model/n_heads). Full attention => long_500k skipped.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", kind="dense", n_layers=36, d_model=2560,
    n_heads=32, n_kv_heads=8, d_ff=9728, vocab=151936,
    head_dim=128, qk_norm=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", kind="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=103,
    head_dim=32, qk_norm=True,
)

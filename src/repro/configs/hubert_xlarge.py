"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only (bidirectional attention), same trunk as wav2vec2
[arXiv:2106.07447]. The CNN waveform frontend is a stub per assignment:
``input_mode="frames"`` -- the batch carries precomputed (B, S, d) frame
embeddings; a learned projector stands in for the post-CNN projection.
No decode shapes (encoder has no autoregressive step); masked-unit
prediction loss over the 504-unit codebook (padded to the TP vocab grid).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", kind="dense", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504,
    causal=False, act="gelu", input_mode="frames",
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", kind="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=103,
    causal=False, act="gelu", input_mode="frames",
)

"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) vocab=102400.

Fine-grained MoE [arXiv:2401.06066]: 64 routed experts top-6 with
d_ff=1408 each, plus 2 shared experts (always-on), and a dense first
layer (d_ff=10944 per the HF checkpoint). Expert parallelism: experts
sharded over the `model` axis, dispatched with the paper-technique
all-to-all (PeerComm.alltoall). Full attention => long_500k skipped.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", kind="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=10944, vocab=102400,
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    first_dense_layers=1, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke", kind="moe", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab=103,
    n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=32,
    first_dense_layers=1, capacity_factor=1.5,
)

"""stablelm-3b [dense]: 32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304.

StableLM-family dense transformer [hf:stabilityai/stablelm-2-1_6b style]:
partial rotary (rope_pct=0.25). Full attention => long_500k skipped.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", kind="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=6912, vocab=50304,
    rope_pct=0.25,
)

SMOKE = ModelConfig(
    name="stablelm-smoke", kind="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=103, rope_pct=0.25,
)

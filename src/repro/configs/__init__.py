from .registry import ARCHS, SHAPES, Shape, cell_plan, get_config, skip_reason

__all__ = ["ARCHS", "SHAPES", "Shape", "cell_plan", "get_config",
           "skip_reason"]

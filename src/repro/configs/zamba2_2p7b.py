"""zamba2-2.7b [hybrid]: 54L d_model=2560, Mamba2 backbone + one shared
attention+MLP block applied every 6 layers [arXiv:2411.15242].

Shared block: 32H MHA (kv=32), d_ff=10240 MLP. SSD: state N=64, head dim
P=64 (=> 80 SSD heads at expand=2). Runs long_500k (SSM: O(1) state; the
shared attention keeps one KV cache per group application).
Simplification vs. HF checkpoint (DESIGN.md): a single shared block (the
checkpoint alternates two) and no embedding concat at shared-block entry.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", kind="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    attn_every=6, long_context_ok=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", kind="hybrid", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=103,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
    attn_every=2, long_context_ok=True,
)

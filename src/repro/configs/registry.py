"""Architecture & shape registry -- the assigned (arch x shape) grid.

``get_config(name, smoke=False)`` returns the exact assigned ModelConfig;
``SHAPES`` defines the four assigned input shapes; ``cell_plan()``
enumerates every runnable (arch, shape) cell plus explicit SKIP records
with rationale (encoder-only archs have no decode; full-attention archs
skip long_500k per assignment).
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.common import ModelConfig

ARCH_MODULES = {
    "hubert-xlarge": "hubert_xlarge",
    "zamba2-2.7b": "zamba2_2p7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "arctic-480b": "arctic_480b",
    "stablelm-3b": "stablelm_3b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "qwen3-4b": "qwen3_4b",
    "xlstm-125m": "xlstm_125m",
    "llama-3.2-vision-11b": "llama32_vision_11b",
}

ARCHS = tuple(ARCH_MODULES)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    step: str          # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f".{ARCH_MODULES[name]}", __package__)
    return (mod.SMOKE if smoke else mod.CONFIG).validate()


def skip_reason(cfg: ModelConfig, shape: Shape) -> str | None:
    if shape.step == "decode" and cfg.is_encoder:
        return "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return "full quadratic attention: long_500k assigned to " \
               "SSM/hybrid/SWA archs only"
    return None


def cell_plan() -> list[dict]:
    """All 40 cells; runnable ones have skip=None."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            out.append({"arch": arch, "shape": shape.name,
                        "skip": skip_reason(cfg, shape)})
    return out

"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (kv=8) d_ff=14336
vocab=128256 [hf:meta-llama/Llama-3.2-11B-Vision].

Backbone only per assignment: the vision tower is a stub --
``input_specs`` provides precomputed (B, 1601, 1280) patch embeddings,
projected by a learned (1280 -> 4096) matrix. Every 5th layer is a
tanh-gated cross-attention block (8 groups of 4 self + 1 cross = 40).
Full attention => long_500k skipped.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", kind="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256,
    cross_attn_every=5, n_image_tokens=1601, vision_d=1280,
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", kind="dense", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=103,
    cross_attn_every=2, n_image_tokens=17, vision_d=48,
)

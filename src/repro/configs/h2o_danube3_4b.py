"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (kv=8) d_ff=10240
vocab=32000 [arXiv:2401.16818 family]. SWA window 4096; head_dim=120.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", kind="dense", n_layers=24, d_model=3840,
    n_heads=32, n_kv_heads=8, d_ff=10240, vocab=32000,
    window=4096, long_context_ok=True,
)

SMOKE = ModelConfig(
    name="h2o-danube3-smoke", kind="dense", n_layers=2, d_model=96,
    n_heads=4, n_kv_heads=2, d_ff=160, vocab=103,
    window=32, long_context_ok=True,
)

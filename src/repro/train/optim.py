"""Sharded optimizers: AdamW and Adafactor, with fp32 master weights.

States mirror parameter sharding exactly (local shards on the mpignite
path, global-with-constraints on gspmd), so ZeRO-3 partitioning of
optimizer state falls out of the FSDP parameter specs for free.

Adafactor (Shazeer & Stern, arXiv:1804.04235) factors the second moment
of every rank>=2 parameter over its last two dims -- the reason
arctic-480b fits: Adam would need ~3.8 GB/chip of extra state per moment
at 256 chips; factored stats are O(rows+cols).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    master: bool = True            # keep fp32 master weights; False =>
                                   # update the bf16 params directly
                                   # (T5X-style lean Adafactor -- the
                                   # memory mode that fits 480B training)
    # adafactor
    decay_pow: float = 0.8         # beta2_t = 1 - t^-decay_pow
    min_dim_factored: int = 2      # factor only if both dims >= this


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_peak * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _master_of(p):
    """fp32 master copy -- always a distinct buffer (params and opt_state
    are donated separately; aliasing them breaks donation)."""
    return jnp.copy(p) if p.dtype == jnp.float32 else p.astype(jnp.float32)


def adamw_init(params):
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(_master_of, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        w = w - lr * (u + cfg.weight_decay * w)
        return m, v, w

    gl, tdef = jax.tree.flatten(grads)
    ml = jax.tree.leaves(state["m"])
    vl = jax.tree.leaves(state["v"])
    wl = jax.tree.leaves(state["master"])
    res = [upd(g, m, v, w) for g, m, v, w in zip(gl, ml, vl, wl)]
    m = jax.tree.unflatten(tdef, [r[0] for r in res])
    v = jax.tree.unflatten(tdef, [r[1] for r in res])
    w = jax.tree.unflatten(tdef, [r[2] for r in res])
    new_params = jax.tree.map(_cast_distinct, w, params)
    return new_params, {"step": step, "master": w, "m": m, "v": v}


def _cast_distinct(master, p):
    """Master -> compute dtype. When they coincide (fp32 runs), force a
    distinct buffer: params and opt_state are both donated, and aliased
    outputs would be donated twice on the next step."""
    if master.dtype == p.dtype:
        return jnp.copy(master)
    return master.astype(p.dtype)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no first moment; fp32 master)
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor_init(params, master: bool = True):
    def stats(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    out = {
        "step": jnp.zeros((), jnp.int32),
        "stats": jax.tree.map(stats, params),
    }
    if master:
        out["master"] = jax.tree.map(_master_of, params)
    return out


def adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    beta2 = 1.0 - step.astype(jnp.float32) ** -cfg.decay_pow
    eps = 1e-30
    has_master = "master" in state

    def upd(g, s, w):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if "vr" in s:
            vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            prec = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            u = g * jax.lax.rsqrt(prec + eps)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta2 * s["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(v + eps)
            new_s = {"v": v}
        # update clipping (RMS(u) <= 1) stabilizes early training
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms)
        w = w - lr * (u + cfg.weight_decay * w)
        return new_s, w

    leaves_g, tdef = jax.tree.flatten(grads)
    leaves_s = tdef.flatten_up_to(state["stats"])
    leaves_w = jax.tree.leaves(state["master"] if has_master else params)
    new_s, new_w = [], []
    for g, s, w in zip(leaves_g, leaves_s, leaves_w):
        ns, nw = upd(g, s, w.astype(jnp.float32))
        new_s.append(ns)
        new_w.append(nw)
    stats = jax.tree.unflatten(tdef, new_s)
    master = jax.tree.unflatten(tdef, new_w)
    new_params = jax.tree.map(_cast_distinct, master, params)
    new_state = {"step": step, "stats": stats}
    if has_master:
        new_state["master"] = master
    return new_params, new_state


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    cfg: OptConfig

    def init(self, params):
        if self.cfg.name == "adamw":
            return adamw_init(params)
        if self.cfg.name == "adafactor":
            return adafactor_init(params, master=self.cfg.master)
        raise ValueError(self.cfg.name)

    def update(self, grads, state, params):
        if self.cfg.name == "adamw":
            return adamw_update(self.cfg, grads, state, params)
        return adafactor_update(self.cfg, grads, state, params)

    def state_pspecs_from(self, specs_tree):
        """ParamSpec tree -> PartitionSpec tree for the optimizer state."""
        from jax.sharding import PartitionSpec as P
        from ..models.common import ParamSpec
        is_ps = lambda x: isinstance(x, ParamSpec)
        pspecs = jax.tree.map(lambda s: s.pspec, specs_tree, is_leaf=is_ps)
        if self.cfg.name == "adamw":
            return {"step": P(), "master": pspecs, "m": pspecs, "v": pspecs}

        def stats(s: ParamSpec):
            e = tuple(s.pspec) + (None,) * (len(s.shape) - len(s.pspec))
            if _factored(s.shape):
                return {"vr": P(*e[:-1]), "vc": P(*(e[:-2] + e[-1:]))}
            return {"v": P(*e)}
        out = {"step": P(),
               "stats": jax.tree.map(stats, specs_tree, is_leaf=is_ps)}
        if self.cfg.master:
            out["master"] = pspecs
        return out

"""Int8 gradient compression with error feedback for cross-pod sync.

Cross-pod links are the scarcest bandwidth in a multi-pod mesh; the
pod-axis gradient allreduce is compressed 4x (bf16 -> int8 + one fp32
scale) using the classic EF-SGD scheme (Seide et al. 2014; Karimireddy
et al., arXiv:1901.09847): the quantization residual is carried to the
next step so the compression error telescopes instead of accumulating.

The exchange itself is an ``allgather`` of int8 payloads composed from
PeerComm primitives -- on the `linear` backend this byte-for-byte
reproduces the paper's phase-1 master relay, compressed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.comm import PeerComm


def quantize_int8(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pod_allreduce_int8(comm: PeerComm, g, ef=None):
    """Sum-allreduce g over the pod axis in int8. Returns (g_sum, ef_new).
    ``ef`` is this leaf's error-feedback residual (same shape, f32)."""
    gf = g.astype(jnp.float32)
    if ef is not None:
        gf = gf + ef
    q, scale = quantize_int8(gf)
    sent = q.astype(jnp.float32) * scale
    ef_new = gf - sent                       # residual stays local
    qs = comm.allgather(q, axis=0)           # (P, ...) int8 on the wire
    ss = comm.allgather(scale, axis=0)       # (P,) f32
    total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=1)
    return total.astype(g.dtype), ef_new


def ef_zeros_like(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

"""Asynchronous in-cluster buddy checkpointing (arXiv 1804.11312 model).

Instead of stalling the step loop on a disk write, each rank streams its
state shard to its *buddy* -- the next rank around the ring
(``comm.buddy()``) -- with ``isend``/``irecv`` driven by the runtime's
progress engine, overlapped with the step's compute. The shards live in
executor-process memory (module-level store, surviving across pooled
jobs), so recovery after a failure needs no relaunch and no full-world
disk restore: the survivors already hold every shard, including the dead
rank's (one hop away at its buddy).

Epoch/commit protocol -- a snapshot interrupted by the failure it is
meant to survive must never be restored:

1. ``snapshot(comm, step, shard)`` *stages* epoch ``step``: the local
   shard is recorded, the transfer to the buddy starts nonblocking.
2. ``commit(comm, handle)`` waits the transfers, records the peer shard,
   then runs a tiny allreduce. The allreduce completing on *any* rank
   proves every rank contributed -- i.e. every transfer of this epoch
   was fully staged world-wide -- so only then is the epoch marked
   committed locally.
3. ``recover(...)`` (in the shrunken world) agrees on the restore epoch
   as ``max`` over the survivors' latest *committed* epochs: if any rank
   committed E, E is fully staged on every survivor; if the failure hit
   mid-snapshot, nobody committed E and the agreement lands on E-1 --
   the torn epoch is unreachable by construction.

A single failure is always recoverable (the dead rank's shard is at its
buddy). Losing a rank *and* its buddy loses a shard:``recover`` raises
``BuddyShardLost`` and the caller falls back to the disk checkpoint.
"""
from __future__ import annotations

import threading
from typing import Any

import numpy as np

#: tag band for buddy traffic -- far above the small tags closures use
_TAG_BASE = 1 << 20

#: per-process stores, keyed by (namespace, rank); populated inside
#: executor processes and surviving across pooled jobs (that persistence
#: IS the checkpoint medium). Keying by rank too keeps the thread-mode
#: SPMD runtime honest, where every rank shares one process.
_STORES: dict[tuple[str, int], dict] = {}
_STORES_LOCK = threading.Lock()


class BuddyShardLost(RuntimeError):
    """A needed shard died with both its owner and its buddy: in-memory
    recovery is impossible, fall back to the disk checkpoint."""


def _store(namespace: str, rank: int) -> dict:
    with _STORES_LOCK:
        return _STORES.setdefault((namespace, rank), {"epochs": {}})


def reset(namespace: str | None = None) -> None:
    """Drop staged state (tests; or a workload switching checkpoints)."""
    with _STORES_LOCK:
        if namespace is None:
            _STORES.clear()
        else:
            for key in [k for k in _STORES if k[0] == namespace]:
                del _STORES[key]


class SnapshotHandle:
    """In-flight snapshot: the nonblocking buddy transfer of one epoch."""

    def __init__(self, step: int, send_req, recv_req):
        self.step = step
        self.send_req = send_req
        self.recv_req = recv_req


class BuddyCheckpointer:
    """The in-memory twin of ``checkpoint.AsyncCheckpointer``: snapshot
    to a buddy rank's memory instead of disk, overlapped with compute.

    Usage inside a step closure (the executor process keeps the store
    across jobs)::

        bc = BuddyCheckpointer("myrun")
        h = bc.snapshot(comm, step, my_shard)   # nonblocking
        ...compute...
        bc.commit(comm, h)                      # barrier + commit mark
    """

    def __init__(self, namespace: str = "default", history: int = 2,
                 timeout: float = 30.0):
        if history < 2:
            raise ValueError("history must keep >= 2 epochs: the commit "
                             "protocol falls back one epoch on a torn "
                             "snapshot")
        self.namespace = namespace
        self.history = history
        self.timeout = timeout

    # -- snapshot/commit ----------------------------------------------------
    def snapshot(self, comm, step: int, shard: Any) -> SnapshotHandle:
        """Stage epoch ``step`` and start the nonblocking buddy
        transfer. Returns a handle for ``commit``."""
        size, rank = comm.get_size(), comm.get_rank()
        store = _store(self.namespace, rank)
        entry = {"step": step, "rank": rank, "size": size,
                 "self": shard, "peer": None,
                 "peer_src": (rank - 1) % size, "committed": False}
        store["epochs"][step] = entry
        self._prune(store)
        if size == 1:
            return SnapshotHandle(step, None, None)
        tag = _TAG_BASE + step
        # ibsend: the serialize+stream cost runs on the progress engine,
        # not here -- the caller's compute is what it overlaps with
        send_req = comm.ibsend(comm.buddy(), tag, (step, rank, shard))
        recv_req = comm.irecv((rank - 1) % size, tag)
        return SnapshotHandle(step, send_req, recv_req)

    def commit(self, comm, handle: SnapshotHandle) -> None:
        """Complete the epoch: wait the transfers, then agree world-wide
        that every rank staged it before marking it committed. Raises
        (``PeerDeadError`` et al.) if the world broke mid-snapshot --
        leaving the epoch staged-but-uncommitted, exactly as the
        protocol requires."""
        store = _store(self.namespace, comm.get_rank())
        entry = store["epochs"].get(handle.step)
        if entry is None:
            raise RuntimeError(f"epoch {handle.step} was pruned before "
                               "commit; raise history")
        if handle.recv_req is not None:
            _, src_rank, peer_shard = handle.recv_req.wait(
                timeout=self.timeout)
            handle.send_req.wait(timeout=self.timeout)
            entry["peer"] = peer_shard
            entry["peer_src"] = src_rank
        # all-staged barrier: completing proves every rank contributed,
        # which requires its transfers staged -- the commit invariant
        comm.allreduce(np.ones(1, np.float32), np.minimum)
        entry["committed"] = True

    def _prune(self, store: dict) -> None:
        steps = sorted(store["epochs"])
        for s in steps[:-self.history]:
            del store["epochs"][s]

    # -- introspection ------------------------------------------------------
    def latest_committed(self, rank: int = 0) -> int | None:
        epochs = _store(self.namespace, rank)["epochs"]
        committed = [s for s, e in epochs.items() if e["committed"]]
        return max(committed) if committed else None

    def staged_steps(self, rank: int = 0) -> list[int]:
        return sorted(_store(self.namespace, rank)["epochs"])

    # -- recovery -----------------------------------------------------------
    def recover(self, comm, old_size: int, old_rank_of: list[int],
                dead_old_ranks: list[int]
                ) -> tuple[int, dict[int, Any]]:
        """Reassemble every old-world shard on every survivor, in the
        *shrunken* world. ``old_rank_of[w]`` is new world rank ``w``'s
        rank in the pre-failure epoch; ``dead_old_ranks`` the old ranks
        that died (both come from ``ExecutorPool.shrink_to_survivors``).

        Returns ``(restore_step, {old_rank: shard})`` -- the caller
        rebalances shards over the new world however its state is
        partitioned. Raises ``BuddyShardLost`` when a shard died with
        both its owner and its buddy (fall back to disk)."""
        size, rank = comm.get_size(), comm.get_rank()
        old_rank = old_rank_of[rank]
        # snapshots were staged under this process's *pre-failure* rank
        store = _store(self.namespace, old_rank)
        mine = self.latest_committed(old_rank)
        agreed = int(comm.allreduce(
            np.asarray([-1 if mine is None else mine], np.int64),
            np.maximum)[0])
        if agreed < 0:
            raise BuddyShardLost("no committed buddy snapshot anywhere")
        entry = store["epochs"].get(agreed)
        contrib: dict[int, Any] = {}
        if entry is not None:
            contrib[old_rank] = entry["self"]
            if (entry["peer"] is not None
                    and entry["peer_src"] in dead_old_ranks):
                # this survivor is the buddy of a dead rank: its staged
                # copy is the only remaining instance of that shard
                contrib[entry["peer_src"]] = entry["peer"]
        # exchange via p2p (payloads are arbitrary objects; collectives
        # may slice arrays): root merges, then fans the union back out
        if size > 1:
            if rank == 0:
                merged = dict(contrib)
                for src in range(1, size):
                    merged.update(comm.receive(src, _TAG_BASE - 1))
                for dst in range(1, size):
                    comm.send(dst, _TAG_BASE - 2, merged)
            else:
                comm.send(0, _TAG_BASE - 1, contrib)
                merged = comm.receive(0, _TAG_BASE - 2)
        else:
            merged = contrib
        missing = sorted(set(range(old_size)) - set(merged))
        if missing:
            raise BuddyShardLost(
                f"shard(s) of old rank(s) {missing} lost: owner and "
                f"buddy both died (epoch {agreed}); fall back to the "
                "disk checkpoint")
        return agreed, merged

from .optim import OptConfig, Optimizer, lr_at
from .step import (init_opt_state, make_decode_step, make_prefill_step,
                   make_train_step)
from . import buddy, checkpoint, compress, ft

__all__ = ["OptConfig", "Optimizer", "lr_at", "init_opt_state",
           "make_decode_step", "make_prefill_step", "make_train_step",
           "buddy", "checkpoint", "compress", "ft"]

"""Fault-tolerance machinery: failure injection, straggler detection,
comm-mode degradation -- the paper's section 3.1 recovery story.

The paper proposes switching from peer-to-peer mode back to master-relay
mode while coping with faults, then resuming peer-to-peer. Here that is a
*backend swap on restart*, exercised against two failure sources:

- **simulated** (SPMD runtime): the supervisor loop in ``launch/train.py``
  catches a ``SimulatedFailure`` from ``FailureInjector``, restores the
  latest checkpoint and rebuilds the train step with ``backend="linear"``
  (master relay) for ``recovery_steps`` steps before swapping back;
- **real** (cluster runtime): ``core.cluster.ClusterSupervisor`` reacts to
  genuine executor-process death -- detected by the driver's heartbeat
  monitor -- with the same ``RecoveryPolicy``/``SupervisorState`` schedule,
  restoring the checkpoint and relaunching degraded executor processes.

Stragglers: per-step wall time is tracked with an EWMA; a step slower
than ``threshold`` x the EWMA marks a straggler event. In a multi-host
deployment the mitigation is speculative re-execution of the slow host's
shard (MapReduce-style backup tasks); single-process here, the detector
records the event and the supervisor's hook decides (tested
deterministically with a fake clock).
"""
from __future__ import annotations

import dataclasses
import time


class SimulatedFailure(RuntimeError):
    """Raised by FailureInjector to model a node loss."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at given global steps (each fires once)."""
    fail_at: frozenset[int] = frozenset()

    def __post_init__(self):
        self._fired: set[int] = set()

    def check(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time monitor. ``observe`` returns True on a straggler."""
    alpha: float = 0.1
    threshold: float = 3.0
    warmup: int = 5

    def __post_init__(self):
        self.ewma: float | None = None
        self.n = 0
        self.events: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (self.n > self.warmup
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            # do not poison the EWMA with the outlier
            self.events.append((step, dt, self.ewma))
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class RecoveryPolicy:
    """What the supervisor does after a failure."""
    degrade_backend: str = "linear"   # paper phase-1 master relay
    recovery_steps: int = 8           # steps to run degraded after restart
    max_restarts: int = 8


@dataclasses.dataclass
class SupervisorState:
    restarts: int = 0
    degraded_until: int = -1
    straggler_events: int = 0
    #: recoveries served by shrink-to-survivors (no relaunch) -- a
    #: subset of ``restarts``, which counts every recovery either way
    shrinks: int = 0

    def on_failure(self, step: int, policy: RecoveryPolicy) -> str:
        self.restarts += 1
        if self.restarts > policy.max_restarts:
            raise RuntimeError("restart budget exhausted")
        self.degraded_until = step + policy.recovery_steps
        return policy.degrade_backend

    def on_straggler(self, step: int, dt: float, ewma: float) -> None:
        """Record a straggler event surfaced by ``StragglerDetector`` --
        the supervisor calls this (and its user hook) instead of the
        counter being write-only."""
        self.straggler_events += 1

    def backend_for(self, step: int, fast_backend: str,
                    policy: RecoveryPolicy) -> str:
        return (policy.degrade_backend if step <= self.degraded_until
                else fast_backend)

"""Sharded checkpointing with elastic re-mesh restore.

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per pytree leaf
(global arrays; on a real multi-host deployment each host writes its
addressable shards -- single-process here, noted in DESIGN.md) plus
``manifest.json`` (step, leaf paths/shapes/dtypes, user metadata).
Writes are atomic (tmp dir + rename); a retention policy prunes old
steps; ``AsyncCheckpointer`` moves serialization off the step loop.

Elastic re-mesh: arrays are stored with *global* shapes, so restore can
target any mesh -- ``restore_sharded`` re-slices via device_put with the
new NamedShardings (the paper's fault-recovery story: recompute/reload,
then resume peer-to-peer).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

SEP = "/"

# numpy round-trips ml_dtypes arrays as raw void; serialize via a
# same-width integer view and restore from the manifest's logical dtype.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8, "float16": None}


def _to_disk(arr: np.ndarray) -> np.ndarray:
    v = _VIEW_AS.get(arr.dtype.name)
    return arr.view(v) if v is not None else arr


def _from_disk(arr: np.ndarray, logical: str) -> np.ndarray:
    if _VIEW_AS.get(logical) is not None:
        return arr.view(getattr(ml_dtypes, logical))
    return arr


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:     # pragma: no cover - exotic filesystems
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(ckpt_dir: str, step: int, state: dict, meta: dict | None = None,
         keep: int = 3) -> str:
    """state: arbitrary pytree dict (e.g. {params, opt}). Returns path.

    Crash-safe: every leaf and the manifest are fsynced before the
    atomic rename, and the parent directory is fsynced after it -- a
    power cut mid-save leaves only a ``.tmp`` dir (skipped by
    ``latest_step``), never a torn ``step_N`` that restores garbage."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace(SEP, "__") + ".npy"
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, _to_disk(arr))
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": arr.dtype.name}
    # manifest last: its presence (and parseability) is the commit mark
    # _valid_step checks, so a torn write can never look complete
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(ckpt_dir)
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def _valid_step(ckpt_dir: str, step: int) -> bool:
    """A step dir is restorable iff its manifest parses and every leaf
    file it names exists -- a kill mid-write (or a partially deleted
    dir) fails this and the step is skipped."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return all(os.path.exists(os.path.join(path, v["file"]))
                   for v in manifest["leaves"].values())
    except (OSError, ValueError, KeyError, TypeError):
        return False


def latest_step(ckpt_dir: str) -> int | None:
    """Newest *restorable* step: torn/corrupt step dirs (kill mid-write)
    are skipped, falling back to the previous complete checkpoint."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    for step in sorted(steps, reverse=True):
        if _valid_step(ckpt_dir, step):
            return step
    return None


def load(ckpt_dir: str, step: int | None = None) -> tuple[dict, dict, int]:
    """Returns (flat_leaves {key: np.ndarray}, meta, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {k: _from_disk(np.load(os.path.join(path, v["file"])),
                          v["dtype"])
            for k, v in manifest["leaves"].items()}
    return flat, manifest["meta"], step


def restore_tree(template, flat: dict[str, Any]):
    """Rebuild a pytree shaped like ``template`` from flat leaves."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tleaf in paths:
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tleaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {tleaf.shape} (elastic restore "
                             "requires identical global shapes)")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_sharded(template, flat, mesh, pspecs):
    """Elastic re-mesh restore: place global arrays onto ``mesh`` with
    ``pspecs`` (which may describe a different topology than at save)."""
    from jax.sharding import NamedSharding
    tree = restore_tree(template, flat)
    return jax.tree.map(
        lambda arr, tleaf, spec: jax.device_put(
            np.asarray(arr).astype(tleaf.dtype),
            NamedSharding(mesh, spec)),
        tree, template, pspecs)


class AsyncCheckpointer:
    """Serialize checkpoints on a background thread (bounded queue;
    blocks the step loop only when more than one save is in flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.q: queue.Queue = queue.Queue(maxsize=1)
        self.errors: list[Exception] = []
        self._finished = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self.q.get()
            if item is None:
                return
            step, state, meta = item
            try:
                save(self.ckpt_dir, step, state, meta, keep=self.keep)
            except Exception as e:  # noqa: BLE001
                self.errors.append(e)

    def submit(self, step: int, state, meta=None):
        # device_get now so donated buffers aren't freed under us
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self.q.put((step, host_state, meta))

    def finish(self):
        """Drain the queue, stop the writer, surface the first error.
        Idempotent: the supervisor flushes pending saves on shutdown,
        and a workload may already have called this itself."""
        if not self._finished:
            self._finished = True
            self.q.put(None)
        self._thread.join()
        if self.errors:
            raise self.errors[0]

"""Step builders: wrap Model.loss / prefill / decode into compiled SPMD
steps on either distribution path.

- mpignite path: the whole step body (fwd, bwd, grad sync, optimizer) runs
  inside one ``shard_map``; every collective is an explicit PeerComm call
  (paper model). Parameters/optimizer state enter as local shards.
- gspmd path: the same body under ``jit`` with in/out shardings; XLA's
  SPMD partitioner inserts collectives.

Gradient clipping uses a sharding-aware global norm: each leaf's local
square-sum is psum'd only over the axes *present* in its PartitionSpec
(absent axes hold replicas -- summing them would double-count).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import tree_pspecs
from ..models.model import Model
from ..parallel import axes as A
from ..core import compat
from ..parallel.ops import ParallelConfig, ShardOps, make_ops
from . import compress as C
from .optim import Optimizer


def _flat_axes(spec, ndim):
    entries = tuple(spec) + (None,) * (ndim - len(spec))
    out = []
    for e in entries:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return out


def global_grad_norm(ops, grads, pspecs):
    """Replication-aware global L2 norm (identical on every shard)."""
    total = jnp.float32(0.0)
    leaves, tdef = jax.tree.flatten(grads)
    specs = tdef.flatten_up_to(pspecs)
    for g, spec in zip(leaves, specs):
        sq = jnp.sum(g.astype(jnp.float32) ** 2)
        if isinstance(ops, ShardOps):
            axes_here = _flat_axes(spec, g.ndim)
            if A.MODEL_AXIS in axes_here and ops.tp > 1:
                sq = ops.comm_model.allreduce(sq)
            if A.DATA_AXIS in axes_here and ops.axes.data > 1:
                sq = ops.comm_data.allreduce(sq)
        total = total + sq
    return jnp.sqrt(total)


def make_train_step(model: Model, opt: Optimizer, mesh: Mesh,
                    global_batch: int,
                    use_compression: bool | None = None):
    """Returns (step_fn, state_pspecs). step_fn(params, opt_state, batch)
    -> (params, opt_state, metrics). opt_state includes 'ef' when
    cross-pod int8 compression is enabled."""
    pcfg = model.pcfg
    axes = model.axes
    compression = (pcfg.grad_compression == "int8"
                   if use_compression is None else use_compression)
    compression = compression and axes.pod > 1
    param_ps = model.pspecs
    opt_ps = opt.state_pspecs_from(model.specs)
    if compression:
        opt_ps = {**opt_ps, "ef": param_ps}

    def body(params, opt_state, batch):
        ops = make_ops(axes, pcfg)
        m = max(pcfg.microbatches, 1)

        def grad_of(b):
            return jax.value_and_grad(
                lambda p: model.loss(ops, p, b), has_aux=True)(params)

        if m == 1:
            (loss, metrics), grads = grad_of(batch)
        else:
            # gradient accumulation: scan over microbatches; each micro
            # loss is a global mean, so the accumulated grad averages by m.
            mb = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)

            acc_dt = jnp.dtype(pcfg.microbatch_dtype)

            def acc_step(acc, b):
                (l, met), g = grad_of(b)
                acc = jax.tree.map(
                    lambda a, gi: a + (gi.astype(jnp.float32) / m
                                       ).astype(acc_dt), acc, g)
                return acc, (l, met)

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            from ..core.comm import cost_scope
            with cost_scope(m):
                grads, (losses, mets) = jax.lax.scan(acc_step, acc0, mb)
            metrics = {"nll_sum": jnp.sum(mets["nll_sum"]),
                       "n_valid": jnp.sum(mets["n_valid"]),
                       "aux": jnp.mean(mets["aux"])}
        ef = opt_state.get("ef") if compression else None
        comp_fn = C.pod_allreduce_int8 if compression else None
        grads, ef_new = ops.sync_grads(grads, param_ps, compress=comp_fn,
                                       ef=ef)
        gnorm = (global_grad_norm(ops, grads, param_ps)
                 if isinstance(ops, ShardOps)
                 else jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                   for g in jax.tree.leaves(grads))))
        clip = opt.cfg.grad_clip
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12)) \
            if clip else jnp.float32(1.0)
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
        inner = ({k: v for k, v in opt_state.items() if k != "ef"}
                 if compression else opt_state)
        new_params, new_opt = opt.update(grads, inner, params)
        if compression:
            new_opt = {**new_opt, "ef": ef_new}
        # metrics: reduce the local sums to global means for reporting
        nll, nv = metrics["nll_sum"], metrics["n_valid"]
        if isinstance(ops, ShardOps):
            nll = ops.comm_data.allreduce(nll)
            if ops.comm_pod is not None:
                nll = ops.comm_pod.allreduce(nll)
            nv = nv * ops.dp
        out_metrics = {"loss": nll / nv, "gnorm": gnorm,
                       "aux": metrics["aux"],
                       "step": new_opt["step"].astype(jnp.float32)}
        return new_params, new_opt, out_metrics

    _, batch_ps = model.batch_specs(global_batch, 1)
    metrics_ps = {"loss": P(), "gnorm": P(), "aux": P(), "step": P()}

    if pcfg.path == "mpignite":
        smapped = compat.shard_map(
            body, mesh=mesh,
            in_specs=(param_ps, opt_ps, batch_ps),
            out_specs=(param_ps, opt_ps, metrics_ps),
            check_vma=False)
        step = jax.jit(smapped, donate_argnums=(0, 1))
    else:
        ns = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree)
        step = jax.jit(body,
                       in_shardings=(ns(param_ps), ns(opt_ps), ns(batch_ps)),
                       out_shardings=(ns(param_ps), ns(opt_ps),
                                      ns(metrics_ps)),
                       donate_argnums=(0, 1))
    return step, {"params": param_ps, "opt": opt_ps, "batch": batch_ps}


def init_opt_state(model: Model, opt: Optimizer, params,
                   use_compression: bool = False):
    state = opt.init(params)
    if use_compression and model.axes.pod > 1:
        state = {**state, "ef": C.ef_zeros_like(params)}
    return state


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model, mesh: Mesh, global_batch: int,
                      s_max: int):
    """Sequence-parallelism is disabled for serving steps (a 1-token decode
    cannot be sequence-sharded; prefill follows for cache-layout parity)."""
    pcfg = model.pcfg.replace(sequence_parallel=False)
    axes = model.axes
    serve_model = _with_pcfg(model, pcfg)

    def body(params, batch):
        ops = make_ops(axes, pcfg)
        return serve_model.prefill(ops, params, batch, s_max=s_max)

    param_ps = model.pspecs
    _, batch_ps = model.batch_specs(global_batch, 1)
    cache_ps = tree_pspecs(serve_model.cache_specs(global_batch, s_max))
    logits_ps = P(_first(batch_ps), None)
    if pcfg.path == "mpignite":
        smapped = compat.shard_map(body, mesh=mesh,
                                in_specs=(param_ps, batch_ps),
                                out_specs=(logits_ps, cache_ps),
                                check_vma=False)
        return jax.jit(smapped)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    return jax.jit(body, in_shardings=(ns(param_ps), ns(batch_ps)),
                   out_shardings=(ns(logits_ps), ns(cache_ps)))


def make_decode_step(model: Model, mesh: Mesh, batch: int, s_max: int):
    pcfg = model.pcfg.replace(sequence_parallel=False)
    axes = model.axes
    serve_model = _with_pcfg(model, pcfg)

    def body(params, caches, tokens, pos):
        ops = make_ops(axes, pcfg)
        return serve_model.decode(ops, params, caches, tokens, pos)

    param_ps = model.pspecs
    cache_ps = tree_pspecs(model.cache_specs(batch, s_max))
    bsp = model._bspec(batch)
    tok_ps = P(bsp, None)
    pos_ps = P(bsp)
    logits_ps = P(bsp, None)
    if pcfg.path == "mpignite":
        smapped = compat.shard_map(
            body, mesh=mesh,
            in_specs=(param_ps, cache_ps, tok_ps, pos_ps),
            out_specs=(logits_ps, cache_ps), check_vma=False)
        return jax.jit(smapped, donate_argnums=(1,))
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    return jax.jit(body,
                   in_shardings=(ns(param_ps), ns(cache_ps), ns(tok_ps),
                                 ns(pos_ps)),
                   out_shardings=(ns(logits_ps), ns(cache_ps)),
                   donate_argnums=(1,))


def _first(batch_ps):
    spec = batch_ps[next(iter(batch_ps))]
    return tuple(spec)[0] if len(tuple(spec)) else None


def _with_pcfg(model: Model, pcfg: ParallelConfig) -> Model:
    m = object.__new__(Model)
    m.__dict__.update(model.__dict__)
    m.pcfg = pcfg
    return m

"""Pluggable executor launchers: how ranks come into existence.

PR-2's pool hardcoded ``fork`` -- fine on one machine, a dead end for the
paper's actual premise (peer communication inside a *cluster*). This
module splits "what an executor needs to know" (``ExecutorSpec``) from
"how its process starts" (``Launcher.launch -> ExecutorHandle``):

- ``ForkLauncher``    : today's behavior -- ``multiprocessing`` fork of
  ``executor_main`` in-process. Zero startup cost, single-host only, the
  secret rides into the child as inherited memory.
- ``CommandLauncher`` : spawn via an arbitrary command template, each
  element ``str.format``-ed with the spec's fields. The default template
  runs the module entry (``python -m repro.core.cluster.executor``) as a
  plain subprocess; a template like ``["ssh", "node{rank}", "python",
  "-m", "repro.core.cluster.executor", ...]`` reaches remote machines,
  and the same shape covers ``srun`` / ``kubectl exec``. The shared
  secret travels as a *file path* (``{secret_file}``), never argv, so it
  does not leak into process listings.

The pool and the supervisor both speak only this interface, so
checkpoint-restart recovery relaunches through whatever launcher the
world was built with -- a kill-an-ssh-rank failure restarts ssh ranks,
not forks.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
import subprocess
import sys
from typing import Sequence


@dataclasses.dataclass
class ExecutorSpec:
    """Everything one rank needs to boot and join the world."""
    rank: int
    world: int
    driver_host: str
    driver_port: int
    backend: str = "linear"
    timeout: float = 60.0
    hb_interval: float = 0.1
    data_plane: str = "direct"
    bind_host: str = "127.0.0.1"
    #: this rank's *own* data-plane advertise address. The pool never
    #: fills it (the driver's advertise_host is a different address --
    #: the one executors dial); set it per rank through a launcher
    #: template's --advertise-host, or leave None to derive it from the
    #: rank's route to the driver.
    advertise_host: str | None = None
    secret: bytes = b""
    secret_file: str | None = None

    @property
    def driver(self) -> str:
        return f"{self.driver_host}:{self.driver_port}"

    def format_args(self) -> dict:
        """The substitution map for ``CommandLauncher`` templates."""
        return {
            "rank": self.rank, "world": self.world, "driver": self.driver,
            "driver_host": self.driver_host, "driver_port": self.driver_port,
            "backend": self.backend, "timeout": self.timeout,
            "hb_interval": self.hb_interval, "data_plane": self.data_plane,
            "bind_host": self.bind_host,
            "advertise_host": self.advertise_host or "",
            "secret_file": self.secret_file or "",
            "python": sys.executable,
        }


class ExecutorHandle:
    """Liveness/teardown facade over however the rank was started."""

    pid: int | None

    def is_alive(self) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def terminate(self) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def join(self, timeout: float | None = None) -> None:
        raise NotImplementedError  # pragma: no cover - interface

    def exit_code(self) -> int | None:
        """The process's exit status, or None while it runs."""
        raise NotImplementedError  # pragma: no cover - interface


class _ForkHandle(ExecutorHandle):
    def __init__(self, proc: multiprocessing.Process):
        self._proc = proc

    @property
    def pid(self) -> int | None:
        return self._proc.pid

    def is_alive(self) -> bool:
        return self._proc.is_alive()

    def terminate(self) -> None:
        self._proc.terminate()

    def join(self, timeout: float | None = None) -> None:
        self._proc.join(timeout)

    def exit_code(self) -> int | None:
        return self._proc.exitcode


class _CommandHandle(ExecutorHandle):
    def __init__(self, proc: subprocess.Popen):
        self._proc = proc

    @property
    def pid(self) -> int | None:
        return self._proc.pid

    def is_alive(self) -> bool:
        return self._proc.poll() is None

    def terminate(self) -> None:
        self._proc.terminate()

    def join(self, timeout: float | None = None) -> None:
        try:
            self._proc.wait(timeout)
        except subprocess.TimeoutExpired:
            pass

    def exit_code(self) -> int | None:
        return self._proc.poll()


class Launcher:
    """Start one executor per ``launch`` call.

    ``needs_secret_file`` tells the pool to materialize the shared secret
    as a 0600 temp file before launching (command-spawned executors
    cannot inherit driver memory)."""

    needs_secret_file = False

    def launch(self, spec: ExecutorSpec) -> ExecutorHandle:
        raise NotImplementedError  # pragma: no cover - interface

    def cache_key(self) -> tuple:
        """Hashable identity for warm-pool caching: two launchers with
        equal keys start interchangeable worlds."""
        return (type(self).__module__, type(self).__qualname__)


class ForkLauncher(Launcher):
    """PR-2 semantics: fork ``executor_main`` in-process (POSIX only)."""

    def launch(self, spec: ExecutorSpec) -> ExecutorHandle:
        from .executor import executor_main
        try:
            mp = multiprocessing.get_context("fork")
        except ValueError as e:  # pragma: no cover - non-POSIX platforms
            raise RuntimeError(
                "ForkLauncher requires the fork start method (POSIX); use "
                "CommandLauncher or mode='local' here") from e
        proc = mp.Process(
            target=executor_main,
            args=(spec.rank, spec.world,
                  (spec.driver_host, spec.driver_port), spec.backend,
                  spec.timeout, spec.hb_interval, spec.data_plane),
            kwargs={"bind_host": spec.bind_host,
                    "advertise_host": spec.advertise_host,
                    "secret": spec.secret},
            daemon=True)
        proc.start()
        return _ForkHandle(proc)


#: the plain-subprocess instantiation of the spawn bridge; ssh/srun/
#: kubectl templates prepend their own transport in front of {python}.
DEFAULT_COMMAND_TEMPLATE: tuple[str, ...] = (
    "{python}", "-m", "repro.core.cluster.executor",
    "--rank", "{rank}", "--world", "{world}", "--driver", "{driver}",
    "--secret-file", "{secret_file}", "--backend", "{backend}",
    "--timeout", "{timeout}", "--hb-interval", "{hb_interval}",
    "--data-plane", "{data_plane}", "--bind-host", "{bind_host}",
)


class CommandLauncher(Launcher):
    """Spawn executors from a command template -- the module-entry
    bootstrap that makes ssh/srun/kubectl-exec launches possible, and
    that tests exercise via plain local subprocesses."""

    needs_secret_file = True

    def __init__(self, template: Sequence[str] | None = None,
                 env: dict | None = None):
        self.template = tuple(template) if template is not None \
            else DEFAULT_COMMAND_TEMPLATE
        self.env = env

    def cache_key(self) -> tuple:
        return (*super().cache_key(), self.template,
                None if self.env is None else tuple(sorted(self.env.items())))

    def launch(self, spec: ExecutorSpec) -> ExecutorHandle:
        subst = spec.format_args()
        argv = [part.format(**subst) for part in self.template]
        # an advertise host must never be dropped silently: templates
        # that don't place {advertise_host} themselves get it appended
        # (trailing flags still reach the CLI through ssh/srun wrappers)
        if spec.advertise_host and not any("{advertise_host}" in part
                                           for part in self.template):
            argv += ["--advertise-host", spec.advertise_host]
        env = dict(os.environ if self.env is None else self.env)
        # the module entry must find this checkout regardless of cwd
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        path = env.get("PYTHONPATH", "")
        if src_root not in path.split(os.pathsep):
            env["PYTHONPATH"] = src_root + (os.pathsep + path if path else "")
        # runpy warns that `-m repro.core.cluster.executor` was already
        # imported by its own package -- expected here, not actionable
        flt, warn = "ignore::RuntimeWarning:runpy", env.get("PYTHONWARNINGS")
        if not warn:
            env["PYTHONWARNINGS"] = flt
        elif flt not in warn.split(","):
            env["PYTHONWARNINGS"] = flt + "," + warn
        proc = subprocess.Popen(argv, env=env)
        return _CommandHandle(proc)

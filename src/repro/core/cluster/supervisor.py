"""Checkpoint-restart supervision for the cluster runtime.

This is the paper's section-3.1 fault story made real: the driver's
heartbeat monitor declares a rank dead (``ExecutorFailure``), the
supervisor restores the latest checkpoint, relaunches the world with the
degraded phase-1 ``linear`` backend for ``recovery_steps`` steps (master
relay is the mode the paper falls back to while coping with faults), and
then the workload resumes the fast peer-to-peer backend -- all driven by
the very same ``RecoveryPolicy``/``SupervisorState`` machinery
``train.ft`` previously exercised only against *simulated* failures.

The workload contract is step-structured: the caller provides
``make_closure(run) -> fn(comm)`` where ``run`` tells the closure where
to resume and which backend each step must use. Inside the closure,
``run.comm_for(comm, step)`` applies the degrade schedule and rank 0
persists state with ``run.save(step, state)``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from ...train import ft
from .driver import ClusterFuncRDD, ExecutorFailure


@dataclasses.dataclass
class RunContext:
    """What one (re)launch of the world knows about recovery."""
    ckpt_dir: str
    start_step: int                  # first step this launch must execute
    attempt: int                     # 0 on the first launch
    degraded_until: int              # steps <= this use the degrade backend
    fast_backend: str
    degrade_backend: str

    def backend_for(self, step: int) -> str:
        return (self.degrade_backend if step <= self.degraded_until
                else self.fast_backend)

    def comm_for(self, comm, step: int):
        """The communicator to use at ``step`` (same transport, possibly
        degraded algorithm)."""
        want = self.backend_for(step)
        return comm if comm.backend == want else comm.with_backend(want)

    def save(self, step: int, state: dict, meta: dict | None = None) -> str:
        from ...train import checkpoint as CKPT
        return CKPT.save(self.ckpt_dir, step, state, meta)

    def restore(self) -> tuple[dict, dict, int] | None:
        """(flat_leaves, meta, step) of the latest checkpoint, or None."""
        from ...train import checkpoint as CKPT
        if CKPT.latest_step(self.ckpt_dir) is None:
            return None
        return CKPT.load(self.ckpt_dir)


@dataclasses.dataclass
class ClusterSupervisor:
    """Relaunch-from-checkpoint loop above ``ClusterFuncRDD``."""
    ckpt_dir: str
    policy: ft.RecoveryPolicy = dataclasses.field(
        default_factory=ft.RecoveryPolicy)
    fast_backend: str = "ring"
    timeout: float = 60.0
    hb_interval: float = 0.1
    hb_timeout: float = 1.0
    restart_delay: float = 0.0

    def __post_init__(self):
        self.state = ft.SupervisorState()
        self.failures: list[tuple[int, str]] = []   # (restart_step, reason)

    def _latest_step(self) -> int:
        from ...train import checkpoint as CKPT
        return CKPT.latest_step(self.ckpt_dir) or 0

    def run(self, make_closure: Callable[[RunContext], Callable], n: int,
            ) -> list[Any]:
        """Run ``make_closure(run_ctx)`` across ``n`` executor processes,
        restarting from the latest checkpoint on executor death until the
        closure completes or ``policy.max_restarts`` is exhausted."""
        attempt = 0
        while True:
            start = self._latest_step()
            run_ctx = RunContext(
                ckpt_dir=self.ckpt_dir,
                start_step=start,
                attempt=attempt,
                degraded_until=self.state.degraded_until,
                fast_backend=self.fast_backend,
                degrade_backend=self.policy.degrade_backend)
            # every launch starts in the backend the schedule dictates
            launch_backend = run_ctx.backend_for(start + 1)
            rdd = ClusterFuncRDD(make_closure(run_ctx), timeout=self.timeout,
                                 backend=launch_backend,
                                 hb_interval=self.hb_interval,
                                 hb_timeout=self.hb_timeout)
            try:
                return rdd.execute(n)
            except ExecutorFailure as e:
                restart_step = self._latest_step()
                self.failures.append((restart_step, e.reason))
                # raises once policy.max_restarts is exhausted
                self.state.on_failure(restart_step, self.policy)
                attempt += 1
                if self.restart_delay:
                    time.sleep(self.restart_delay)

"""Checkpoint-restart supervision for the cluster runtime.

This is the paper's section-3.1 fault story made real: the pool's
failure detector declares a rank dead (``ExecutorFailure``), the
supervisor restores the latest checkpoint, relaunches the world with the
degraded phase-1 ``linear`` backend for ``recovery_steps`` steps (master
relay is the mode the paper falls back to while coping with faults), and
then the workload resumes the fast peer-to-peer backend -- all driven by
the very same ``RecoveryPolicy``/``SupervisorState`` machinery
``train.ft`` previously exercised only against *simulated* failures.

Two workload shapes:

- ``run(make_closure, n)``: one closure owns the whole step loop (the
  PR-1 contract). Each attempt gets a fresh ``ExecutorPool``; a failure
  discards it and relaunches from the latest checkpoint.
- ``run_steps(make_step, n, total_steps)``: each step is its own pooled
  job, so the *same* warm executors serve every step -- and a rank that
  dies **between** jobs (SIGKILL while the pool idles) is caught at the
  next dispatch, checkpoint-restarted exactly like a mid-job death.

The closure contract is unchanged: ``run.comm_for(comm, step)`` applies
the degrade schedule and rank 0 persists state with
``run.save(step, state)``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from ...train import ft
from ..obs.log import get_logger
from .driver import ExecutorFailure, ExecutorPool

_log = get_logger("cluster.supervisor")


@dataclasses.dataclass
class RunContext:
    """What one (re)launch of the world knows about recovery."""
    ckpt_dir: str
    start_step: int                  # first step this launch must execute
    attempt: int                     # 0 on the first launch
    degraded_until: int              # steps <= this use the degrade backend
    fast_backend: str
    degrade_backend: str

    def backend_for(self, step: int) -> str:
        return (self.degrade_backend if step <= self.degraded_until
                else self.fast_backend)

    def comm_for(self, comm, step: int):
        """The communicator to use at ``step`` (same transport, possibly
        degraded algorithm)."""
        want = self.backend_for(step)
        return comm if comm.backend == want else comm.with_backend(want)

    def save(self, step: int, state: dict, meta: dict | None = None) -> str:
        from ...train import checkpoint as CKPT
        return CKPT.save(self.ckpt_dir, step, state, meta)

    def restore(self) -> tuple[dict, dict, int] | None:
        """(flat_leaves, meta, step) of the latest checkpoint, or None."""
        from ...train import checkpoint as CKPT
        if CKPT.latest_step(self.ckpt_dir) is None:
            return None
        return CKPT.load(self.ckpt_dir)


@dataclasses.dataclass
class ClusterSupervisor:
    """Relaunch-from-checkpoint loop above ``ExecutorPool``.

    ``launcher`` is honored on *every* (re)launch: a world built from
    ssh/srun-spawned ranks is restarted the same way, never silently
    degraded to single-host forks."""
    ckpt_dir: str
    policy: ft.RecoveryPolicy = dataclasses.field(
        default_factory=ft.RecoveryPolicy)
    fast_backend: str = "ring"
    timeout: float = 60.0
    hb_interval: float = 0.1
    hb_timeout: float = 1.0
    restart_delay: float = 0.0
    data_plane: str = "direct"
    launcher: Any = None
    bind_host: str = "127.0.0.1"
    advertise_host: str | None = None
    secret: bytes | str | None = None

    def __post_init__(self):
        self.state = ft.SupervisorState()
        self.failures: list[tuple[int, str]] = []   # (restart_step, reason)

    def _latest_step(self) -> int:
        from ...train import checkpoint as CKPT
        return CKPT.latest_step(self.ckpt_dir) or 0

    def _make_pool(self, n: int) -> ExecutorPool:
        return ExecutorPool(n, backend=self.fast_backend,
                            timeout=self.timeout,
                            data_plane=self.data_plane,
                            hb_interval=self.hb_interval,
                            hb_timeout=self.hb_timeout,
                            launcher=self.launcher,
                            bind_host=self.bind_host,
                            advertise_host=self.advertise_host,
                            secret=self.secret)

    def _run_ctx(self, start: int, attempt: int) -> RunContext:
        return RunContext(
            ckpt_dir=self.ckpt_dir,
            start_step=start,
            attempt=attempt,
            degraded_until=self.state.degraded_until,
            fast_backend=self.fast_backend,
            degrade_backend=self.policy.degrade_backend)

    def _on_failure(self, e: ExecutorFailure) -> None:
        restart_step = self._latest_step()
        self.failures.append((restart_step, e.reason))
        _log.warning("rank(s) %s failed (%s); restarting from step %d "
                     "(restart %d/%d)", e.dead_ranks, e.reason,
                     restart_step, self.state.restarts + 1,
                     self.policy.max_restarts)
        # raises once policy.max_restarts is exhausted
        self.state.on_failure(restart_step, self.policy)
        if self.restart_delay:
            time.sleep(self.restart_delay)

    def run(self, make_closure: Callable[[RunContext], Callable], n: int,
            ) -> list[Any]:
        """Run ``make_closure(run_ctx)`` across ``n`` pooled executors,
        restarting from the latest checkpoint on executor death until the
        closure completes or ``policy.max_restarts`` is exhausted."""
        attempt = 0
        while True:
            start = self._latest_step()
            run_ctx = self._run_ctx(start, attempt)
            # every launch starts in the backend the schedule dictates
            launch_backend = run_ctx.backend_for(start + 1)
            pool = None
            try:
                pool = self._make_pool(n)   # spawn failure also restarts
                return pool.run(make_closure(run_ctx),
                                backend=launch_backend)
            except ExecutorFailure as e:
                self._on_failure(e)
                attempt += 1
            finally:
                if pool is not None:
                    pool.shutdown()

    def run_steps(self, make_step: Callable[[RunContext, int], Callable],
                  n: int, total_steps: int,
                  on_step: Callable[[int, ExecutorPool], None] | None = None,
                  ) -> list[Any]:
        """Run ``make_step(run_ctx, step)`` as one pooled job per step,
        keeping the same warm pool across steps. ``on_step(step, pool)``
        is an instrumentation hook invoked after each completed step --
        tests use it to injure the pool *between* jobs. Returns the final
        step's per-rank results."""
        pool: ExecutorPool | None = None
        attempt = 0
        try:
            while True:
                start = self._latest_step()
                run_ctx = self._run_ctx(start, attempt)
                try:
                    if pool is None or pool.broken or pool.closed:
                        if pool is not None:
                            pool.shutdown()
                        pool = self._make_pool(n)
                    outs: list[Any] = []
                    for step in range(start + 1, total_steps + 1):
                        outs = pool.run(make_step(run_ctx, step),
                                        backend=run_ctx.backend_for(step))
                        if on_step is not None:
                            on_step(step, pool)
                    if not outs and total_steps > 0:
                        # resume landed past the final step: its ckpt was
                        # saved but its result frames were lost to the
                        # failure. Surface that loudly -- re-running the
                        # step would double-apply its state update.
                        raise RuntimeError(
                            "final step's results were lost to a failure "
                            "after its checkpoint was saved; state is "
                            "recoverable from the checkpoint but per-rank "
                            "return values are not")
                    return outs
                except ExecutorFailure as e:
                    self._on_failure(e)
                    attempt += 1
        finally:
            if pool is not None:
                pool.shutdown()

"""Elastic supervision for the cluster runtime.

This is the paper's section-3.1 fault story grown into an autoscaler.
The pool's failure detector declares a rank dead (``ExecutorFailure``)
and the supervisor recovers -- in order of preference:

1. **shrink-to-survivors** (``elastic=True``): the pool rebuilds its
   communicator over the live ranks (``shrink_to_survivors``) -- no
   process relaunch, survivors keep their PIDs and warm peer channels --
   and the workload resumes on the degraded phase-1 ``linear`` backend
   for ``recovery_steps`` steps per ``RecoveryPolicy``, exactly like a
   relaunch would. Closures see the shrink through
   ``run_ctx.shrink_info`` (the pool's remap dict) and can reassemble
   lost shards from buddy snapshots (``train.buddy``).
2. **checkpoint-restart relaunch** (the legacy path, and the fallback
   when too few ranks survive or ``elastic`` is off): discard the pool,
   restore the latest disk checkpoint, relaunch the full world through
   the configured launcher.

**Grow-on-join**: a fresh executor that dials the driver mid-job parks
until the next step boundary; ``run_steps`` absorbs it there
(``absorb_joiners``), so the world rides preemptible capacity both ways.

**Proactive suspicion** (``suspect_after``): a rank whose heartbeat age
exceeds the threshold is declared dead *before* the hard ``hb_timeout``
would strand a dispatched job -- ``rank_health()`` RTT/staleness wired
into the failure decision.

**Stragglers**: ``run_steps`` feeds per-step wall time to an optional
``StragglerDetector``; events land in ``SupervisorState.straggler_events``
and fire the ``on_straggler(step, dt, pool)`` hook.

Two workload shapes:

- ``run(make_closure, n)``: one closure owns the whole step loop (the
  PR-1 contract). A failure shrinks (elastic) or relaunches, then
  re-dispatches the closure from the latest checkpoint.
- ``run_steps(make_step, n, total_steps)``: each step is its own pooled
  job on the *same* warm executors; membership changes land between
  steps. Per-step results are persisted beside the checkpoint, so a
  failure after the final step's checkpoint no longer loses the run's
  return values.

The closure contract is unchanged: ``run.comm_for(comm, step)`` applies
the degrade schedule and rank 0 persists state with
``run.save(step, state)``.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import time
from typing import Any, Callable

from ...train import ft
from ..obs.log import get_logger
from .driver import ExecutorFailure, ExecutorPool

_log = get_logger("cluster.supervisor")


@dataclasses.dataclass
class RunContext:
    """What one (re)launch of the world knows about recovery."""
    ckpt_dir: str
    start_step: int                  # first step this launch must execute
    attempt: int                     # 0 on the first launch
    degraded_until: int              # steps <= this use the degrade backend
    fast_backend: str
    degrade_backend: str
    #: ranks this attempt runs on (shrinks/grows move it off the
    #: originally requested n)
    world_size: int = 0
    #: the pool's remap dict right after a shrink-to-survivors recovery
    #: (``old_size``/``old_rank_of``/``dead_old_ranks``...), None
    #: otherwise -- what ``train.buddy.BuddyCheckpointer.recover`` needs
    shrink_info: dict | None = None

    def backend_for(self, step: int) -> str:
        return (self.degrade_backend if step <= self.degraded_until
                else self.fast_backend)

    def comm_for(self, comm, step: int):
        """The communicator to use at ``step`` (same transport, possibly
        degraded algorithm)."""
        want = self.backend_for(step)
        return comm if comm.backend == want else comm.with_backend(want)

    def save(self, step: int, state: dict, meta: dict | None = None) -> str:
        from ...train import checkpoint as CKPT
        return CKPT.save(self.ckpt_dir, step, state, meta)

    def restore(self) -> tuple[dict, dict, int] | None:
        """(flat_leaves, meta, step) of the latest checkpoint, or None."""
        from ...train import checkpoint as CKPT
        if CKPT.latest_step(self.ckpt_dir) is None:
            return None
        return CKPT.load(self.ckpt_dir)


@dataclasses.dataclass
class ClusterSupervisor:
    """Recovery loop above ``ExecutorPool``: shrink-to-survivors first
    (``elastic``), checkpoint-restart relaunch as the last resort.

    ``launcher`` is honored on *every* (re)launch: a world built from
    ssh/srun-spawned ranks is restarted the same way, never silently
    degraded to single-host forks."""
    ckpt_dir: str
    policy: ft.RecoveryPolicy = dataclasses.field(
        default_factory=ft.RecoveryPolicy)
    fast_backend: str = "ring"
    timeout: float = 60.0
    hb_interval: float = 0.1
    hb_timeout: float = 1.0
    restart_delay: float = 0.0
    data_plane: str = "direct"
    launcher: Any = None
    bind_host: str = "127.0.0.1"
    advertise_host: str | None = None
    secret: bytes | str | None = None
    #: recover by shrinking to the survivors instead of relaunching;
    #: full relaunch remains the fallback below ``min_ranks``
    elastic: bool = False
    min_ranks: int = 1
    #: heartbeat age (seconds) that flags a rank dead proactively,
    #: before the hard ``hb_timeout`` strands a dispatched job
    suspect_after: float | None = None
    straggler_detector: ft.StragglerDetector | None = None
    #: called as ``on_straggler(step, dt, pool)`` when the detector
    #: flags a step (after ``SupervisorState.on_straggler`` recorded it)
    on_straggler: Callable | None = None
    #: flushed (``finish()``) when the supervisor shuts down, so no
    #: queued save is lost to process exit
    async_ckpt: Any = None
    #: per-step result files retained beside the checkpoints
    keep_results: int = 3

    def __post_init__(self):
        self.state = ft.SupervisorState()
        self.failures: list[tuple[int, str]] = []   # (restart_step, reason)

    def _latest_step(self) -> int:
        from ...train import checkpoint as CKPT
        return CKPT.latest_step(self.ckpt_dir) or 0

    def _make_pool(self, n: int) -> ExecutorPool:
        return ExecutorPool(n, backend=self.fast_backend,
                            timeout=self.timeout,
                            data_plane=self.data_plane,
                            hb_interval=self.hb_interval,
                            hb_timeout=self.hb_timeout,
                            launcher=self.launcher,
                            bind_host=self.bind_host,
                            advertise_host=self.advertise_host,
                            secret=self.secret)

    def _run_ctx(self, start: int, attempt: int, world_size: int,
                 shrink_info: dict | None = None) -> RunContext:
        return RunContext(
            ckpt_dir=self.ckpt_dir,
            start_step=start,
            attempt=attempt,
            degraded_until=self.state.degraded_until,
            fast_backend=self.fast_backend,
            degrade_backend=self.policy.degrade_backend,
            world_size=world_size,
            shrink_info=shrink_info)

    def _on_failure(self, e: ExecutorFailure) -> None:
        restart_step = self._latest_step()
        self.failures.append((restart_step, e.reason))
        _log.warning("rank(s) %s failed (%s); recovering from step %d "
                     "(recovery %d/%d)", e.dead_ranks, e.reason,
                     restart_step, self.state.restarts + 1,
                     self.policy.max_restarts)
        # raises once policy.max_restarts is exhausted
        self.state.on_failure(restart_step, self.policy)
        if self.restart_delay:
            time.sleep(self.restart_delay)

    # -- elastic helpers ----------------------------------------------------
    def _try_shrink(self, pool: ExecutorPool) -> dict | None:
        """Shrink a broken pool to its survivors; None => caller must
        fall back to a full relaunch (elastic off, nothing survived, or
        below the ``min_ranks`` floor)."""
        if not self.elastic:
            return None
        try:
            info = pool.shrink_to_survivors()
        except (ExecutorFailure, RuntimeError) as e:
            _log.warning("shrink failed (%s); falling back to relaunch", e)
            return None
        if len(info["new_world"]) < max(1, self.min_ranks):
            _log.warning("only %d survivor(s), below min_ranks=%d; "
                         "falling back to relaunch",
                         len(info["new_world"]), self.min_ranks)
            return None
        self.state.shrinks += 1
        return info

    def _suspect_check(self, pool: ExecutorPool) -> None:
        """Proactive failure decision off ``rank_health()``: a rank with
        no sign of life for ``suspect_after`` seconds is declared dead
        now (raising ``ExecutorFailure``) instead of stranding the next
        job until the hard timeout."""
        if self.suspect_after is None:
            return
        sus = [h["rank"] for h in pool.rank_health()
               if h["conn_dead"] or not h["alive"]
               or h["last_seen_age"] > self.suspect_after]
        if sus:
            pool.fail_ranks(
                sus, "suspected dead: no sign of life for "
                f">{self.suspect_after:.2f}s (proactive shrink)")

    def _observe_step(self, step: int, dt: float,
                      pool: ExecutorPool) -> None:
        det = self.straggler_detector
        if det is None or not det.observe(step, dt):
            return
        self.state.on_straggler(step, dt, det.ewma or dt)
        _log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                     step, dt, det.ewma or dt)
        if self.on_straggler is not None:
            self.on_straggler(step, dt, pool)

    def _flush_async_ckpt(self) -> None:
        if self.async_ckpt is None:
            return
        try:
            self.async_ckpt.finish()
        except Exception as e:      # noqa: BLE001 -- shutdown path: a
            _log.warning("async checkpointer flush failed: %s", e)
            # failed background save must not mask the primary outcome

    # -- per-step result persistence ----------------------------------------
    def _results_path(self, step: int) -> str:
        return os.path.join(self.ckpt_dir, f"results_step_{step:08d}.pkl")

    def _save_results(self, step: int, outs: list) -> None:
        """Persist a completed step's per-rank results beside the
        checkpoint (atomic + fsynced), so a later resume landing past
        the final step can still return them."""
        os.makedirs(self.ckpt_dir, exist_ok=True)
        path = self._results_path(step)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump({"step": step, "results": outs}, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, path)
        kept = sorted(d for d in os.listdir(self.ckpt_dir)
                      if d.startswith("results_step_")
                      and not d.endswith(".tmp"))
        for d in kept[:-self.keep_results]:
            try:
                os.unlink(os.path.join(self.ckpt_dir, d))
            except OSError:
                pass

    def _recover_results(self, total_steps: int) -> list:
        """A resume landed past the final step: its checkpoint was saved
        but the result frames were lost to the failure. Recover the
        per-rank results instead of failing the otherwise-successful
        run: (a) the supervisor's persisted result file; (b) a
        ``results`` list the closure stored in its final checkpoint's
        meta; else the legacy loud error."""
        path = self._results_path(total_steps)
        if os.path.exists(path):
            with open(path, "rb") as f:
                return pickle.load(f)["results"]
        from ...train import checkpoint as CKPT
        try:
            if CKPT.latest_step(self.ckpt_dir) == total_steps:
                _, meta, _ = CKPT.load(self.ckpt_dir, total_steps)
                if isinstance(meta, dict) and "results" in meta:
                    return list(meta["results"])
        except (OSError, ValueError, KeyError):
            pass
        raise RuntimeError(
            "final step's results were lost to a failure after its "
            "checkpoint was saved; state is recoverable from the "
            "checkpoint but per-rank return values are not (closures "
            "may store meta={'results': ...} at their final save to "
            "close this hole)")

    # -- workloads ----------------------------------------------------------
    def run_job(self, make_job: Callable[[RunContext], Callable],
                pool: ExecutorPool, *, backend: str | None = None,
                timeout: float | None = None) -> list[Any]:
        """Run one pooled job elastically on a *caller-owned* warm pool.

        Unlike ``run``/``run_steps`` the pool is external state: it is
        never shut down or relaunched here, so recovery is
        shrink-to-survivors only (``elastic=True`` required to recover
        at all) and any materialized state the executors hold -- e.g.
        ``data.dataset``'s partition store -- survives the retry.
        ``make_job(run_ctx)`` sees ``run_ctx.shrink_info`` on a
        post-shrink attempt and re-derives the work the dead ranks lost
        (lineage recompute); raises once ``policy.max_restarts`` is
        exhausted or when the pool cannot shrink."""
        attempt = 0
        shrink_info: dict | None = None
        while True:
            if pool.closed:
                raise RuntimeError("pool is shut down")
            if pool.broken:
                info = self._try_shrink(pool)
                if info is None:
                    raise ExecutorFailure(
                        list(pool.dead_ranks),
                        pool.broken_reason or "pool broken and shrink "
                        "unavailable (elastic off, nothing survived, or "
                        "below min_ranks)")
                shrink_info = info
            self._suspect_check(pool)
            run_ctx = self._run_ctx(self._latest_step(), attempt,
                                    pool.size, shrink_info)
            try:
                return pool.run(make_job(run_ctx),
                                backend=backend or self.fast_backend,
                                timeout=timeout)
            except ExecutorFailure as e:
                self._on_failure(e)
                attempt += 1

    def run(self, make_closure: Callable[[RunContext], Callable], n: int,
            ) -> list[Any]:
        """Run ``make_closure(run_ctx)`` across ``n`` pooled executors,
        recovering from executor death (shrink when ``elastic``, else
        relaunch from the latest checkpoint) until the closure completes
        or ``policy.max_restarts`` is exhausted."""
        attempt = 0
        pool: ExecutorPool | None = None
        shrink_info: dict | None = None
        world_n = n
        try:
            while True:
                start = self._latest_step()
                run_ctx = self._run_ctx(start, attempt, world_n,
                                        shrink_info)
                # every launch starts in the backend the schedule dictates
                launch_backend = run_ctx.backend_for(start + 1)
                try:
                    if pool is None or pool.closed:
                        pool = self._make_pool(world_n)
                    elif pool.broken:
                        info = self._try_shrink(pool)
                        if info is not None:
                            shrink_info = run_ctx.shrink_info = info
                            world_n = len(info["new_world"])
                            run_ctx.world_size = world_n
                        else:
                            pool.shutdown()
                            world_n = n     # full relaunch: full world
                            shrink_info = run_ctx.shrink_info = None
                            run_ctx.world_size = world_n
                            pool = self._make_pool(world_n)
                    return pool.run(make_closure(run_ctx),
                                    backend=launch_backend)
                except ExecutorFailure as e:
                    self._on_failure(e)
                    attempt += 1
        finally:
            if pool is not None:
                pool.shutdown()
            self._flush_async_ckpt()

    def run_steps(self, make_step: Callable[[RunContext, int], Callable],
                  n: int, total_steps: int,
                  on_step: Callable[[int, ExecutorPool], None] | None = None,
                  ) -> list[Any]:
        """Run ``make_step(run_ctx, step)`` as one pooled job per step,
        keeping the same warm pool across steps. ``on_step(step, pool)``
        is an instrumentation hook invoked after each completed step --
        tests use it to injure the pool *between* jobs. Membership
        changes land at step boundaries: joiners are absorbed before a
        step dispatches, failures shrink (elastic) or relaunch between
        attempts. Returns the final step's per-rank results."""
        pool: ExecutorPool | None = None
        attempt = 0
        shrink_info: dict | None = None
        world_n = n
        try:
            while True:
                start = self._latest_step()
                run_ctx = self._run_ctx(start, attempt, world_n,
                                        shrink_info)
                try:
                    if pool is None or pool.closed:
                        pool = self._make_pool(world_n)
                    elif pool.broken:
                        info = self._try_shrink(pool)
                        if info is not None:
                            shrink_info = run_ctx.shrink_info = info
                            world_n = len(info["new_world"])
                            run_ctx.world_size = world_n
                        else:
                            pool.shutdown()
                            world_n = n
                            shrink_info = run_ctx.shrink_info = None
                            run_ctx.world_size = world_n
                            pool = self._make_pool(world_n)
                    outs: list[Any] = []
                    for step in range(start + 1, total_steps + 1):
                        if self.elastic and pool.pending_joins():
                            # grow-on-join lands at the step boundary
                            if pool.absorb_joiners():
                                world_n = pool.size
                                run_ctx.world_size = world_n
                        self._suspect_check(pool)
                        t0 = time.monotonic()
                        outs = pool.run(make_step(run_ctx, step),
                                        backend=run_ctx.backend_for(step))
                        self._observe_step(step, time.monotonic() - t0,
                                           pool)
                        self._save_results(step, outs)
                        # the remap was consumed by this completed step
                        shrink_info = run_ctx.shrink_info = None
                        if on_step is not None:
                            on_step(step, pool)
                    if not outs and total_steps > 0:
                        outs = self._recover_results(total_steps)
                    return outs
                except ExecutorFailure as e:
                    self._on_failure(e)
                    attempt += 1
        finally:
            if pool is not None:
                pool.shutdown()
            self._flush_async_ckpt()

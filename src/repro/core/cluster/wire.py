"""Wire protocol for the cluster transport.

Frames are length-prefixed: ``[4B header len][8B payload len][JSON
header][payload bytes]``. The header carries routing/matching metadata
(``kind``, ``ctx``, ``tag``, ``src``, ``dst``); the payload is an encoded
python object.

The payload codec handles the three shapes the communicator API admits:

- numpy arrays (any standard dtype, plus ml_dtypes names such as
  ``bfloat16``) travel as a manifest entry + raw contiguous bytes -- no
  pickling on the hot path;
- pytrees of arrays (nested dict/list/tuple with JSON-able scalars) are
  walked recursively, each array leaf becoming its own buffer;
- anything else falls back to a pickle buffer.

Encoded layout: ``[4B manifest len][JSON manifest][buffer 0][buffer 1]...``
with every buffer's length recorded in the manifest. Decode walks one
``memoryview`` over the frame -- slicing a memoryview is zero-copy, so an
array payload is materialized by exactly one copy (the ``.copy()`` that
gives the caller a writable array independent of the receive buffer).

Authentication: every control- and data-plane connection starts with an
HMAC-SHA256 challenge-response handshake over a shared secret. The
listener sends a fresh random nonce; the dialer answers with its own
nonce plus ``HMAC(secret, "client" | server_nonce | client_nonce)``; the
listener proves itself back with the mirrored MAC. Both sides end up
holding the *transcript* (the concatenated nonces), and the hello frame
that follows carries ``HMAC(secret, "hello" | transcript | header)`` --
because the transcript is unique per connection, a captured hello can
never be replayed to register on a different connection. A dialer that
skips the handshake (a legacy/no-secret client) sends a hello where an
``auth_reply`` is expected and is disconnected: the protocol fails
closed.
"""
from __future__ import annotations

import hashlib
import hmac as _hmac
import json
import os
import pickle
import secrets as _secrets
import socket
import struct
from typing import Any

import numpy as np

_HDR = struct.Struct(">IQ")          # (header_len, payload_len)
_MLEN = struct.Struct(">I")          # manifest length inside a payload

MAX_FRAME = 1 << 34                  # 16 GiB sanity bound

SECRET_ENV = "MPIGNITE_SECRET"       # fallback secret source for executors
AUTH_TIMEOUT = 10.0                  # handshake must finish inside this
#: frame-size bound for *unauthenticated* reads. Handshake and hello
#: frames are a few hundred bytes; honoring MAX_FRAME before auth would
#: let a rogue dialer pin a 16 GiB buffer per connection just by
#: claiming a huge length prefix.
PREAUTH_MAX_FRAME = 1 << 16


class AuthError(ConnectionError):
    """The peer failed (or refused) the HMAC handshake."""


def load_secret(secret: bytes | str | None = None,
                secret_file: str | None = None) -> bytes | None:
    """Resolve the shared cluster secret: explicit value, then file, then
    the ``MPIGNITE_SECRET`` environment variable, else None. A launcher
    distributes the file; fork children inherit the value in memory.
    Every path strips surrounding whitespace, so a driver handed
    ``open(path).read()`` (trailing newline and all) derives the same
    key as an executor reading the file itself."""
    if secret is not None:
        raw = secret.encode() if isinstance(secret, str) else bytes(secret)
        return raw.strip()
    if secret_file:
        with open(secret_file, "rb") as f:
            return f.read().strip()
    env = os.environ.get(SECRET_ENV)
    return env.encode().strip() if env else None


def generate_secret() -> bytes:
    """A fresh random shared secret (hex, so it survives files/env)."""
    return _secrets.token_hex(16).encode()


def _mac(secret: bytes, *parts: bytes) -> str:
    return _hmac.new(secret, b"|".join(parts), hashlib.sha256).hexdigest()


def _handshake_frame(sock: socket.socket, want_kind: str) -> dict:
    frame = recv_frame(sock, limit=PREAUTH_MAX_FRAME)
    if frame is None:
        raise AuthError("connection closed during auth handshake")
    header = frame[0]
    if header.get("kind") != want_kind:
        raise AuthError(f"expected {want_kind!r} frame during handshake, "
                        f"got {header.get('kind')!r}")
    return header


def server_handshake(sock: socket.socket, secret: bytes,
                     timeout: float = AUTH_TIMEOUT) -> bytes:
    """Listener side of the challenge-response. Returns the connection
    transcript on success; raises ``AuthError`` (the caller must close
    the socket -- the stream is not trustworthy) otherwise. The
    challenge goes out first, so a legacy dialer that leads with a hello
    frame is rejected before any state is touched: fail closed."""
    prev = sock.gettimeout()
    sock.settimeout(timeout)
    try:
        snonce = os.urandom(16)
        send_frame(sock, {"kind": "auth", "nonce": snonce.hex()})
        reply = _handshake_frame(sock, "auth_reply")
        cnonce = bytes.fromhex(reply.get("nonce", ""))
        if len(cnonce) < 8:
            raise AuthError("auth_reply carried no usable nonce")
        want = _mac(secret, b"client", snonce, cnonce)
        if not _hmac.compare_digest(want, reply.get("mac", "")):
            raise AuthError("dialer presented a bad MAC (wrong secret)")
        send_frame(sock, {"kind": "auth_ok",
                          "mac": _mac(secret, b"server", cnonce, snonce)})
        return snonce + cnonce
    except (socket.timeout, ConnectionError, OSError, ValueError,
            TypeError, AttributeError, KeyError) as e:
        # TypeError/AttributeError/KeyError cover attacker-controlled
        # JSON of the wrong shape (int nonce, array header, ...): every
        # malformed frame must become AuthError, never escape and kill
        # the listener's accept/reject loop
        raise AuthError(f"auth handshake failed: {e}") from e
    finally:
        try:
            sock.settimeout(prev)
        except OSError:
            pass


def client_handshake(sock: socket.socket, secret: bytes,
                     timeout: float = AUTH_TIMEOUT) -> bytes:
    """Dialer side: answer the listener's challenge, verify the listener
    knows the secret too (mutual auth), return the transcript."""
    prev = sock.gettimeout()
    sock.settimeout(timeout)
    try:
        challenge = _handshake_frame(sock, "auth")
        snonce = bytes.fromhex(challenge.get("nonce", ""))
        if len(snonce) < 8:
            raise AuthError("challenge carried no usable nonce")
        cnonce = os.urandom(16)
        send_frame(sock, {"kind": "auth_reply", "nonce": cnonce.hex(),
                          "mac": _mac(secret, b"client", snonce, cnonce)})
        ok = _handshake_frame(sock, "auth_ok")
        want = _mac(secret, b"server", cnonce, snonce)
        if not _hmac.compare_digest(want, ok.get("mac", "")):
            raise AuthError("listener presented a bad MAC (wrong secret)")
        return snonce + cnonce
    except (socket.timeout, ConnectionError, OSError, ValueError,
            TypeError, AttributeError, KeyError) as e:
        raise AuthError(f"auth handshake failed: {e}") from e
    finally:
        try:
            sock.settimeout(prev)
        except OSError:
            pass


def hello_mac(secret: bytes, transcript: bytes, header: dict) -> str:
    """MAC binding a hello header to one connection's handshake. The
    transcript nonces are fresh per connection, so this doubles as the
    per-frame nonce that stops replayed registrations."""
    blob = json.dumps({k: v for k, v in header.items() if k != "mac"},
                      sort_keys=True).encode()
    return _mac(secret, b"hello", transcript, blob)


def verify_hello(secret: bytes, transcript: bytes, header: dict) -> bool:
    mac = header.get("mac")
    if not isinstance(mac, str):    # wrong JSON type must not TypeError
        return False
    return _hmac.compare_digest(hello_mac(secret, transcript, header), mac)


# ---------------------------------------------------------------------------
# Payload codec
# ---------------------------------------------------------------------------

def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _is_jax_array(o: Any) -> bool:
    mod = type(o).__module__ or ""
    return mod.startswith("jax") and hasattr(o, "__array__")


def encode_parts(obj: Any) -> list[bytes]:
    """Object -> list of byte chunks (manifest prefix + raw buffers).
    Senders write each chunk with its own sendall, so bulk arrays are
    never concatenated into one giant intermediate bytes object."""
    bufs: list[bytes] = []

    def enc(o):
        if _is_jax_array(o):
            o = np.asarray(o)
        if isinstance(o, np.ndarray) and not o.dtype.hasobject:
            bufs.append(np.ascontiguousarray(o).tobytes())
            return {"t": "nd", "n": len(bufs[-1]), "d": o.dtype.name,
                    "s": list(o.shape)}
        if isinstance(o, (np.integer, np.floating, np.bool_)):
            return {"t": "np", "d": o.dtype.name, "v": o.item()}
        if o is None or isinstance(o, (bool, int, float, str)):
            return {"t": "py", "v": o}
        if isinstance(o, (list, tuple)):
            return {"t": "list" if isinstance(o, list) else "tuple",
                    "v": [enc(x) for x in o]}
        if isinstance(o, dict) and all(isinstance(k, str) for k in o):
            return {"t": "dict", "k": list(o.keys()),
                    "v": [enc(v) for v in o.values()]}
        bufs.append(pickle.dumps(o))
        return {"t": "pkl", "n": len(bufs[-1])}

    manifest = json.dumps(enc(obj)).encode()
    return [_MLEN.pack(len(manifest)), manifest, *bufs]


def encode(obj: Any) -> bytes:
    """Object -> one contiguous self-describing bytes blob."""
    return b"".join(encode_parts(obj))


def decode(data: bytes | bytearray | memoryview) -> Any:
    """Inverse of ``encode``. Malformed input -- truncated buffers,
    corrupted length prefixes, garbage manifests -- raises ``ValueError``
    (never hangs, never escapes as a codec-internal exception type):
    frames cross trust boundaries, so a peer's bad bytes must be a clean,
    catchable error on the receiving rank."""
    try:
        return _decode_strict(data)
    except ValueError:
        raise
    except (struct.error, KeyError, IndexError, TypeError, AttributeError,
            UnicodeDecodeError, json.JSONDecodeError, EOFError,
            pickle.UnpicklingError, ImportError, RecursionError) as e:
        raise ValueError(f"malformed payload: {type(e).__name__}: {e}") from e


def _decode_strict(data: bytes | bytearray | memoryview) -> Any:
    mv = memoryview(data)
    (mlen,) = _MLEN.unpack_from(mv, 0)
    raw_manifest = mv[_MLEN.size:_MLEN.size + mlen]
    if len(raw_manifest) != mlen:
        raise ValueError(f"manifest length {mlen} exceeds payload "
                         f"({len(mv)} bytes)")
    manifest = json.loads(bytes(raw_manifest))
    pos = _MLEN.size + mlen

    def take(n) -> memoryview:
        nonlocal pos
        if not isinstance(n, int) or n < 0 or pos + n > len(mv):
            raise ValueError(f"buffer of {n!r} bytes at offset {pos} "
                             f"overruns payload ({len(mv)} bytes)")
        raw = mv[pos:pos + n]            # memoryview slice: no copy
        pos += n
        return raw

    def dec(node):
        t = node["t"]
        if t == "nd":
            raw = take(node["n"])
            arr = np.frombuffer(raw, dtype=_dtype_from_name(node["d"]))
            return arr.reshape(node["s"]).copy()   # the one copy
        if t == "np":
            return _dtype_from_name(node["d"]).type(node["v"])
        if t == "py":
            return node["v"]
        if t == "list":
            return [dec(x) for x in node["v"]]
        if t == "tuple":
            return tuple(dec(x) for x in node["v"])
        if t == "dict":
            return {k: dec(v) for k, v in zip(node["k"], node["v"])}
        if t == "pkl":
            return pickle.loads(take(node["n"]))
        raise ValueError(f"bad manifest node type {t!r}")

    return dec(manifest)


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------

def pack_frame(header: dict, payload: bytes | list[bytes] = b"") -> bytes:
    """One frame as a single contiguous blob -- byte-identical to what
    ``send_frame`` puts on a socket. Record-oriented transports (the shm
    rings) carry these blobs whole, so the codec and every frame header
    field stay transport-agnostic."""
    parts = [payload] if isinstance(payload, (bytes, bytearray)) else payload
    h = json.dumps(header).encode()
    return b"".join([_HDR.pack(len(h), sum(len(p) for p in parts)), h,
                     *parts])


def unpack_frame(buf: bytes | bytearray | memoryview
                 ) -> tuple[dict, memoryview]:
    """Inverse of ``pack_frame``. The payload comes back as a zero-copy
    memoryview into ``buf``; malformed records raise ``ValueError`` (shm
    ring corruption must be a clean error, like a bad socket frame)."""
    mv = memoryview(buf)
    try:
        hlen, plen = _HDR.unpack_from(mv, 0)
        if _HDR.size + hlen + plen != len(mv):
            raise ValueError(
                f"frame lengths (header={hlen}, payload={plen}) do not "
                f"match record size {len(mv)}")
        header = json.loads(bytes(mv[_HDR.size:_HDR.size + hlen]))
    except (struct.error, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ValueError(f"malformed frame record: {e}") from e
    if not isinstance(header, dict):
        raise ValueError("frame header is not a JSON object")
    return header, mv[_HDR.size + hlen:]


def send_frame(sock: socket.socket, header: dict,
               payload: bytes | list[bytes] = b"", lock=None,
               on_tx=None) -> None:
    """Write one frame. ``payload`` may be one bytes object or a list of
    chunks (from ``encode_parts``); each chunk gets its own sendall, so
    bulk arrays cross without ever being concatenated. ``lock``
    serializes writers sharing a socket. ``on_tx(nbytes)`` fires once
    per frame with the full wire size (prefix + header + payload) --
    the tx mirror of ``recv_exact``'s ``on_bytes``; channel byte
    counters hang off it."""
    parts = [payload] if isinstance(payload, (bytes, bytearray)) else payload
    h = json.dumps(header).encode()
    prefix = _HDR.pack(len(h), sum(len(p) for p in parts)) + h

    def write():
        sock.sendall(prefix)
        for p in parts:
            if p:
                sock.sendall(p)

    if lock is not None:
        with lock:
            write()
    else:
        write()
    if on_tx is not None:
        on_tx(len(prefix) + sum(len(p) for p in parts))


def recv_exact(sock: socket.socket, n: int, on_bytes=None
               ) -> bytearray | None:
    """Read exactly n bytes into one preallocated buffer; None on clean
    EOF at a frame boundary. ``recv_into`` writes straight into the
    buffer, so there is no per-chunk bytes object and no final join copy.
    ``on_bytes(k)`` fires per chunk -- failure detectors use it to treat
    in-flight bulk transfers as proof of liveness."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], min(n - got, 1 << 20))
        if k == 0:
            if got == 0:
                return None
            raise ConnectionError("connection closed mid-frame")
        got += k
        if on_bytes is not None:
            on_bytes(k)
    return buf


def recv_frame(sock: socket.socket, on_bytes=None, limit: int = MAX_FRAME
               ) -> tuple[dict, bytes | bytearray] | None:
    """Read one frame; None on EOF. The payload is the receive buffer
    itself (a bytearray) -- ``decode`` reads it through a memoryview, so
    array payloads incur exactly one copy end to end. ``limit`` bounds
    both lengths *before* any allocation; pre-auth readers pass
    ``PREAUTH_MAX_FRAME`` so unauthenticated dialers cannot demand
    gigabyte buffers."""
    head = recv_exact(sock, _HDR.size)
    if head is None:
        return None
    hlen, plen = _HDR.unpack(head)
    if hlen > limit or plen > limit:
        raise ValueError(f"oversized frame (header={hlen}, payload={plen})")
    h = recv_exact(sock, hlen)
    if h is None:
        raise ConnectionError("connection closed mid-frame")
    header = json.loads(bytes(h))
    payload: bytes | bytearray = b""
    if plen:
        p = recv_exact(sock, plen, on_bytes)
        if p is None:
            raise ConnectionError("connection closed mid-frame")
        payload = p
    return header, payload

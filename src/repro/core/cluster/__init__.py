"""Cluster transport: the paper's cluster deployment with real processes.

- ``wire``       : length-prefixed frames + numpy/pytree payload codec
- ``executor``   : executor process (mailbox over TCP, heartbeats,
                   ``ClusterComm``)
- ``driver``     : ``ClusterFuncRDD`` -- spawn/route/failure-detect
- ``supervisor`` : heartbeat-triggered checkpoint-restart recovery
                   (``ClusterSupervisor``), degrading to the phase-1
                   ``linear`` backend per ``train.ft.RecoveryPolicy``
"""
from . import wire
from .driver import ClusterFuncRDD, ExecutorFailure
from .executor import ClusterComm

__all__ = ["wire", "ClusterFuncRDD", "ExecutorFailure", "ClusterComm",
           "ClusterSupervisor", "RunContext"]


def __getattr__(name):
    # Lazy: supervisor pulls in repro.train (checkpoint/ft), which imports
    # repro.core back -- deferring breaks the cycle at package-init time.
    if name in ("ClusterSupervisor", "RunContext"):
        from . import supervisor
        return getattr(supervisor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Cluster transport: the paper's cluster deployment with real processes.

- ``wire``       : length-prefixed frames + numpy/pytree payload codec
                   (decode through one memoryview -- arrays copy once),
                   HMAC challenge-response auth for both planes
- ``serializer`` : closures -> bytes for pooled job dispatch
- ``launcher``   : how ranks start -- ``ForkLauncher`` (single-host
                   fork) or ``CommandLauncher`` (module-entry CLI via an
                   ssh/srun/kubectl-shaped command template)
- ``executor``   : persistent executor process (job loop, mailbox,
                   heartbeats, direct data-plane channels,
                   ``ClusterComm``); also the ``python -m
                   repro.core.cluster.executor`` remote bootstrap CLI
- ``driver``     : ``ExecutorPool``/``ClusterPool`` -- launch once,
                   broker peer addresses, dispatch jobs, detect failure;
                   ``ClusterFuncRDD`` cold-start wrapper; ``get_pool``
                   warm-pool cache
- ``supervisor`` : elastic recovery (``ClusterSupervisor``) --
                   shrink-to-survivors without relaunch, grow-on-join at
                   step boundaries, proactive suspicion off heartbeat
                   staleness, checkpoint-restart relaunch as the
                   fallback -- degrading to the phase-1 ``linear``
                   backend per ``train.ft.RecoveryPolicy``
"""
from . import wire
from .driver import (ClusterFuncRDD, ClusterPool, ExecutorFailure,
                     ExecutorPool, get_pool, shutdown_pools)
from .executor import ClusterComm
from .launcher import (CommandLauncher, ExecutorSpec, ForkLauncher,
                       Launcher)
from .wire import AuthError, load_secret

__all__ = ["wire", "ClusterFuncRDD", "ClusterPool", "ExecutorFailure",
           "ExecutorPool", "ClusterComm", "ClusterSupervisor", "RunContext",
           "get_pool", "shutdown_pools", "Launcher", "ForkLauncher",
           "CommandLauncher", "ExecutorSpec", "AuthError", "load_secret"]


def __getattr__(name):
    # Lazy: supervisor pulls in repro.train (checkpoint/ft), which imports
    # repro.core back -- deferring breaks the cycle at package-init time.
    if name in ("ClusterSupervisor", "RunContext"):
        from . import supervisor
        return getattr(supervisor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

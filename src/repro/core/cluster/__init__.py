"""Cluster transport: the paper's cluster deployment with real processes.

- ``wire``       : length-prefixed frames + numpy/pytree payload codec
                   (decode through one memoryview -- arrays copy once)
- ``serializer`` : closures -> bytes for pooled job dispatch
- ``executor``   : persistent executor process (job loop, mailbox,
                   heartbeats, direct data-plane channels,
                   ``ClusterComm``)
- ``driver``     : ``ExecutorPool``/``ClusterPool`` -- fork once, broker
                   peer addresses, dispatch jobs, detect failure;
                   ``ClusterFuncRDD`` cold-start wrapper; ``get_pool``
                   warm-pool cache
- ``supervisor`` : failure-triggered checkpoint-restart recovery
                   (``ClusterSupervisor``), degrading to the phase-1
                   ``linear`` backend per ``train.ft.RecoveryPolicy``
"""
from . import wire
from .driver import (ClusterFuncRDD, ClusterPool, ExecutorFailure,
                     ExecutorPool, get_pool, shutdown_pools)
from .executor import ClusterComm

__all__ = ["wire", "ClusterFuncRDD", "ClusterPool", "ExecutorFailure",
           "ExecutorPool", "ClusterComm", "ClusterSupervisor", "RunContext",
           "get_pool", "shutdown_pools"]


def __getattr__(name):
    # Lazy: supervisor pulls in repro.train (checkpoint/ft), which imports
    # repro.core back -- deferring breaks the cycle at package-init time.
    if name in ("ClusterSupervisor", "RunContext"):
        from . import supervisor
        return getattr(supervisor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Driver side of the cluster transport: pool, broker, failure detector.

``ExecutorPool`` is the persistent heart of the data plane. It forks n
executor processes **once**, brokers the peer address exchange (each
executor's hello advertises its data-plane listener; the driver fans the
full map back out in a ``peers`` frame), and then keeps the world warm:
every ``run(fn)`` serializes the closure and dispatches it as a ``job``
frame, so steady-state job latency contains no fork, no connect, and --
in ``data_plane="direct"`` mode -- no driver hop for payload traffic.

The driver keeps only the **control plane**: hello/peers at bootstrap,
job/result dispatch, heartbeats, and exit. ``msg`` frames appear at the
driver only in ``data_plane="relay"`` mode (the PR-1 behavior, kept for
benchmarks and as the executors' fallback when a peer dial fails);
``frame_counts`` records every frame kind the driver sees, which is how
tests *prove* a p2p payload traversed zero driver sockets.

Failure detection is layered: heartbeat staleness (a wedged executor),
control-connection EOF and ``Process.is_alive()`` (an abruptly killed
one -- also checked at job dispatch, so a rank SIGKILLed *between* two
``run()`` calls surfaces immediately), and ``peer_rx`` vouching (a rank
whose own heartbeats stall while peers are actively receiving its
data-plane bytes is *not* declared dead). Any death raises
``ExecutorFailure`` and marks the pool broken; the supervisor layer
turns that into checkpoint-restart recovery with a fresh pool.

``ClusterFuncRDD`` survives as the cold-start wrapper (one transient
pool per ``execute``); ``get_pool`` is the module-level warm-pool cache
keyed by ``(n, backend, data_plane)`` that ``ParallelClosure.execute(
mode="cluster")`` routes through.

Multi-host: executors are started through a pluggable ``Launcher``
(``ForkLauncher`` keeps the single-host fork path; ``CommandLauncher``
spawns the module-entry CLI via an arbitrary command template --
ssh/srun/kubectl shaped). The control listener binds ``bind_host`` and
tells executors to dial ``advertise_host``; every accepted connection
must pass the ``wire`` HMAC handshake and present a MAC-bound hello
before it is registered, and a rejection thread keeps refusing
unauthenticated dials for the pool's whole lifetime.
"""
from __future__ import annotations

import atexit
import collections
import os
import queue
import socket
import stat
import tempfile
import threading
import time
from typing import Any, Callable

from ..matching import env_segment_bytes
from ..obs.log import get_logger
from ..obs.trace import JobTrace, trace_enabled
from . import shm as shm_transport
from . import wire
from .launcher import ExecutorSpec, ForkLauncher, Launcher
from .serializer import dumps_closure

_log = get_logger("cluster.driver")


class ExecutorFailure(RuntimeError):
    """One or more executor processes were declared dead."""

    def __init__(self, dead_ranks: list[int], reason: str):
        self.dead_ranks = dead_ranks
        self.reason = reason
        super().__init__(f"executor rank(s) {dead_ranks} failed: {reason}")


class _ExternalHandle:
    """Handle for a rank that joined from outside any launcher (a
    grow-on-join dial): liveness is judged by its control connection and
    heartbeats alone, and teardown is the ``ctrl``/``exit`` frame -- the
    driver has no process to signal."""

    def __init__(self, pid: int | None):
        self.pid = pid

    def is_alive(self) -> bool:
        return True

    def terminate(self) -> None:
        pass

    def join(self, timeout: float | None = None) -> None:
        pass

    def exit_code(self) -> int | None:
        return None


class ExecutorPool:
    """A persistent world of n executor processes accepting dispatched
    jobs. Usable as a context manager (``ClusterPool`` is the exported
    alias)::

        with ExecutorPool(4) as pool:
            out1 = pool.run(step1)      # same processes,
            out2 = pool.run(step2)      # same peer channels

    ``backend`` is the *default* collective algorithm (``linear`` |
    ``ring`` | ``native``); each ``run`` may override it, because the
    algorithm is a property of the job, not of the transport.

    Membership is *elastic*: every executor ever launched owns a stable
    **slot** (its launch rank -- the index of the per-rank arrays
    below), while the **world** is the ordered list of live slots. A
    ``shrink_to_survivors()`` after a failure, or an
    ``absorb_joiners()`` at a step boundary, renumbers world ranks and
    re-brokers peer addresses under a bumped ``membership_epoch``; jobs
    always dispatch with the world view of their epoch, so no process
    relaunch is needed to keep computing on the survivors.
    """

    def __init__(self, n: int, backend: str = "linear",
                 timeout: float = 60.0, data_plane: str = "direct",
                 hb_interval: float = 0.1, hb_timeout: float = 2.0,
                 launcher: Launcher | None = None,
                 bind_host: str = "127.0.0.1",
                 advertise_host: str | None = None,
                 secret: bytes | str | None = None,
                 shm: bool | None = None):
        if n < 1:
            raise ValueError("cluster mode needs at least one executor")
        if data_plane not in ("direct", "relay"):
            raise ValueError(f"unknown data_plane {data_plane!r}; "
                             "expected 'direct' or 'relay'")

        self.n = n
        #: whether the broker publishes the shared-memory transport map
        #: (None resolves $MPIGNITE_SHM, default on). Executors create
        #: and advertise their ring segments regardless -- disabling
        #: here just means the broker never matches same-host pairs, so
        #: every send rides TCP (the benchmark's comparison baseline).
        self.shm = ((shm_transport.enabled() if shm is None else bool(shm))
                    and data_plane == "direct")
        self.backend = backend
        self.timeout = timeout
        self.data_plane = data_plane
        self.hb_interval = hb_interval
        self.hb_timeout = hb_timeout
        self.closed = False
        self.broken = False
        self._owner_pid = os.getpid()
        self.broken_reason = ""
        self.dead_ranks: list[int] = []
        self.launcher = launcher if launcher is not None else ForkLauncher()
        self.bind_host = bind_host
        self.advertise_host = advertise_host
        self.secret = wire.load_secret(secret) or wire.generate_secret()
        self._secret_path: str | None = None
        #: frames seen at the driver, by kind -- the proof obligation for
        #: the direct data plane is frame_counts["msg"] == 0.
        self.frame_counts: collections.Counter = collections.Counter()
        #: dials refused by the auth layer (bootstrap + rejection thread)
        self.rejected_dials = 0

        self._server = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._server.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._server.bind((bind_host, 0))
        self._server.listen(n)
        port = self._server.getsockname()[1]
        # the address executors dial: an explicit advertise host wins; a
        # wildcard bind with no advertise host degrades to loopback (the
        # single-host case -- multi-host launches must say who they are).
        # NOTE: this is strictly the *driver's* address. Each executor's
        # own data-plane advertise address is a different thing -- set
        # per rank via the CLI's --advertise-host (launcher template),
        # or derived from that rank's route to the driver -- so the spec
        # below deliberately does NOT forward pool advertise_host.
        dial_host = advertise_host or (
            "127.0.0.1" if bind_host in ("0.0.0.0", "::", "") else bind_host)
        self._dial_addr = (dial_host, port)     # what joiners dial too

        #: live slots in world-rank order; ``n`` counts slots ever
        #: launched (the per-slot arrays index it), ``world`` is the
        #: current membership
        self._world: list[int] = list(range(n))
        self._wrank: dict[int, int] = {s: s for s in range(n)}
        self.membership_epoch = 0
        #: authenticated grow-on-join dials parked until absorb_joiners()
        self._pending_joins: list[tuple[socket.socket, dict]] = []
        #: handles of spawn_joiner() processes not yet absorbed
        self._join_handles: list = []

        if self.launcher.needs_secret_file:
            fd, self._secret_path = tempfile.mkstemp(prefix="mpignite-",
                                                     suffix=".secret")
            os.write(fd, self.secret)
            os.close(fd)
            os.chmod(self._secret_path, stat.S_IRUSR | stat.S_IWUSR)

        specs = [ExecutorSpec(
            rank=rank, world=n, driver_host=dial_host, driver_port=port,
            backend=backend, timeout=timeout, hb_interval=hb_interval,
            data_plane=data_plane, bind_host=bind_host,
            secret=self.secret,
            secret_file=self._secret_path) for rank in range(n)]
        self._handles = []
        try:
            for spec in specs:
                self._handles.append(self.launcher.launch(spec))
        except Exception:
            # a half-launched world must not outlive a failed constructor
            # (command-spawned executors are not daemons)
            for h in self._handles:
                try:
                    h.terminate()
                except Exception:       # noqa: BLE001 - best effort
                    pass
            self._server.close()
            if self._secret_path is not None:
                try:
                    os.unlink(self._secret_path)
                except OSError:
                    pass
            raise

        self._conns: list[socket.socket | None] = [None] * n
        self._out_qs: list[queue.Queue] = [queue.Queue(maxsize=128)
                                           for _ in range(n)]
        self._last_seen = [time.time()] * n
        self._conn_dead = [False] * n
        self._peer_rx_seen: dict[tuple[int, int], int] = {}
        self._data_addrs: list[tuple[str, int] | None] = [None] * n
        #: each slot's advertised shm segment as (name, host_token), or
        #: None. The driver owns these names' lifecycle: they are
        #: unlinked when the slot dies, shrinks away, or the pool shuts
        #: down -- a SIGKILL'd rank can therefore never leak /dev/shm.
        self._shm_info: list[tuple[str, str] | None] = [None] * n
        #: latest heartbeat round-trip time per rank (None until the
        #: first hb/hb_ack exchange completes)
        self._rank_rtt: list[float | None] = [None] * n
        #: per-job trace snapshots flushed by executors (rank -> snapshot)
        self._trace_snaps: dict[int, dict] = {}
        #: ``obs.JobTrace`` of the most recent *traced* run() (None
        #: when tracing was off for that job)
        self.last_trace: JobTrace | None = None

        # single-writer state for the job in flight
        self._lock = threading.Lock()
        self._job_lock = threading.Lock()       # one run() at a time
        self._job_seq = 0
        self._cur_job = -1
        self._prev_deadline = 0.0
        self._results: list[Any] = [None] * n
        self._done = [True] * n
        self._errors: list[str | None] = [None] * n
        self._done_event = threading.Event()
        self._error_event = threading.Event()

        # Everything past the launch must tear the world down on
        # failure: command-spawned executors are not daemons, so an
        # exception escaping __init__ without shutdown() would orphan
        # them (plus the server socket and the 0600 secret file).
        try:
            # Each accepted dial is authenticated on its own thread: one
            # stalled or rogue connection (a port scanner on a routable
            # bind) must not serially consume the bootstrap deadline
            # while legitimate executors queue in the listen backlog.
            self._admit_lock = threading.Lock()
            deadline = time.time() + timeout
            try:
                while any(c is None for c in self._conns):
                    # a rank that died before registering (wrong secret
                    # -> exit 3, bad launch command, missing package on
                    # the remote side) fails the bootstrap immediately
                    # with its exit status, not after the full timeout
                    dead = [r for r in range(n)
                            if self._conns[r] is None
                            and not self._handles[r].is_alive()]
                    if dead:
                        codes = {r: self._handles[r].exit_code()
                                 for r in dead}
                        raise ExecutorFailure(
                            dead, "executor exited before registering "
                            f"(exit codes {codes}; 3 = auth refused)")
                    remaining = deadline - time.time()
                    if remaining <= 0:
                        missing = [r for r in range(n)
                                   if self._conns[r] is None]
                        raise ExecutorFailure(
                            missing, "never connected to the driver")
                    self._server.settimeout(min(remaining, 0.25))
                    try:
                        conn, _ = self._server.accept()
                    except socket.timeout:
                        continue
                    conn.setsockopt(socket.IPPROTO_TCP,
                                    socket.TCP_NODELAY, 1)
                    threading.Thread(target=self._admit_one, args=(conn,),
                                     daemon=True).start()
            finally:
                try:
                    self._server.settimeout(None)
                except OSError:
                    pass

            self._writers = [threading.Thread(target=self._writer,
                                              args=(r,), daemon=True)
                             for r in range(n)]
            self._routers = [threading.Thread(target=self._route,
                                              args=(r,), daemon=True)
                             for r in range(n)]
            for t in self._writers:
                t.start()

            # broker the data-plane address exchange before any job
            # runs, using the addresses each executor *advertised*
            if data_plane == "direct":
                self._broker_peers()

            for t in self._routers:
                t.start()

            # keep refusing unauthenticated/rogue dials for the pool's
            # whole life
            self._rejector = threading.Thread(target=self._reject_loop,
                                              daemon=True)
            self._rejector.start()
        except Exception:
            self.shutdown()
            raise

    def _admit_one(self, conn: socket.socket) -> None:
        """Authenticate one dialing executor (own thread): HMAC
        handshake, then a hello MAC-bound to that handshake's transcript
        (so a captured hello cannot re-register on a new connection).
        Any failure -- wrong secret, legacy frame instead of a
        handshake, bad/replayed hello, rank out of range, a rank that
        already registered -- closes the connection and counts a
        rejected dial."""
        try:
            transcript = wire.server_handshake(
                conn, self.secret, timeout=min(self.timeout,
                                               wire.AUTH_TIMEOUT))
            conn.settimeout(min(self.timeout, wire.AUTH_TIMEOUT))
            frame = wire.recv_frame(conn, limit=wire.PREAUTH_MAX_FRAME)
            conn.settimeout(None)
            if frame is None or frame[0].get("kind") != "hello":
                raise wire.AuthError("no hello after handshake")
            header = frame[0]
            if not wire.verify_hello(self.secret, transcript, header):
                raise wire.AuthError("hello MAC invalid (replay?)")
            rank = header["rank"]
            if not (isinstance(rank, int) and 0 <= rank < self.n):
                raise wire.AuthError(f"hello rank {rank!r} out of range")
            addr = header.get("data_addr")
            if self.data_plane == "direct" and not addr:
                # a direct-plane world cannot broker peers without it --
                # fail the dial now, not the broker later
                raise wire.AuthError(f"rank {rank} advertised no data_addr "
                                     "for the direct data plane")
            with self._admit_lock:      # rank claim must be atomic
                if self._conns[rank] is not None:
                    raise wire.AuthError(f"rank {rank} already registered")
                self._data_addrs[rank] = (addr[0], addr[1]) if addr else None
                self._shm_info[rank] = self._hello_shm(header)
                self._last_seen[rank] = time.time()
                self.frame_counts["hello"] += 1
                # publish the connection last: the bootstrap loop treats
                # a non-None conn as a fully-registered rank
                self._conns[rank] = conn
        except (wire.AuthError, ConnectionError, OSError, ValueError,
                KeyError, TypeError, AttributeError, IndexError):
            with self._admit_lock:      # concurrent rejections must not
                self.rejected_dials += 1    # lose increments
            try:
                conn.close()
            except OSError:
                pass

    def _reject_loop(self):
        """Post-bootstrap acceptor. The launched world is complete, so a
        dial claiming a rank is rogue -- but an authenticated dial whose
        hello says ``join`` is a grow-on-join candidate: it is parked in
        ``_pending_joins`` (no world membership, no heartbeat watch)
        until ``absorb_joiners()`` admits it at a step boundary. Every
        other dial runs the handshake (so a wrong-secret dialer learns
        nothing but a refusal) and is closed."""
        while True:
            try:
                conn, _ = self._server.accept()
            except OSError:
                return                  # server closed: pool shut down
            threading.Thread(target=self._postboot_admit, args=(conn,),
                             daemon=True).start()

    def _postboot_admit(self, conn: socket.socket) -> None:
        try:
            transcript = wire.server_handshake(conn, self.secret,
                                               timeout=5.0)
            conn.settimeout(5.0)
            frame = wire.recv_frame(conn, limit=wire.PREAUTH_MAX_FRAME)
            conn.settimeout(None)
            if frame is None or frame[0].get("kind") != "hello":
                raise wire.AuthError("no hello after handshake")
            header = frame[0]
            if not wire.verify_hello(self.secret, transcript, header):
                raise wire.AuthError("hello MAC invalid (replay?)")
            if not header.get("join"):
                raise wire.AuthError("world is complete; only join "
                                     "hellos are admitted")
            if self.data_plane == "direct" and not header.get("data_addr"):
                raise wire.AuthError("joiner advertised no data_addr "
                                     "for the direct data plane")
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._admit_lock:
                if self.closed:
                    raise wire.AuthError("pool is shut down")
                self._pending_joins.append((conn, header))
                self.frame_counts["hello"] += 1
            _log.bound(world=len(self._world)).info(
                "parked join dial (pid %s); %d pending",
                header.get("pid"), len(self._pending_joins))
        except (wire.AuthError, ConnectionError, OSError, ValueError,
                KeyError, TypeError, AttributeError, IndexError):
            with self._admit_lock:
                self.rejected_dials += 1
            try:
                conn.close()
            except OSError:
                pass

    @staticmethod
    def _hello_shm(header: dict) -> tuple[str, str] | None:
        """A hello's advertised shm segment, validated: both fields must
        be strings and the segment name must carry the transport prefix
        (the hello is MAC-bound, so this is shape-checking, not auth)."""
        seg, host = header.get("shm_seg"), header.get("shm_host")
        if (isinstance(seg, str) and isinstance(host, str)
                and seg.startswith(shm_transport.SEG_PREFIX)):
            return (seg, host)
        return None

    def _unlink_shm(self, slots) -> None:
        """Reap the named slots' shm segments (idempotent; the driver is
        the sole owner of segment names)."""
        for s in slots:
            info = self._shm_info[s] if 0 <= s < len(self._shm_info) \
                else None
            if info is not None and shm_transport.unlink(info[0]):
                _log.bound(world=len(self._world)).debug(
                    "unlinked shm segment %s of slot %d", info[0], s)

    # -- elastic membership -------------------------------------------------
    @property
    def size(self) -> int:
        """Current world size (may differ from ``n``, the slots ever
        launched, after a shrink or grow)."""
        return len(self._world)

    @property
    def world(self) -> list[int]:
        """Live slots in world-rank order: ``world[w]`` is the slot
        (stable launch identity) of world rank ``w``."""
        return list(self._world)

    def _broker_peers(self) -> None:
        """(Re-)send the peers frame to every world member: data-plane
        addresses keyed by *world rank* for the current membership
        epoch. Executors receiving a bumped epoch evict their peer
        channels (the rank->address mapping changed meaning) and clear
        any peer-death poison -- the new world is healthy."""
        addrs = {}
        if self.data_plane == "direct":
            addrs = {str(w): list(self._data_addrs[s])
                     for w, s in enumerate(self._world)}
        note = {"kind": "peers", "addrs": addrs,
                "mepoch": self.membership_epoch}
        if self.shm:
            # the shm tier's routing table: per world rank, the host
            # token (senders compare against their own), the inbound
            # segment name, and the *stable slot* (the ring index a
            # sender uses in every receiver's segment -- slots never
            # renumber, so attachments survive re-brokering)
            note["shm"] = {
                str(w): {"seg": self._shm_info[s][0],
                         "host": self._shm_info[s][1], "slot": s}
                for w, s in enumerate(self._world)
                if self._shm_info[s] is not None}
        for s in self._world:
            self._out_qs[s].put((note, b""))

    def pending_joins(self) -> int:
        """Authenticated joiners parked and waiting to be absorbed."""
        with self._admit_lock:
            return len(self._pending_joins)

    def spawn_joiner(self):
        """Launch a fresh executor process that dials this driver as a
        grow-on-join candidate (rank -1). It authenticates, parks, and
        is absorbed by the next ``absorb_joiners()``. Returns the
        launcher handle."""
        spec = ExecutorSpec(
            rank=-1, world=len(self._world),
            driver_host=self._dial_addr[0], driver_port=self._dial_addr[1],
            backend=self.backend, timeout=self.timeout,
            hb_interval=self.hb_interval, data_plane=self.data_plane,
            bind_host=self.bind_host, secret=self.secret,
            secret_file=self._secret_path)
        handle = self.launcher.launch(spec)
        self._join_handles.append(handle)
        return handle

    def _claim_join_handle(self, pid):
        for i, h in enumerate(self._join_handles):
            if pid is not None and h.pid == pid:
                return self._join_handles.pop(i)
        return _ExternalHandle(pid)

    def absorb_joiners(self) -> list[int]:
        """Admit every parked joiner into the world (call at a step
        boundary -- never mid-job): each gets the next launch slot, a
        ``welcome`` frame assigning its slot + the new world size, and
        the whole world is re-brokered under a bumped membership epoch.
        Returns the new slots (empty if nobody was waiting)."""
        with self._job_lock:
            if self.closed:
                raise RuntimeError("pool is shut down")
            if self.broken:
                raise ExecutorFailure(self.dead_ranks,
                                      "cannot grow a broken pool; shrink "
                                      "or relaunch first")
            with self._admit_lock:
                joins, self._pending_joins = self._pending_joins, []
            if not joins:
                return []
            new_slots = []
            for conn, header in joins:
                slot = self.n
                self.n += 1
                addr = header.get("data_addr")
                self._conns.append(conn)
                self._out_qs.append(queue.Queue(maxsize=128))
                self._last_seen.append(time.time())
                self._conn_dead.append(False)
                self._data_addrs.append((addr[0], addr[1]) if addr
                                        else None)
                self._shm_info.append(self._hello_shm(header))
                self._rank_rtt.append(None)
                self._handles.append(
                    self._claim_join_handle(header.get("pid")))
                self._world.append(slot)
                new_slots.append(slot)
                w = threading.Thread(target=self._writer, args=(slot,),
                                     daemon=True)
                self._writers.append(w)
                w.start()
            self.membership_epoch += 1
            with self._lock:
                self._wrank = {s: w for w, s in enumerate(self._world)}
            for slot in new_slots:
                # welcome first: ordered control socket => the joiner
                # learns its slot before the peers frame that follows
                self._out_qs[slot].put(
                    ({"kind": "ctrl", "op": "welcome", "rank": slot,
                      "size": len(self._world),
                      "mepoch": self.membership_epoch}, b""))
                r = threading.Thread(target=self._route, args=(slot,),
                                     daemon=True)
                self._routers.append(r)
                r.start()
            self._broker_peers()
            _log.bound(world=len(self._world)).info(
                "absorbed %d joiner(s) as slot(s) %s (epoch %d)",
                len(new_slots), new_slots, self.membership_epoch)
            return new_slots

    def shrink_to_survivors(self) -> dict:
        """Rebuild the world over the live ranks of a *broken* pool --
        the elastic alternative to discarding it: survivors keep their
        processes (and PIDs), get contiguous new world ranks in the old
        order, and a re-brokered peers map under a bumped membership
        epoch. Returns a remap-info dict::

            {"old_size", "old_world", "new_world",
             "dead_slots", "dead_old_ranks", "old_rank_of"}

        where ``old_rank_of[w]`` is new world rank ``w``'s rank in the
        *previous* epoch (what buddy-snapshot recovery needs to locate
        shards). Raises ``ExecutorFailure`` if nothing survives."""
        with self._job_lock:
            if self.closed:
                raise RuntimeError("pool is shut down")
            if not self.broken:
                raise RuntimeError("pool is not broken; nothing to "
                                   "shrink from")
            old_world = list(self._world)
            dead = set(self.dead_ranks)
            for s in old_world:     # catch deaths since the failure
                if self._conn_dead[s] or not self._handles[s].is_alive():
                    dead.add(s)
            survivors = [s for s in old_world if s not in dead]
            if not survivors:
                raise ExecutorFailure(sorted(dead),
                                      "no survivors to shrink to")
            info = {
                "old_size": len(old_world),
                "old_world": old_world,
                "new_world": list(survivors),
                "dead_slots": sorted(d for d in dead if d in old_world),
                "dead_old_ranks": [old_world.index(d)
                                   for d in sorted(dead)
                                   if d in old_world],
                "old_rank_of": [old_world.index(s) for s in survivors],
            }
            self._world = survivors
            self.membership_epoch += 1
            with self._lock:
                self._wrank = {s: w for w, s in enumerate(survivors)}
                # a dead rank can never deliver its result for the
                # failed job -- mark its straggler slot done so the next
                # dispatch's drain only waits on *live* stragglers
                # instead of idling out the failed job's whole deadline
                for r in info["dead_old_ranks"]:
                    if r < len(self._done):
                        self._done[r] = True
                if self._done and all(self._done):
                    self._done_event.set()
            now = time.time()
            for s in survivors:
                self._last_seen[s] = now
            self.broken = False
            self.broken_reason = ""
            self.dead_ranks = []
            for s in info["dead_slots"]:    # reap, don't leak zombies
                try:
                    self._handles[s].terminate()
                    self._handles[s].join(timeout=0.5)
                except Exception:   # noqa: BLE001 - best effort
                    pass
            self._unlink_shm(info["dead_slots"])    # nor /dev/shm names
            self._broker_peers()
            _log.bound(world=len(survivors)).warning(
                "shrunk to survivors %s (epoch %d; lost %s)", survivors,
                self.membership_epoch, info["dead_slots"])
            return info

    def fail_ranks(self, ranks: list[int], reason: str) -> None:
        """Externally declare slots dead -- the supervisor's proactive
        suspicion path (heartbeat age over its threshold long before the
        hard timeout). Marks the pool broken, notifies survivors, and
        raises ``ExecutorFailure`` exactly like an organic detection."""
        self._mark_broken(list(ranks), reason)

    @property
    def data_addrs(self) -> list[tuple[str, int] | None]:
        """Each rank's advertised data-plane address (None in relay
        mode) -- what the driver brokered to peers."""
        return list(self._data_addrs)

    @property
    def control_addr(self) -> tuple[str, int]:
        """The (host, port) the control-plane listener is bound to."""
        host, port = self._server.getsockname()[:2]
        return host, port

    # -- context manager ----------------------------------------------------
    def __enter__(self) -> "ExecutorPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    @property
    def pids(self) -> list[int]:
        return [h.pid for h in self._handles]

    # -- driver threads -----------------------------------------------------
    def _writer(self, rank: int):
        """Sole writer for one control connection: drains the rank's
        outbound queue so no *reader* ever blocks on a slow destination.
        Keeps consuming after a write error (frames are dropped); a None
        sentinel ends the thread."""
        conn, q = self._conns[rank], self._out_qs[rank]
        broken = False
        while True:
            item = q.get()
            if item is None:
                return
            if broken:
                continue
            header, payload = item
            try:
                wire.send_frame(conn, header, payload)
            except (ConnectionError, OSError):
                broken = True

    def _route(self, rank: int):
        """Read one rank's control frames: liveness, results, and (relay
        mode) msg forwarding. *Any* inbound bytes count as liveness, so a
        rank mid-way through a bulk relay transfer is never declared dead
        while its data is flowing; ``peer_rx`` maps inside heartbeats
        extend the same courtesy to data-plane traffic the driver never
        sees. EOF outside shutdown marks the rank's connection dead --
        the fast path for detecting an abruptly killed process."""
        conn = self._conns[rank]

        def alive(_nbytes):
            self._last_seen[rank] = time.time()

        try:
            while True:
                frame = wire.recv_frame(conn, on_bytes=alive)
                if frame is None:
                    break
                alive(0)
                header, payload = frame
                kind = header.get("kind")
                self.frame_counts[kind] += 1
                if kind == "msg":
                    # relay mode addresses world ranks: map through the
                    # membership to the destination's slot queue
                    try:
                        dst_slot = self._world[header["dst"]]
                    except IndexError:
                        continue    # straggler for a smaller, older world
                    self._out_qs[dst_slot].put((header, payload))
                elif kind == "hb":
                    rtt = header.get("rtt")
                    if rtt is not None:
                        self._rank_rtt[rank] = float(rtt)
                    try:
                        # echo the executor's timestamp so it can measure
                        # the control-plane round trip; a backlogged
                        # writer just skips this RTT sample
                        self._out_qs[rank].put_nowait(
                            ({"kind": "hb_ack", "t": header["t"]}, b""))
                    except queue.Full:
                        pass
                    for src, count in (header.get("peer_rx") or {}).items():
                        # watermark per (reporter, source): another peer's
                        # higher historical count must not mask fresh
                        # progress on this edge. Keys are slots (stable
                        # data-plane identities).
                        k = (rank, int(src))
                        if (0 <= int(src) < len(self._last_seen)
                                and count > self._peer_rx_seen.get(k, -1)):
                            self._peer_rx_seen[k] = count
                            self._last_seen[int(src)] = time.time()
                elif kind == "trace":
                    # per-rank trace snapshot: the final flush arrives
                    # just before the result frame on the same (ordered)
                    # control socket, so it is always stored by the time
                    # run() returns -- and traced executors also stream
                    # cumulative snapshots mid-job (trace_flush_interval),
                    # each replacing the previous, so a partial JobTrace
                    # is published immediately: a hung, SIGSTOPped or
                    # killed job still leaves its spans on last_trace.
                    with self._lock:
                        wr = self._wrank.get(rank)
                        if header.get("job") == self._cur_job \
                                and wr is not None:
                            self._trace_snaps[wr] = wire.decode(payload)
                            self.last_trace = JobTrace(
                                self._cur_job, len(self._world),
                                dict(self._trace_snaps))
                elif kind == "result":
                    with self._lock:
                        wr = self._wrank.get(rank)
                        if (header.get("job") != self._cur_job
                                or wr is None or wr >= len(self._done)):
                            continue        # straggler from an aborted job
                        if header["ok"]:
                            self._results[wr] = wire.decode(payload)
                        else:
                            self._errors[wr] = wire.decode(payload)
                            self._error_event.set()
                        self._done[wr] = True
                        if all(self._done):
                            self._done_event.set()
        except (ConnectionError, OSError, ValueError) as e:
            if not self.closed:
                _log.bound(rank=rank, world=self.n).debug(
                    "control connection lost: %s", e)
        if not self.closed:
            self._conn_dead[rank] = True

    # -- job dispatch -------------------------------------------------------
    def _health_check(self) -> None:
        dead = [s for s in self._world
                if self._conn_dead[s] or not self._handles[s].is_alive()]
        if dead:
            self._mark_broken(dead, "executor process died between jobs")

    def rank_health(self) -> list[dict]:
        """Per-member liveness snapshot for the current world:
        process/connection state, seconds since the last sign of life
        (any control bytes, or a peer_rx vouch), and the latest
        heartbeat round-trip time (None until the first hb/hb_ack
        exchange completes). ``rank`` is the stable slot (what
        ``fail_ranks`` takes); ``world_rank`` its current position."""
        now = time.time()
        return [{"rank": s,
                 "world_rank": w,
                 "alive": self._handles[s].is_alive(),
                 "conn_dead": self._conn_dead[s],
                 "last_seen_age": max(0.0, now - self._last_seen[s]),
                 "rtt": self._rank_rtt[s]}
                for w, s in enumerate(self._world)]

    def _mark_broken(self, dead: list[int], reason: str):
        _log.bound(world=len(self._world)).warning(
            "marking pool broken: rank(s) %s -- %s", sorted(set(dead)),
            reason)
        self.broken = True
        self.dead_ranks = sorted(set(self.dead_ranks) | set(dead))
        self.broken_reason = self.broken_reason or reason
        # reap the dead ranks' shm segments now: a SIGKILL'd process
        # cannot unlink its own advertisement, and survivors keep any
        # mapping they already hold (unlink removes the name, not maps)
        self._unlink_shm(sorted(set(dead)))
        # tell the survivors before raising: their blocked receives and
        # in-flight nonblocking requests must fail with PeerDeadError
        # now, not hang out their full receive timeouts
        note = {"kind": "ctrl", "op": "peer_dead",
                "ranks": sorted(set(dead)), "reason": reason}
        for s in self._world:
            if s not in dead and not self._conn_dead[s]:
                try:
                    self._out_qs[s].put_nowait((note, b""))
                except queue.Full:
                    pass        # writer backlogged: the timeout still bounds
        raise ExecutorFailure(sorted(set(dead)), reason)

    def run(self, fn: Callable, backend: str | None = None,
            timeout: float | None = None,
            segment_bytes: int | None = None,
            trace: bool | None = None) -> list:
        """Dispatch ``fn`` to every executor as one job; return the list
        of per-rank results (the paper: 'an array of return values from
        each process'). ``segment_bytes`` travels with the job (like
        ``backend``) and tunes the segmented ring schedules inside the
        executors; None resolves to the *driver's*
        $MPIGNITE_SEGMENT_BYTES at dispatch, so every rank of a job
        always computes segmentation from one shared value -- executors
        on hosts with divergent env cannot build incompatible schedules
        (a closure can still retune via ``comm.with_segment_bytes``).
        ``trace`` enables per-rank runtime tracing for the job (None =
        the driver's $MPIGNITE_TRACE); each executor flushes its event
        buffer back on the control plane and the merged ``obs.JobTrace``
        lands on ``self.last_trace``. Raises ``ExecutorFailure`` on rank
        death, ``RuntimeError`` with the remote traceback on a closure
        error, ``TimeoutError`` on a deadlocked closure."""
        with self._job_lock:
            if self.closed:
                raise RuntimeError("pool is shut down")
            if self.broken:
                raise ExecutorFailure(self.dead_ranks,
                                      self.broken_reason or "pool broken")
            self._health_check()

            # Drain stragglers from a previous *errored* job first: the
            # executor main thread serves jobs serially, so a rank still
            # blocked in the old closure (because its partner raised)
            # must unblock -- its own receive timeout bounds this --
            # before the new job's deadline starts ticking. Otherwise a
            # short-timeout follow-up job would spuriously brick a
            # healthy pool.
            grace = self._prev_deadline + 1.0
            while not all(self._done) and time.time() < grace:
                time.sleep(min(self.hb_interval, 0.05))

            blob = dumps_closure(fn)
            job_timeout = self.timeout if timeout is None else timeout
            job_backend = self.backend if backend is None else backend
            # tracing resolves at the *driver* (like segment_bytes), so
            # one shared decision reaches every rank of the job
            job_traced = trace_enabled() if trace is None else bool(trace)
            world = list(self._world)
            k = len(world)
            with self._lock:
                self._job_seq += 1
                job_id = self._cur_job = self._job_seq
                self._results = [None] * k
                self._done = [False] * k
                self._errors = [None] * k
                self._done_event = threading.Event()
                self._error_event = threading.Event()
                done_event, error_event = self._done_event, self._error_event
                self._trace_snaps = {}
                self.last_trace = None
            job_seg = (env_segment_bytes() if segment_bytes is None
                       else int(segment_bytes))
            now = time.time()
            for w, s in enumerate(world):
                # each slot gets its world identity for this epoch
                header = {"kind": "job", "job": job_id,
                          "backend": job_backend, "timeout": job_timeout,
                          "segment_bytes": job_seg, "trace": job_traced,
                          "rank": w, "size": k,
                          "mepoch": self.membership_epoch}
                self._last_seen[s] = now    # fresh grace period per job
                self._out_qs[s].put((header, blob))

            deadline = time.time() + job_timeout
            self._prev_deadline = deadline
            while not done_event.is_set():
                if done_event.wait(self.hb_interval):
                    break
                if error_event.is_set():
                    break
                now = time.time()
                dead = [s for w, s in enumerate(world)
                        if not self._done[w]
                        and (self._conn_dead[s]
                             or not self._handles[s].is_alive()
                             or now - self._last_seen[s] > self.hb_timeout)]
                if dead:
                    self._raise_executor_errors()       # root cause first
                    reason = ("connection closed (heartbeats ended)"
                              if any(self._conn_dead[r] for r in dead)
                              else "missed heartbeats for "
                                   f">{self.hb_timeout:.1f}s")
                    self._mark_broken(dead, reason)
                if now > deadline:
                    self._raise_executor_errors()       # root cause first
                    self.broken = True      # ranks may be wedged mid-closure
                    self.broken_reason = "job deadline exceeded"
                    raise TimeoutError(
                        "cluster closure deadlocked (implicit barrier at "
                        "closure end never reached)")
            self._raise_executor_errors()
            if job_traced:
                with self._lock:
                    snaps = dict(self._trace_snaps)
                self.last_trace = JobTrace(job_id, k, snaps)
            return list(self._results)

    def job_trace(self) -> JobTrace | None:
        """The merged ``obs.JobTrace`` of the most recent traced
        ``run()`` (None when that job ran untraced)."""
        return self.last_trace

    def _raise_executor_errors(self):
        # _cur_job stays put: stragglers of an errored job keep recording
        # into its arrays (the drain in run() watches them), and the next
        # dispatch swaps job id + arrays together under the lock.
        with self._lock:
            failed = [(r, e) for r, e in enumerate(self._errors)
                      if e is not None]
        if failed:
            raise RuntimeError("\n".join(
                f"executor rank {r} raised:\n{e}" for r, e in failed))

    # -- teardown -----------------------------------------------------------
    def shutdown(self) -> None:
        """Graceful exit: ask every executor to leave, then escalate."""
        if self.closed or os.getpid() != self._owner_pid:
            return      # fork-safety: only the creating process tears down
        self.closed = True
        with self._admit_lock:
            joins, self._pending_joins = self._pending_joins, []
        for conn, header in joins:      # parked joiners: polite exit
            try:
                wire.send_frame(conn, {"kind": "ctrl", "op": "exit"})
            except (ConnectionError, OSError):
                pass
            try:
                conn.close()
            except OSError:
                pass
        for h in self._join_handles:    # spawned but never absorbed
            try:
                h.terminate()
                h.join(timeout=2.0)
            except Exception:   # noqa: BLE001 - best effort
                pass
        self._join_handles = []
        for conn, q in zip(self._conns, self._out_qs):
            if conn is None:
                continue
            try:
                q.put_nowait(({"kind": "ctrl", "op": "exit"}, b""))
            except queue.Full:
                pass
        for h in self._handles:
            h.join(timeout=2.0)
        for h in self._handles:
            if h.is_alive():
                h.terminate()
                h.join(timeout=2.0)
        for conn in self._conns:
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
        for q in self._out_qs:  # connections closed => writers drain fast
            q.put(None)
        try:
            self._server.close()
        except OSError:
            pass
        # every advertised segment dies with the pool -- normal exits
        # close their own maps, and the unlink here guarantees the
        # *names* are gone even for ranks that had to be terminated
        self._unlink_shm(range(len(self._shm_info)))
        if self._secret_path is not None:
            try:
                os.unlink(self._secret_path)
            except OSError:
                pass
            self._secret_path = None


#: context-manager spelling from the issue; same object.
ClusterPool = ExecutorPool


# ---------------------------------------------------------------------------
# Module-level warm-pool cache: ParallelClosure.execute(mode="cluster")
# routes here, so repeated execute() calls hit live executors.
# ---------------------------------------------------------------------------

_POOLS: dict[tuple, ExecutorPool] = {}
_POOLS_LOCK = threading.Lock()


def get_pool(n: int, backend: str = "linear", data_plane: str = "direct",
             timeout: float = 60.0, hb_interval: float = 0.1,
             hb_timeout: float = 2.0, launcher: Launcher | None = None,
             bind_host: str = "127.0.0.1", advertise_host: str | None = None,
             secret: bytes | str | None = None,
             shm: bool | None = None) -> ExecutorPool:
    """The warm pool for this transport configuration -- created on
    first use, replaced transparently if a failure broke the cached one.
    The backend is deliberately *not* part of the key: it is a per-job
    parameter (``pool.run(fn, backend=...)``), so closures running
    linear and ring collectives share one executor world; ``backend``
    here only seeds a new pool's default. Everything that shapes the
    *world itself* -- launcher, binds, secret -- IS part of the key, so
    asking for a differently-launched or differently-credentialed pool
    never silently hands back an incompatible cached one."""
    # launcher=None and an explicit ForkLauncher() start identical
    # worlds -- normalize so they share one cached pool
    launcher_key = (launcher if launcher is not None
                    else ForkLauncher()).cache_key()
    secret_key = wire.load_secret(secret)
    # shm participates in the key *resolved* (None -> the env default),
    # so a benchmark holding one shm-on and one shm-off pool warm at
    # the same time gets two distinct worlds, while callers passing
    # None and the matching explicit value share one.
    shm_key = shm_transport.enabled() if shm is None else bool(shm)
    key = (n, data_plane, launcher_key, bind_host, advertise_host,
           secret_key, shm_key)
    with _POOLS_LOCK:
        pool = _POOLS.get(key)
        if pool is not None and not (pool.broken or pool.closed):
            return pool
        if pool is not None:
            pool.shutdown()
        pool = ExecutorPool(n, backend=backend, timeout=timeout,
                            data_plane=data_plane, hb_interval=hb_interval,
                            hb_timeout=hb_timeout, launcher=launcher,
                            bind_host=bind_host,
                            advertise_host=advertise_host, secret=secret,
                            shm=shm)
        _POOLS[key] = pool
        return pool


def shutdown_pools() -> None:
    """Tear down every cached warm pool (atexit, or tests that want a
    cold world)."""
    with _POOLS_LOCK:
        pools = list(_POOLS.values())
        _POOLS.clear()
    for pool in pools:
        pool.shutdown()


atexit.register(shutdown_pools)


class ClusterFuncRDD:
    """RDD-of-a-function executed across real OS processes -- the
    *cold-start* wrapper: one transient ``ExecutorPool`` per
    ``execute()``, so every call pays fork + connect + broker (the PR-1
    cost model; benchmarks use it as the baseline the warm pool beats).

    ``backend`` picks the collective algorithm family inside the
    executors: ``linear`` (paper phase-1 master relay), ``ring`` (phase-2
    peer-to-peer) or ``native`` (alias of linear, for closure portability
    with the SPMD backend -- see ``matching.normalize_backend``).
    ``data_plane`` picks where ``msg`` frames travel: ``direct`` peer
    sockets (default) or ``relay`` through the driver (PR-1 behavior).
    """

    def __init__(self, fn: Callable, timeout: float = 60.0,
                 backend: str = "linear", hb_interval: float = 0.1,
                 hb_timeout: float = 2.0, data_plane: str = "direct",
                 launcher: Launcher | None = None,
                 bind_host: str = "127.0.0.1",
                 advertise_host: str | None = None,
                 secret: bytes | str | None = None):
        self._fn = fn
        self._timeout = timeout
        self._backend = backend
        self._hb_interval = hb_interval
        self._hb_timeout = hb_timeout
        self._data_plane = data_plane
        self._launcher = launcher
        self._bind_host = bind_host
        self._advertise_host = advertise_host
        self._secret = secret
        self.last_trace: JobTrace | None = None

    def execute(self, n: int) -> list:
        pool = ExecutorPool(n, backend=self._backend, timeout=self._timeout,
                            data_plane=self._data_plane,
                            hb_interval=self._hb_interval,
                            hb_timeout=self._hb_timeout,
                            launcher=self._launcher,
                            bind_host=self._bind_host,
                            advertise_host=self._advertise_host,
                            secret=self._secret)
        try:
            out = pool.run(self._fn)
            self.last_trace = pool.last_trace
            return out
        finally:
            pool.shutdown()

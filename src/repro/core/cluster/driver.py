"""Driver side of the cluster transport: spawn, route, detect failure.

``ClusterFuncRDD.execute(n)`` is the process-separated twin of the local
``ParallelFuncRDD``: it forks n executor processes, accepts one TCP
connection per rank, and then acts as the message router the paper's
Spark driver RPC endpoints play -- every ``msg`` frame an executor sends
is forwarded to the destination rank's connection, where the receiving
executor buffers it in its matched mailbox.

Failure detection is heartbeat-based: executors announce liveness every
``hb_interval`` seconds and the driver's monitor declares a rank dead
when its announcements go quiet for ``hb_timeout`` seconds (a dead
process stops heartbeating because its socket closes; a wedged one stops
because its closure stalled the process). Death of any rank aborts the
world with ``ExecutorFailure`` -- the supervisor layer
(``cluster.supervisor``) turns that into checkpoint-restart recovery.
"""
from __future__ import annotations

import multiprocessing
import queue
import socket
import threading
import time
from typing import Any, Callable

from . import wire
from .executor import executor_main


class ExecutorFailure(RuntimeError):
    """One or more executor processes were declared dead."""

    def __init__(self, dead_ranks: list[int], reason: str):
        self.dead_ranks = dead_ranks
        self.reason = reason
        super().__init__(f"executor rank(s) {dead_ranks} failed: {reason}")


class ClusterFuncRDD:
    """RDD-of-a-function executed across real OS processes.

    ``backend`` picks the collective algorithm family inside the
    executors: ``linear`` (paper phase-1 master relay), ``ring`` (phase-2
    peer-to-peer) or ``native`` (alias of linear, for closure portability
    with the SPMD backend -- see ``matching.normalize_backend``).
    """

    def __init__(self, fn: Callable, timeout: float = 60.0,
                 backend: str = "linear", hb_interval: float = 0.1,
                 hb_timeout: float = 2.0):
        self._fn = fn
        self._timeout = timeout
        self._backend = backend
        self._hb_interval = hb_interval
        self._hb_timeout = hb_timeout

    def execute(self, n: int) -> list:
        if n < 1:
            raise ValueError("cluster mode needs at least one executor")
        try:
            mp = multiprocessing.get_context("fork")
        except ValueError as e:  # pragma: no cover - non-POSIX platforms
            raise RuntimeError(
                "cluster mode requires the fork start method (POSIX); use "
                "mode='local' here") from e

        server = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        server.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        server.bind(("127.0.0.1", 0))
        server.listen(n)
        port = server.getsockname()[1]

        procs = [mp.Process(
            target=executor_main,
            args=(self._fn, rank, n, port, self._backend, self._timeout,
                  self._hb_interval),
            daemon=True) for rank in range(n)]
        for p in procs:
            p.start()

        conns: list[socket.socket | None] = [None] * n
        out_qs: list[queue.Queue] = [queue.Queue(maxsize=128)
                                     for _ in range(n)]
        last_seen = [time.time()] * n
        results: list[Any] = [None] * n
        done = [False] * n
        errors: list[str | None] = [None] * n
        done_event = threading.Event()
        error_event = threading.Event()
        lock = threading.Lock()

        try:
            server.settimeout(self._timeout)
            pending = n
            while pending:
                conn, _ = server.accept()
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                frame = wire.recv_frame(conn)
                if frame is None or frame[0].get("kind") != "hello":
                    conn.close()
                    continue
                rank = frame[0]["rank"]
                conns[rank] = conn
                last_seen[rank] = time.time()
                pending -= 1
        except socket.timeout:
            self._teardown(procs, conns, out_qs)
            server.close()
            missing = [r for r in range(n) if conns[r] is None]
            raise ExecutorFailure(missing, "never connected to the driver")
        finally:
            server.settimeout(None)

        def writer(rank: int):
            """Sole writer for one connection: drains the rank's outbound
            queue so that no *reader* ever blocks on a slow destination.
            Keeps consuming after a write error (the frames are dropped);
            a None sentinel ends the thread."""
            conn, q = conns[rank], out_qs[rank]
            broken = False
            while True:
                item = q.get()
                if item is None:
                    return
                if broken:
                    continue
                header, payload = item
                try:
                    wire.send_frame(conn, header, payload)
                except (ConnectionError, OSError):
                    broken = True

        def route(rank: int):
            """Read this rank's frames; record liveness and results, and
            enqueue forwards. *Any* inbound bytes count as liveness (via
            on_bytes), so a rank mid-way through a multi-second bulk
            transfer -- whose heartbeat thread may be blocked behind the
            send -- is never declared dead while its data is flowing; and
            forwarding is queued to the destination's writer thread, so a
            slow destination cannot stop this thread from reading the
            source's heartbeats."""
            conn = conns[rank]

            def alive(_nbytes):
                last_seen[rank] = time.time()

            try:
                while True:
                    frame = wire.recv_frame(conn, on_bytes=alive)
                    if frame is None:
                        return      # heartbeats stop; monitor takes it from here
                    alive(0)
                    header, payload = frame
                    kind = header.get("kind")
                    if kind == "msg":
                        out_qs[header["dst"]].put((header, payload))
                    elif kind == "result":
                        with lock:
                            if header["ok"]:
                                results[rank] = wire.decode(payload)
                            else:
                                errors[rank] = wire.decode(payload)
                                error_event.set()
                            done[rank] = True
                            if all(done):
                                done_event.set()
            except (ConnectionError, OSError, ValueError):
                return

        writers = [threading.Thread(target=writer, args=(r,), daemon=True)
                   for r in range(n)]
        routers = [threading.Thread(target=route, args=(r,), daemon=True)
                   for r in range(n)]
        for t in writers + routers:
            t.start()

        # -- monitor: heartbeat staleness is the failure signal; an error
        #    result from any rank aborts the world (the others would only
        #    deadlock waiting for it) ----------------------------------------
        deadline = time.time() + self._timeout
        try:
            while not done_event.is_set():
                if done_event.wait(self._hb_interval):
                    break
                if error_event.is_set():
                    break
                now = time.time()
                dead = [r for r in range(n)
                        if not done[r]
                        and now - last_seen[r] > self._hb_timeout]
                if dead:
                    self._raise_executor_errors(errors)  # root cause first
                    raise ExecutorFailure(
                        dead, f"missed heartbeats for >{self._hb_timeout:.1f}s")
                if now > deadline:
                    self._raise_executor_errors(errors)  # root cause first
                    raise TimeoutError(
                        "cluster closure deadlocked (implicit barrier at "
                        "closure end never reached)")
        finally:
            self._teardown(procs, conns, out_qs)
            server.close()

        self._raise_executor_errors(errors)
        return results

    @staticmethod
    def _raise_executor_errors(errors):
        failed = [(r, e) for r, e in enumerate(errors) if e is not None]
        if failed:
            raise RuntimeError("\n".join(
                f"executor rank {r} raised:\n{e}" for r, e in failed))

    @staticmethod
    def _teardown(procs, conns, out_qs):
        # best-effort graceful exit (skip a backlogged queue: closing the
        # connection below also signals the executor to leave)
        for conn, q in zip(conns, out_qs):
            if conn is None:
                continue
            try:
                q.put_nowait(({"kind": "ctrl", "op": "exit"}, b""))
            except queue.Full:
                pass
        for p in procs:
            p.join(timeout=2.0)
        for p in procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=2.0)
        for conn in conns:
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
        for q in out_qs:   # connections closed => writers drain fast
            q.put(None)

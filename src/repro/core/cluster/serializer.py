"""Closure serialization for pooled job dispatch.

The PR-1 runtime forked a fresh executor world inside every
``execute()``, so the closure rode into the child for free as process
memory. A persistent ``ExecutorPool`` forks once and then receives each
new closure as a *job frame*, which means closures must genuinely cross
a process boundary -- lambdas, nested functions, and captured arrays
included (the same "picklable-closure story" the ROADMAP names as a
prerequisite for ssh-launched remote executors).

``cloudpickle`` serializes code objects by value and is the standard
answer; it is gated, not required -- without it we fall back to stdlib
pickle, which covers module-level functions (functools.partial over
importables, etc.) and raises a clear error for lambdas.
"""
from __future__ import annotations

import pickle
from typing import Any, Callable

try:
    import cloudpickle as _cp
except ImportError:            # pragma: no cover - container ships it
    _cp = None


def dumps_closure(fn: Callable) -> bytes:
    if _cp is not None:
        return _cp.dumps(fn)
    try:
        return pickle.dumps(fn)
    except (pickle.PicklingError, AttributeError, TypeError) as e:
        raise TypeError(
            "cannot ship this closure to pooled executors: cloudpickle is "
            "unavailable and stdlib pickle only handles module-level "
            f"functions ({e})") from e


def loads_closure(blob: bytes | bytearray | memoryview) -> Any:
    # cloudpickle output is plain pickle data; stdlib loads either.
    return pickle.loads(bytes(blob))

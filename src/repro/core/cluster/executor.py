"""Executor side of the cluster transport.

Each executor is a real OS process hosting one rank of the world. It
dials the driver's TCP endpoint, then runs three concerns:

- a reader thread draining routed frames into the rank's matched
  ``Mailbox`` (receiver-side buffering, exactly as in local mode);
- a heartbeat thread announcing liveness every ``hb_interval`` seconds
  (the driver's failure detector watches for these going quiet);
- the main thread executing the user closure against a ``ClusterComm``
  and shipping the return value (or traceback) back as a result frame.

``ClusterComm`` subclasses the transport-agnostic ``MessageComm``: a send
writes one ``msg`` frame to the driver, which routes it to the
destination rank's connection; collectives and ``split`` are therefore
the same phase-1/phase-2 message compositions the thread runtime uses.
"""
from __future__ import annotations

import os
import socket
import threading
import time
import traceback
from typing import Any, Callable

from ..matching import Mailbox, MessageComm
from . import wire


class ExecutorChannel:
    """One rank's connection to the driver: socket + write lock + mailbox."""

    def __init__(self, sock: socket.socket, rank: int, hb_interval: float):
        self.sock = sock
        self.rank = rank
        self.wlock = threading.Lock()
        self.mailbox = Mailbox()
        self.exit_requested = threading.Event()
        self._hb_stop = threading.Event()
        self._hb_interval = hb_interval
        self._reader = threading.Thread(target=self._read_loop, daemon=True)
        self._reader.start()
        self._hb = threading.Thread(target=self._hb_loop, daemon=True)
        self._hb.start()

    def _read_loop(self):
        try:
            while True:
                frame = wire.recv_frame(self.sock)
                if frame is None:
                    break
                header, payload = frame
                kind = header.get("kind")
                if kind == "msg":
                    self.mailbox.put(header["ctx"], header["tag"],
                                     header["src"], wire.decode(payload))
                elif kind == "ctrl" and header.get("op") == "exit":
                    break
        except (ConnectionError, OSError):
            pass
        finally:
            self.exit_requested.set()

    def _hb_loop(self):
        while not self._hb_stop.wait(self._hb_interval):
            if self.exit_requested.is_set():
                return
            try:
                wire.send_frame(self.sock, {"kind": "hb", "rank": self.rank,
                                            "t": time.time()},
                                lock=self.wlock)
            except (ConnectionError, OSError):
                return

    def stop_heartbeat(self):
        """Test hook: silence this rank's failure-detector signal (models a
        wedged executor whose process is still alive)."""
        self._hb_stop.set()

    def send_msg(self, dst_world: int, ctx: int, tag: int, src_world: int,
                 payload: Any) -> None:
        wire.send_frame(self.sock,
                        {"kind": "msg", "dst": dst_world, "ctx": ctx,
                         "tag": tag, "src": src_world},
                        wire.encode_parts(payload), lock=self.wlock)

    def send_result(self, ok: bool, payload: list[bytes]) -> None:
        wire.send_frame(self.sock, {"kind": "result", "rank": self.rank,
                                    "ok": ok}, payload, lock=self.wlock)


class ClusterComm(MessageComm):
    """MPIgnite communicator over the process-separated TCP transport."""

    def __init__(self, channel: ExecutorChannel, group: tuple[int, ...],
                 rank_in_group: int, ctx: int, epoch: tuple = (),
                 backend: str = "linear", timeout: float = 60.0):
        super().__init__(group, rank_in_group, ctx, epoch, backend)
        self._chan = channel
        self._timeout = timeout

    # -- transport ----------------------------------------------------------
    def _put(self, world_dst: int, ctx: int, tag: int, src_world: int,
             payload: Any) -> None:
        self._chan.send_msg(world_dst, ctx, tag, src_world, payload)

    def _get(self, ctx: int, tag: int, src_world: int) -> Any:
        return self._chan.mailbox.get(ctx, tag, src_world, self._timeout)

    def _clone(self, group: tuple[int, ...], rank_in_group: int, ctx: int,
               epoch: tuple) -> "ClusterComm":
        return ClusterComm(self._chan, group, rank_in_group, ctx, epoch,
                           self._backend, self._timeout)

    # -- cluster extras -----------------------------------------------------
    @property
    def channel(self) -> ExecutorChannel:
        return self._chan

    def die(self, exit_code: int = 1):
        """Test hook: abrupt node loss -- no result frame, no goodbye."""
        os._exit(exit_code)


def executor_main(fn: Callable[[ClusterComm], Any], rank: int, size: int,
                  port: int, backend: str, timeout: float,
                  hb_interval: float, host: str = "127.0.0.1") -> None:
    """Entry point of an executor process (spawned via fork, so ``fn`` may
    be any closure -- lambdas and captured arrays included)."""
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    wire.send_frame(sock, {"kind": "hello", "rank": rank, "pid": os.getpid()})
    chan = ExecutorChannel(sock, rank, hb_interval)
    comm = ClusterComm(chan, tuple(range(size)), rank, ctx=0,
                       backend=backend, timeout=timeout)
    try:
        result = fn(comm)
        chan.send_result(True, wire.encode_parts(result))
    except BaseException:  # noqa: BLE001 -- ship the traceback to the driver
        try:
            chan.send_result(False, wire.encode_parts(traceback.format_exc()))
        except (ConnectionError, OSError):
            pass
        chan.exit_requested.wait(timeout)
        os._exit(1)
    # Stay alive until the driver says exit: other ranks may still route
    # messages here, and the driver owns teardown ordering.
    chan.exit_requested.wait(timeout)
    os._exit(0)

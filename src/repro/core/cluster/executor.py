"""Executor side of the cluster transport.

Each executor is a real OS process hosting one rank of the world,
*persistent across jobs*: it is forked once by an ``ExecutorPool``, then
sits in a job loop receiving closures as dispatched ``job`` frames (see
``serializer``) instead of being re-forked per ``execute()``.

Two planes of traffic:

- **control plane** (one TCP connection to the driver): ``hello``,
  ``peers``, ``job``, ``result``, ``hb`` heartbeats, ``ctrl`` exit. The
  driver brokers bootstrap and watches liveness here.
- **data plane** (lazily-dialed direct TCP connections between
  executors): every ``msg`` frame a closure sends travels peer-to-peer,
  never touching a driver socket. Addresses come from the driver's
  ``peers`` frame at bootstrap -- each executor opens its own data
  listener before saying hello and advertises the port in the hello
  frame. With ``data_plane="relay"`` the PR-1 behavior (driver routes
  every ``msg``) is kept for comparison benchmarks and as a fallback
  when a peer dial fails.

Liveness accounts for peer traffic: data-plane reader threads count the
bytes received per source rank and the heartbeat frame carries that
``peer_rx`` map, so the driver can treat "a peer is receiving bytes from
rank r" as proof that r is alive even when r's own heartbeats stall
behind a bulk transfer.

``ClusterComm`` subclasses the transport-agnostic ``MessageComm``; a
fresh communicator is built per job with ``ctx=job id``, which isolates
any stale matched messages a misbehaved previous job left behind.

Multi-host: this module is also a CLI (``python -m
repro.core.cluster.executor --rank R --world N --driver HOST:PORT
--secret-file F``) so a launcher can start ranks on remote machines
instead of forking them. The data listener binds ``--bind-host`` (e.g.
``0.0.0.0``) and advertises ``--advertise-host`` to peers; when binding
a wildcard without an explicit advertise address, the executor
advertises the local address of its route to the driver. Every
connection -- the control dial to the driver, and both ends of every
peer channel -- runs the ``wire`` HMAC handshake, and hello frames are
MAC-bound to the handshake transcript so registrations cannot be
replayed.
"""
from __future__ import annotations

import argparse
import os
import queue
import socket
import threading
import time
import traceback
from typing import Any

from ..matching import Mailbox, MessageComm, ProgressEngine
from ..obs.log import get_logger
from ..obs.metrics import ChannelStats
from ..obs.trace import Tracer, trace_flush_interval
from . import shm as shm_transport
from . import wire
from .serializer import loads_closure

#: ChannelStats peer id for the driver's control connection
DRIVER_PEER = -1

#: shm fragment envelope (first byte of every ring record): frames
#: larger than one ring record are split by the sender and reassembled
#: by the receiver's read loop, so frame size never picks the transport
_SHM_WHOLE, _SHM_FIRST, _SHM_MID, _SHM_LAST = 0, 1, 2, 3


class ExecutorChannel:
    """One rank's transport state: the control connection to the driver,
    the data-plane listener + peer connections, and the matched mailbox
    both planes deliver into."""

    def __init__(self, sock: socket.socket, rank: int, hb_interval: float,
                 data_plane: str = "direct",
                 data_server: socket.socket | None = None,
                 host: str = "127.0.0.1", secret: bytes = b"",
                 shm_rings: "shm_transport.ShmRings | None" = None):
        self.sock = sock
        self.rank = rank
        self.host = host
        self.secret = secret
        self.data_plane = data_plane
        self.wlock = threading.Lock()
        #: this rank's own inbound shared-memory segment (None = the
        #: shm tier is off; everything rides TCP as before)
        self.shm = shm_rings
        #: world rank -> (segment name, ring index = our stable slot)
        #: for peers the broker matched to this host
        self._shm_peers: dict[int, tuple[str, int]] = {}
        #: attached remote segments, by name (attachments survive
        #: re-brokering: slot numbering is stable across epochs)
        self._shm_attach: dict[str, shm_transport.ShmRings] = {}
        #: world ranks permanently demoted to TCP (attach/write failure,
        #: or an oversized record): per-key FIFO delivery only holds if
        #: a pair never interleaves transports, so the demotion sticks
        #: until the next re-broker
        self._shm_tcp_only: set[int] = set()
        self._shm_lock = threading.Lock()
        # one producer lock per destination rank: a ring is SPSC, but a
        # job thread and its ProgressEngine can both send to the same
        # peer (the TCP path serializes on the per-socket lock; this is
        # the shm equivalent)
        self._shm_tx_locks: dict[int, threading.Lock] = {}
        # one mailbox per job id: structural isolation between jobs, and
        # a GC boundary -- stray messages a misbehaved job left behind
        # are dropped when their job's mailbox is purged at a later
        # dispatch (ctx isolation alone would pin them forever in a
        # persistent executor).
        self._mailboxes: dict[int, Mailbox] = {}
        self._mb_lock = threading.Lock()
        # one progress engine per job id (thread starts lazily on the
        # first nonblocking collective); closed when the job is purged,
        # so a leaked request dies with its job instead of poisoning the
        # next pooled job's comm ctx.
        self._engines: dict[int, ProgressEngine] = {}
        #: reason string once the driver declared some rank dead -- new
        #: mailboxes are born poisoned so nothing can block afterwards
        self._peer_dead: str | None = None
        self.jobs: queue.Queue = queue.Queue()
        self.exit_requested = threading.Event()
        self.peers_ready = threading.Event()
        self.peer_addrs: dict[int, tuple[str, int]] = {}
        #: this rank's position in the *current membership epoch's* world
        #: (== launch rank until a shrink/grow re-broker renumbers it);
        #: updated by the job loop from each job frame. ``msg`` frames
        #: address world ranks, so the self-send check compares this.
        self.world_rank = rank
        #: membership epoch of the last brokered peers frame
        self.mepoch = 0
        self._peer_socks: dict[int, tuple[socket.socket, threading.Lock]] = {}
        self._peer_lock = threading.Lock()
        #: dst -> monotonic time before which we won't re-dial it. A
        #: peer whose advertised address drops packets would otherwise
        #: cost a full connect timeout on *every* send; backing off
        #: keeps the relay fallback fast enough to carry the traffic.
        self._peer_backoff: dict[int, float] = {}
        self._rx_counts: dict[int, int] = {}    # data-plane bytes per src
        self._rx_lock = threading.Lock()
        #: always-on wire counters (tx/rx bytes + frames, per peer;
        #: the driver's control connection is peer -1)
        self.stats = ChannelStats()
        #: control-plane heartbeat round-trip time (seconds), measured
        #: off the driver's hb_ack echo; None until the first ack lands
        self.hb_rtt: float | None = None
        #: per-job tracers (installed by the job loop when the job
        #: header asks for tracing); both planes' readers consult this
        self._tracers: dict[int, Tracer] = {}
        self._log = get_logger("cluster.executor").bound(rank=rank)
        self._driver_tx = lambda n: self.stats.on_tx(DRIVER_PEER, n)
        self._hb_stop = threading.Event()
        self._hb_interval = hb_interval
        self._data_server = data_server
        if data_server is not None:
            threading.Thread(target=self._accept_loop, daemon=True).start()
        if shm_rings is not None:
            threading.Thread(target=self._shm_read_loop,
                             daemon=True).start()
        self._reader = threading.Thread(target=self._read_loop, daemon=True)
        self._reader.start()
        self._hb = threading.Thread(target=self._hb_loop, daemon=True)
        self._hb.start()

    # -- mailboxes + progress engines ---------------------------------------
    def mailbox_for(self, job: int) -> Mailbox:
        with self._mb_lock:
            mb = self._mailboxes.get(job)
            if mb is None:
                mb = self._mailboxes[job] = Mailbox()
                mb.tracer = self._tracers.get(job)
                if self._peer_dead is not None:
                    mb.poison = self._peer_dead
            return mb

    def set_tracer(self, job: int, tracer: Tracer | None) -> None:
        """Install (or, with None, retire) a job's tracer. The mailbox
        may already exist -- a fast peer's first msg frame creates it
        before the local job loop sees the dispatch -- so wire it too."""
        with self._mb_lock:
            if tracer is None:
                self._tracers.pop(job, None)
            else:
                self._tracers[job] = tracer
            mb = self._mailboxes.get(job)
            if mb is not None:
                mb.tracer = tracer

    def tracer_for(self, job: int) -> Tracer | None:
        return self._tracers.get(job)

    def _decode(self, payload: list[bytes] | bytes, job: int, via: str):
        """Decode a msg payload, timed when the job is traced."""
        tr = self._tracers.get(job)
        if tr is None:
            return wire.decode(payload)
        t0 = tr.now()
        data = wire.decode(payload)
        tr.complete("wire.decode", "wire", t0, args={"via": via})
        return data

    def engine_for(self, job: int) -> ProgressEngine:
        with self._mb_lock:
            eng = self._engines.get(job)
            if eng is None:
                eng = self._engines[job] = ProgressEngine(
                    name=f"mpignite-progress-r{self.rank}-j{job}")
            return eng

    def purge_mailboxes_before(self, job: int) -> None:
        """Free every mailbox (and close every progress engine) belonging
        to a job older than ``job`` -- called at each dispatch, when no
        live closure can match those messages anymore (a straggler's late
        frame merely recreates one near-empty mailbox, reclaimed at the
        next purge). Closing the engines fails any request a previous
        closure leaked, so its parked schedules can never resume against
        a new job's comm ctx."""
        with self._mb_lock:
            for j in [j for j in self._mailboxes if j < job]:
                del self._mailboxes[j]
            for j in [j for j in self._tracers if j < job]:
                del self._tracers[j]
            stale = [self._engines.pop(j) for j in list(self._engines)
                     if j < job]
        for eng in stale:       # close outside the lock: it joins a thread
            eng.close("job ended with the request still pending")

    def drain_job(self, job: int) -> None:
        """End-of-job teardown: fail any request the closure leaked
        (without waiting for the next dispatch to purge)."""
        with self._mb_lock:
            eng = self._engines.get(job)
        if eng is not None:
            eng.drain("job ended with the request still pending")

    def notify_peer_dead(self, ranks: list[int], reason: str) -> None:
        """Driver-declared rank death: poison every mailbox so blocked
        receives and in-flight requests fail with PeerDeadError now,
        instead of hanging to their timeouts."""
        msg = (f"peer rank(s) {ranks} declared dead by the driver: "
               f"{reason}")
        with self._mb_lock:
            self._peer_dead = msg
            boxes = list(self._mailboxes.values())
        for mb in boxes:
            mb.poison_all(msg)

    def _apply_peers(self, header: dict) -> None:
        """Install a brokered peers map. The bootstrap broker sends one;
        every membership change (shrink-to-survivors, grow-on-join)
        re-brokers with a bumped ``mepoch``: addresses are then keyed by
        *new* world ranks, so the old peer channels (keyed by ranks that
        just changed meaning) are evicted, and the world is declared
        healed -- mailboxes of *future* jobs must not be born poisoned
        by a death the re-broker already survived."""
        addrs = {int(r): (h, p) for r, (h, p) in header["addrs"].items()}
        mepoch = int(header.get("mepoch", 0))
        rebrokered = mepoch != self.mepoch
        self.mepoch = mepoch
        self.peer_addrs = addrs
        self._apply_shm_peers(header.get("shm") or {})
        if rebrokered:
            with self._peer_lock:
                self._peer_backoff.clear()
                socks = list(self._peer_socks.values())
                self._peer_socks.clear()
            for s, _ in socks:
                try:
                    s.close()
                except OSError:
                    pass
            with self._mb_lock:
                self._peer_dead = None      # the new world is healthy
        self.peers_ready.set()

    # -- shared-memory data plane -------------------------------------------
    def _apply_shm_peers(self, shm_map: dict) -> None:
        """Install the broker's shm table: for every peer world rank on
        *this* host, remember its segment name and the ring index this
        rank must write (its own stable slot -- rings are SPSC per
        directed pair). Re-brokering resets TCP demotions: the new
        epoch's first send re-probes shm."""
        if self.shm is None:
            return
        token = shm_transport.host_token()
        me = None
        for info in shm_map.values():
            if info.get("seg") == self.shm.name:
                me = int(info["slot"])
        peers: dict[int, tuple[str, int]] = {}
        if me is not None:
            for wr, info in shm_map.items():
                seg = info.get("seg")
                if (info.get("host") == token and seg
                        and seg != self.shm.name):
                    peers[int(wr)] = (seg, me)
        with self._shm_lock:
            self._shm_peers = peers
            self._shm_tcp_only.clear()

    def _shm_attachment(self, seg: str
                        ) -> "shm_transport.ShmRings | None":
        got = self._shm_attach.get(seg)
        if got is not None:
            return got
        with self._shm_lock:
            got = self._shm_attach.get(seg)
            if got is None:
                got = self._shm_attach[seg] = shm_transport.ShmRings.attach(
                    seg)
            return got

    def _shm_send(self, dst_world: int, header: dict,
                  parts: list[bytes], tracer) -> bool:
        """Try the shared-memory fast path; False => caller uses TCP.
        Any *failure* demotes the pair to TCP until the next re-broker,
        so one (ctx, tag, src) key never interleaves transports (which
        could reorder same-key messages across the two reader threads).
        Frames larger than a ring record are fragmented through the
        ring rather than spilled to TCP, for the same reason: size must
        not decide the transport, or a big send and its small same-tag
        successor could arrive through different readers out of order."""
        with self._shm_lock:
            route = self._shm_peers.get(dst_world)
            demoted = dst_world in self._shm_tcp_only
            tx_lock = self._shm_tx_locks.setdefault(dst_world,
                                                    threading.Lock())
        if route is None or demoted:
            return False
        seg_name, ring = route
        try:
            rings = self._shm_attachment(seg_name)
            record = wire.pack_frame(header, parts)
            t0 = 0 if tracer is None else tracer.now()
            limit = rings.max_record() - 1     # 1-byte fragment envelope
            with tx_lock:
                if len(record) <= limit:
                    ok = rings.write(ring, bytes((_SHM_WHOLE,)) + record)
                else:
                    ok = True
                    for off in range(0, len(record), limit):
                        if off == 0:
                            flag = _SHM_FIRST
                        elif off + limit >= len(record):
                            flag = _SHM_LAST
                        else:
                            flag = _SHM_MID
                        ok = rings.write(
                            ring, bytes((flag,)) + record[off:off + limit])
                        if not ok:
                            break
            if not ok:
                raise ConnectionError(
                    f"ring {ring} rejected a {len(record)}-byte record")
            if tracer is not None:
                tracer.complete("shm.write", "wire", t0,
                                args={"dst": dst_world,
                                      "nbytes": len(record)})
        except (ConnectionError, OSError, ValueError) as e:
            self._log.warning("shm send to rank %d failed (%s); using "
                              "TCP until the next re-broker",
                              dst_world, e)
            with self._shm_lock:
                self._shm_tcp_only.add(dst_world)
            return False
        self.stats.on_tx(dst_world, len(record), shm=True)
        return True

    def _shm_read_loop(self):
        """Drain every ring of this rank's own segment into the mailbox.
        Records are whole wire frames, so decode and delivery are
        identical to the socket readers; the ring index is the sender's
        stable slot, which is the same identity the TCP readers count
        ``_rx_counts`` under (heartbeat vouching keeps working).

        ``try_read`` never raises: a record whose pages are not yet
        visible (or that a dead producer half-wrote) just reads as None
        until the checksum passes, so this loop never abandons the
        transport -- at worst one ring idles until the next re-broker
        retires it."""
        rings = self.shm
        frag: dict[int, bytearray] = {}     # slot -> partial frame
        delay = 0.0
        while not self.exit_requested.is_set():
            got = False
            for slot in range(rings.nrings):
                rec = rings.try_read(slot)
                if rec is None:
                    continue
                got = True
                with self._rx_lock:
                    self._rx_counts[slot] = (self._rx_counts.get(slot, 0)
                                             + len(rec))
                flag = rec[0] if rec else -1
                if flag == _SHM_WHOLE:
                    frame = rec[1:]
                elif flag == _SHM_FIRST:
                    frag[slot] = bytearray(memoryview(rec)[1:])
                    continue
                elif flag in (_SHM_MID, _SHM_LAST):
                    buf = frag.get(slot)
                    if buf is None:     # stale tail of an aborted frame
                        self._log.warning("dropping orphan shm fragment "
                                          "from slot %d", slot)
                        continue
                    buf += memoryview(rec)[1:]
                    if flag == _SHM_MID:
                        continue
                    frame = bytes(frag.pop(slot))
                else:
                    self._log.warning("dropping malformed shm record "
                                      "from slot %d (envelope %r)",
                                      slot, flag)
                    continue
                try:
                    header, payload = wire.unpack_frame(frame)
                except ValueError as e:
                    self._log.warning("dropping malformed shm frame "
                                      "from slot %d: %s", slot, e)
                    continue
                if header.get("kind") == "msg":
                    src = header["src"]
                    self.stats.on_rx(src, len(frame), shm=True)
                    job = header.get("job", 0)
                    self.mailbox_for(job).put(
                        header["ctx"], header["tag"], src,
                        self._decode(payload, job, "shm"))
            if got:
                delay = 0.0
            else:
                # adaptive poll: spin while traffic flows, ramp to a
                # deep 20ms idle backoff. The ceiling matters: unlike
                # the blocking TCP readers, this thread pays for idle
                # time, and a host can hold many warm-but-quiescent
                # pools (the cached-pool pattern) whose polling must
                # cost ~nothing. Active rings reset the delay to zero,
                # so the ceiling is only ever paid by the first record
                # after a long quiet spell.
                time.sleep(delay)
                delay = min(0.02, delay + 0.0002)
        rings.close()

    def close_shm(self):
        with self._shm_lock:
            attached = list(self._shm_attach.values())
            self._shm_attach.clear()
            self._shm_peers.clear()
        for rings in attached:
            rings.close()

    # -- control plane ------------------------------------------------------
    def _read_loop(self):
        nread = [0]

        def on_bytes(k):
            nread[0] += k
        try:
            while True:
                frame = wire.recv_frame(self.sock, on_bytes=on_bytes)
                if frame is None:
                    break
                self.stats.on_rx(DRIVER_PEER, nread[0])
                nread[0] = 0
                header, payload = frame
                kind = header.get("kind")
                if kind == "msg":           # relay-routed delivery
                    job = header.get("job", 0)
                    self.mailbox_for(job).put(
                        header["ctx"], header["tag"], header["src"],
                        self._decode(payload, job, "relay"))
                elif kind == "job":
                    self.jobs.put((header["job"], header["backend"],
                                   header["timeout"],
                                   header.get("segment_bytes"),
                                   header.get("trace", False),
                                   header.get("rank"), header.get("size"),
                                   header.get("mepoch", 0), payload))
                elif kind == "hb_ack":
                    # same clock stamped both legs (our hb's t), so this
                    # is a true control-plane round trip
                    self.hb_rtt = max(0.0, time.time() - header["t"])
                elif kind == "peers":
                    self._apply_peers(header)
                elif kind == "ctrl" and header.get("op") == "peer_dead":
                    self.notify_peer_dead(header.get("ranks", []),
                                          header.get("reason", ""))
                elif kind == "ctrl" and header.get("op") == "exit":
                    break
        except (ConnectionError, OSError) as e:
            if not self.exit_requested.is_set():
                self._log.debug("control connection lost: %s", e)
        finally:
            self.exit_requested.set()
            self.jobs.put(None)

    def _hb_loop(self):
        while not self._hb_stop.wait(self._hb_interval):
            if self.exit_requested.is_set():
                return
            hb = {"kind": "hb", "rank": self.rank, "t": time.time()}
            if self.hb_rtt is not None:
                hb["rtt"] = self.hb_rtt    # report the last measured RTT
            with self._rx_lock:     # peer readers insert keys concurrently
                rx = dict(self._rx_counts)
            if rx:
                # vouch for peers whose data this rank is receiving
                hb["peer_rx"] = {str(s): n for s, n in rx.items()}
            try:
                wire.send_frame(self.sock, hb, lock=self.wlock,
                                on_tx=self._driver_tx)
            except (ConnectionError, OSError):
                return

    def stop_heartbeat(self):
        """Test hook: silence this rank's failure-detector signal (models a
        wedged executor whose process is still alive)."""
        self._hb_stop.set()

    # -- data plane ---------------------------------------------------------
    def _accept_loop(self):
        while True:
            try:
                conn, _ = self._data_server.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._peer_read_loop, args=(conn,),
                             daemon=True).start()

    def _peer_read_loop(self, conn: socket.socket):
        """Authenticate then drain one inbound peer connection into the
        mailbox, counting received bytes per source so heartbeats can
        vouch for the peer. A dialer failing the handshake (wrong secret,
        or a legacy client leading with a bare hello) is disconnected
        before any frame reaches a mailbox: fail closed."""
        src = None
        nread = [0]

        def on_bytes(k):
            nread[0] += k
            if src is not None:
                with self._rx_lock:
                    self._rx_counts[src] = self._rx_counts.get(src, 0) + k
        try:
            transcript = wire.server_handshake(conn, self.secret)
            first = wire.recv_frame(conn, limit=wire.PREAUTH_MAX_FRAME)
            if (first is None or first[0].get("kind") != "hello"
                    or not wire.verify_hello(self.secret, transcript,
                                             first[0])):
                conn.close()
                return
            src = first[0]["src"]
            while True:
                nread[0] = 0
                frame = wire.recv_frame(conn, on_bytes=on_bytes)
                if frame is None:
                    return
                self.stats.on_rx(src, nread[0])
                header, payload = frame
                if header.get("kind") == "msg":
                    job = header.get("job", 0)
                    self.mailbox_for(job).put(
                        header["ctx"], header["tag"], header["src"],
                        self._decode(payload, job, "direct"))
        except (ConnectionError, OSError, ValueError, TypeError,
                AttributeError, KeyError) as e:
            # malformed peer frames end the connection, not the
            # listener -- _accept_loop keeps serving other peers
            self._log.debug("peer connection from rank %s ended: %s",
                            src, e)
            return
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _peer_channel(self, dst: int, tracer: Tracer | None = None
                      ) -> tuple[socket.socket, threading.Lock] | None:
        """Lazily dial the destination's data listener (full mesh grows
        only along edges actually used). None => fall back to relay."""
        got = self._peer_socks.get(dst)
        if got is not None:
            return got
        with self._peer_lock:
            got = self._peer_socks.get(dst)
            if got is not None:
                return got
            addr = self.peer_addrs.get(dst)
            if addr is None:
                return None
            if time.monotonic() < self._peer_backoff.get(dst, 0.0):
                return None     # recent dial failure: relay, don't block
            t0 = 0 if tracer is None else tracer.now()
            try:
                s = socket.create_connection(addr, timeout=10.0)
            except OSError as e:
                self._peer_backoff[dst] = time.monotonic() + 30.0
                self._log.warning("peer %d dial %s failed (%s); relaying "
                                  "via driver for 30s", dst, addr, e)
                return None
            try:
                transcript = wire.client_handshake(s, self.secret)
            except wire.AuthError as e:
                self._peer_backoff[dst] = time.monotonic() + 30.0
                self._log.warning("peer %d handshake failed (%s); relaying "
                                  "via driver for 30s", dst, e)
                try:
                    s.close()
                except OSError:
                    pass
                return None
            s.settimeout(None)      # blocking sends: TCP backpressure,
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)  # not
            hello = {"kind": "hello", "src": self.rank}               # EAGAIN
            hello["mac"] = wire.hello_mac(self.secret, transcript, hello)
            wire.send_frame(s, hello)
            if tracer is not None:
                tracer.complete("peer.dial", "wire", t0,
                                args={"dst": dst})
            got = (s, threading.Lock())
            self._peer_socks[dst] = got
            return got

    def _evict_peer(self, dst: int, sock: socket.socket) -> None:
        """Drop a failed peer connection: a frame may have been half
        written, so the stream can never be trusted again (a later dial
        starts a fresh connection)."""
        with self._peer_lock:
            if self._peer_socks.get(dst, (None,))[0] is sock:
                del self._peer_socks[dst]
        try:
            sock.close()
        except OSError:
            pass

    # -- sends --------------------------------------------------------------
    def send_msg(self, dst_world: int, ctx: int, tag: int, src_world: int,
                 payload: Any, job: int = 0) -> None:
        header = {"kind": "msg", "dst": dst_world, "ctx": ctx,
                  "tag": tag, "src": src_world, "job": job}
        tracer = self._tracers.get(job)
        if self.data_plane == "direct" and dst_world == self.world_rank:
            # self-send: straight to mailbox, nothing ever encoded
            self.mailbox_for(job).put(ctx, tag, src_world, payload)
            return
        if tracer is None:
            parts = wire.encode_parts(payload)
        else:
            t0 = tracer.now()
            parts = wire.encode_parts(payload)
            tracer.complete("wire.encode", "wire", t0,
                            args={"dst": dst_world})
        if self.data_plane == "direct":
            if (self.shm is not None
                    and self._shm_send(dst_world, header, parts, tracer)):
                return
            peer = self._peer_channel(dst_world, tracer)
            if peer is not None:
                sock, lock = peer
                try:
                    wire.send_frame(sock, header, parts, lock=lock,
                                    on_tx=lambda n: self.stats.on_tx(
                                        dst_world, n))
                    return
                except (ConnectionError, OSError) as e:
                    # peer gone: evict the (possibly mid-frame) stream and
                    # relay through the driver as last resort
                    self._log.warning("peer %d send failed (%s); evicting "
                                      "channel and relaying", dst_world, e)
                    self._evict_peer(dst_world, sock)
        wire.send_frame(self.sock, header, parts, lock=self.wlock,
                        on_tx=self._driver_tx)

    def send_result(self, job_id: int, ok: bool,
                    payload: list[bytes]) -> None:
        wire.send_frame(self.sock, {"kind": "result", "rank": self.rank,
                                    "job": job_id, "ok": ok},
                        payload, lock=self.wlock, on_tx=self._driver_tx)

    def send_trace(self, job_id: int, tracer: Tracer) -> None:
        """Flush a finished job's trace snapshot to the driver. Sent
        *before* the result frame on the same ordered control socket, so
        the driver has stored it by the time ``run()`` unblocks."""
        try:
            wire.send_frame(self.sock,
                            {"kind": "trace", "rank": self.rank,
                             "job": job_id},
                            wire.encode_parts(tracer.snapshot()),
                            lock=self.wlock, on_tx=self._driver_tx)
        except (ConnectionError, OSError) as e:
            self._log.debug("trace flush for job %d failed: %s", job_id, e)

    def close_peers(self):
        with self._peer_lock:
            for s, _ in self._peer_socks.values():
                try:
                    s.close()
                except OSError:
                    pass
            self._peer_socks.clear()


class ClusterComm(MessageComm):
    """MPIgnite communicator over the process-separated TCP transport."""

    def __init__(self, channel: ExecutorChannel, group: tuple[int, ...],
                 rank_in_group: int, ctx: int, epoch: tuple = (),
                 backend: str = "linear", timeout: float = 60.0,
                 job: int = 0, segment_bytes: int | None = None):
        super().__init__(group, rank_in_group, ctx, epoch, backend,
                         segment_bytes=segment_bytes)
        self._chan = channel
        self._timeout = timeout
        self._job = job     # selects the job's mailbox; survives split()
        # per-job tracer (None = untraced); _clone() re-reads it, so
        # split()/with_backend() communicators trace into the same buffer
        self._obs = channel.tracer_for(job)

    # -- transport ----------------------------------------------------------
    def _put(self, world_dst: int, ctx: int, tag: int, src_world: int,
             payload: Any) -> None:
        self._chan.send_msg(world_dst, ctx, tag, src_world, payload,
                            job=self._job)

    def _get(self, ctx: int, tag: int, src_world: int) -> Any:
        return self._chan.mailbox_for(self._job).get(ctx, tag, src_world,
                                                     self._timeout)

    def _clone(self, group: tuple[int, ...], rank_in_group: int, ctx: int,
               epoch: tuple) -> "ClusterComm":
        return ClusterComm(self._chan, group, rank_in_group, ctx, epoch,
                           self._backend, self._timeout, self._job,
                           segment_bytes=self._segment_bytes)

    def _async_mailbox(self):
        return self._chan.mailbox_for(self._job), self._timeout

    def _progress_engine(self):
        # one engine per (rank, job): split()/with_backend() clones share
        # it, and it dies with the job's purge
        return self._chan.engine_for(self._job)

    # -- cluster extras -----------------------------------------------------
    @property
    def channel(self) -> ExecutorChannel:
        return self._chan

    def die(self, exit_code: int = 1):
        """Test hook: abrupt node loss -- no result frame, no goodbye."""
        os._exit(exit_code)


def executor_main(rank: int, size: int, driver: tuple[str, int],
                  backend: str, timeout: float, hb_interval: float,
                  data_plane: str = "direct", bind_host: str = "127.0.0.1",
                  advertise_host: str | None = None,
                  secret: bytes | None = None) -> None:
    """Entry point of a persistent executor process.

    Bootstrap: open the data listener on ``bind_host`` (direct mode),
    dial the driver at ``driver = (host, port)``, run the HMAC handshake,
    advertise ``(rank, pid, data_addr)`` in the MAC-bound hello frame,
    wait for the driver's brokered ``peers`` address map. Then loop: each
    ``job`` frame carries a serialized closure which runs against a fresh
    ``ClusterComm`` (ctx = job id); the return value or traceback goes
    back as a ``result`` frame. A job that raises does *not* kill the
    executor -- the pool survives user exceptions.
    """
    if secret is None:
        secret = wire.load_secret()
    if not secret:
        raise SystemExit("executor: no shared secret (pass secret=, "
                         "--secret-file, or set $" + wire.SECRET_ENV)

    joining = rank < 0      # grow-on-join: no slot yet, the driver assigns
    data_server = None
    data_port = None
    if data_plane == "direct":
        data_server = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        data_server.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        data_server.bind((bind_host, 0))
        data_server.listen(max(size, 8))
        data_port = data_server.getsockname()[1]

    sock = socket.create_connection(driver, timeout=timeout)
    sock.settimeout(None)   # the connect timeout must NOT become a read
    # timeout: a warm pool's control plane is legitimately quiet between
    # jobs (heartbeats flow executor->driver only), and a timeout here
    # would make idle executors exit and the pool self-destruct.
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    try:
        transcript = wire.client_handshake(sock, secret)
    except wire.AuthError:
        os._exit(3)         # driver refused us (or we refused the driver)
    # the address peers should dial: an explicit advertise host wins;
    # a wildcard bind falls back to the local address of this
    # executor's route to the driver (correct interface by construction).
    if advertise_host:
        data_host = advertise_host
    elif bind_host in ("0.0.0.0", "::", ""):
        data_host = sock.getsockname()[0]
    else:
        data_host = bind_host
    # the shm tier: create this rank's inbound ring segment *before* the
    # hello so its name travels in the MAC-bound registration. Creation
    # failure (no /dev/shm, exotic platform) silently means TCP-only.
    shm_rings = None
    if data_plane == "direct" and shm_transport.enabled():
        try:
            shm_rings = shm_transport.ShmRings.create(
                nrings=max(size, 1) + 8)
        except (OSError, ValueError):
            shm_rings = None
    hello = {"kind": "hello", "rank": rank, "pid": os.getpid(),
             "data_addr": ([data_host, data_port]
                           if data_port is not None else None)}
    if shm_rings is not None:
        hello["shm_seg"] = shm_rings.name
        hello["shm_host"] = shm_transport.host_token()
    if joining:
        hello["join"] = True
    hello["mac"] = wire.hello_mac(secret, transcript, hello)
    wire.send_frame(sock, hello)
    if joining:
        # Parked until the driver absorbs us at a step boundary: the
        # first frame is a ``welcome`` assigning our launch slot and the
        # current world size. No heartbeats until then -- a parked rank
        # is not a world member and must not trip the failure detector.
        while True:
            frame = wire.recv_frame(sock)
            if frame is None:
                os._exit(1)     # driver went away before absorbing us
            header = frame[0]
            if (header.get("kind") == "ctrl"
                    and header.get("op") == "welcome"):
                rank = int(header["rank"])
                size = int(header.get("size", size) or 1)
                break
            if (header.get("kind") == "ctrl"
                    and header.get("op") == "exit"):
                os._exit(0)
    chan = ExecutorChannel(sock, rank, hb_interval, data_plane=data_plane,
                           data_server=data_server, host=data_host,
                           secret=secret, shm_rings=shm_rings)
    if data_plane == "direct" and not chan.peers_ready.wait(timeout):
        os._exit(1)

    log = get_logger("cluster.executor").bound(rank=rank, world=size)
    while True:
        job = chan.jobs.get()
        if job is None or chan.exit_requested.is_set():
            break
        (job_id, job_backend, job_timeout, job_seg, job_traced,
         job_rank, job_size, job_mepoch, blob) = job
        # membership epochs renumber the world: the job frame carries
        # this rank's world rank + size for *its* epoch (None = the
        # launch-time identity, for epoch 0)
        wrank = rank if job_rank is None else int(job_rank)
        wsize = size if job_size is None else int(job_size)
        chan.world_rank = wrank
        chan.purge_mailboxes_before(job_id)
        tracer = Tracer(wrank, wsize, job=job_id) if job_traced else None
        chan.set_tracer(job_id, tracer)
        flush_stop = threading.Event()
        if tracer is not None:
            # mid-job streaming flush: ship cumulative snapshots on an
            # interval so the driver holds partial spans even when this
            # job hangs, is SIGSTOPped, or never finishes. Each frame
            # *replaces* the previous snapshot driver-side, so the final
            # end-of-job flush stays authoritative.
            interval = trace_flush_interval()
            if interval > 0:
                def _stream_trace(job_id=job_id, tracer=tracer):
                    while not flush_stop.wait(interval):
                        chan.send_trace(job_id, tracer)
                threading.Thread(target=_stream_trace,
                                 daemon=True).start()

        def flush_trace():
            # merge the always-on runtime gauges into the trace, then
            # ship it -- BEFORE the result frame, so the ordered control
            # socket guarantees the driver stored it when run() returns
            flush_stop.set()
            if tracer is None:
                return
            mb = chan.mailbox_for(job_id)
            tracer.counters.update(
                {f"mb.{k}": v for k, v in mb.health().items()})
            eng = chan._engines.get(job_id)
            if eng is not None:
                tracer.counters.update(
                    {f"engine.{k}": v for k, v in eng.gauges().items()})
            s = chan.stats.summary()
            tracer.counters.update(
                {f"chan.{k}": v for k, v in s.items() if k != "peers"})
            if chan.hb_rtt is not None:
                tracer.counters["chan.hb_rtt_us"] = int(chan.hb_rtt * 1e6)
            chan.send_trace(job_id, tracer)
            chan.set_tracer(job_id, None)

        try:
            if tracer is None:
                fn = loads_closure(blob)
            else:
                t0 = tracer.now()
                fn = loads_closure(blob)
                tracer.complete("job.load", "job", t0,
                                args={"nbytes": sum(len(b) for b in blob)
                                      if isinstance(blob, list)
                                      else len(blob)})
        except BaseException:  # noqa: BLE001 -- traceback ships to the
            # driver (which raises it); debug here avoids double-printing
            log.bound(rank=rank, world=size, job=job_id).debug(
                "closure deserialization failed:\n%s",
                traceback.format_exc())
            flush_trace()
            try:
                chan.send_result(job_id, False,
                                 wire.encode_parts(traceback.format_exc()))
            except (ConnectionError, OSError):
                break
            continue
        comm = ClusterComm(chan, tuple(range(wsize)), wrank,
                           ctx=job_id, epoch=("j", job_id, job_mepoch),
                           backend=job_backend or backend,
                           timeout=job_timeout or timeout, job=job_id,
                           segment_bytes=job_seg)
        try:
            if tracer is None:
                result = fn(comm)
            else:
                t0 = tracer.now()
                result = fn(comm)
                tracer.complete("job.run", "job", t0,
                                args={"backend": comm._backend})
            chan.drain_job(job_id)      # leaked requests die with the job
            flush_trace()
            chan.send_result(job_id, True, wire.encode_parts(result))
        except BaseException:  # noqa: BLE001 -- ship traceback, keep serving
            log.bound(rank=rank, world=size, job=job_id).debug(
                "closure raised:\n%s", traceback.format_exc())
            chan.drain_job(job_id)
            flush_trace()
            try:
                chan.send_result(job_id, False,
                                 wire.encode_parts(traceback.format_exc()))
            except (ConnectionError, OSError):
                break
    chan.close_peers()
    chan.close_shm()
    os._exit(0)


def main(argv: list[str] | None = None) -> None:
    """Module entry (``python -m repro.core.cluster.executor``): boot one
    rank on whatever machine this interpreter runs on and join the world
    at ``--driver``. This is the remote half of the spawn-and-connect
    bridge -- launchers wrap this exact command in ssh/srun/kubectl."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.cluster.executor",
        description="Boot one MPIgnite cluster executor and dial the "
                    "driver's control plane.")
    ap.add_argument("--rank", type=int, required=True,
                    help="this executor's world rank")
    ap.add_argument("--world", type=int, required=True,
                    help="total number of ranks")
    ap.add_argument("--driver", required=True, metavar="HOST:PORT",
                    help="driver control-plane address")
    ap.add_argument("--secret-file", default=None,
                    help="file holding the shared cluster secret "
                         f"(fallback: ${wire.SECRET_ENV})")
    ap.add_argument("--backend", default="linear",
                    help="default collective backend (linear|ring|native)")
    ap.add_argument("--timeout", type=float, default=60.0)
    ap.add_argument("--hb-interval", type=float, default=0.1)
    ap.add_argument("--data-plane", default="direct",
                    choices=("direct", "relay"))
    ap.add_argument("--bind-host", default="0.0.0.0",
                    help="interface for the data-plane listener "
                         "(default: all interfaces)")
    ap.add_argument("--advertise-host", default=None,
                    help="address peers should dial; defaults to the "
                         "local address of the route to the driver when "
                         "binding a wildcard")
    args = ap.parse_args(argv)

    host, _, port = args.driver.rpartition(":")
    if not host or not port.isdigit():
        ap.error(f"--driver must be HOST:PORT, got {args.driver!r}")
    secret = wire.load_secret(secret_file=args.secret_file)
    if not secret:
        ap.error("no shared secret: pass --secret-file or set "
                 f"${wire.SECRET_ENV}")
    executor_main(args.rank, args.world, (host, int(port)), args.backend,
                  args.timeout, args.hb_interval, args.data_plane,
                  bind_host=args.bind_host,
                  advertise_host=args.advertise_host, secret=secret)


if __name__ == "__main__":
    main()

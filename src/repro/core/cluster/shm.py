"""Shared-memory data-plane transport: per-rank inbound ring buffers.

This is the transport tier *below* the wire codec. Each executor that
enables it creates one ``multiprocessing.shared_memory`` segment before
saying hello and advertises the segment name (plus a host-identity
token) in the MAC-bound hello frame; the driver's peer broker
re-publishes ``(host, segment, slot)`` per world rank, and a sender
whose host token matches a receiver's attaches the receiver's segment
and writes into the ring indexed by its own *stable slot* -- giving one
single-producer / single-consumer ring per directed executor pair, no
locks, no syscalls on the hot path.

Ring layout (all cursors are monotonic uint64s, reduced mod capacity):

- a 64-byte segment header: ``MAGIC``, ring count, ring capacity;
- per ring, a 128-byte header block -- producer ``head`` at offset 0,
  consumer ``tail`` at offset 64 (separate cache lines, so the two
  sides never false-share);
- per ring, a ``ring_bytes`` data region of framed records
  ``[4B len][4B crc32][record bytes]``. A record's *bytes* may wrap
  around the region end (two slice copies); only the 8-byte header must
  be contiguous, so when fewer than 8 bytes remain before the end both
  sides deterministically skip them.

Records are whole wire frames (``wire.pack_frame`` blobs), so the codec
and the mailbox-matching header fields are byte-identical to the TCP
path. Writers commit by bumping ``head`` *after* the record bytes are
in place; readers bump ``tail`` after copying a record out.

The crc is not paranoia -- it is the correctness mechanism. On several
deployment targets (microVM kernels, snapshot/restore hypervisors) a
cross-process shared mapping is only *eventually* coherent at page
granularity: a reader can observe the freshly stored ``head`` while
some payload pages still show the previous lap's bytes. A lock-free
ring that trusts "cursor visible => payload visible" silently hands
stale bytes to the codec. So the consumer treats every inconsistency
-- implausible length, record larger than the published ``head-tail``
span, crc mismatch -- as *not yet visible* and simply retries on the
next poll without advancing ``tail``; transient staleness heals, and
nothing is ever surfaced to the mailbox until the checksum proves the
copy complete. Symmetrically the producer keeps a private monotonic
floor under its reads of ``tail`` (a torn read can never fabricate
free space and overwrite unread records).

Lifecycle: *nobody* who maps a segment unlinks it implicitly -- both
create and attach detach from the stdlib resource tracker -- because
the **driver** owns unlinking (on rank death, shrink, and shutdown).
That is what keeps ``/dev/shm`` clean when a rank is SIGKILL'd
mid-transfer: the mapping dies with the process, and the name is
reaped by the driver that brokered it.

Trust model: segment names are 128-bit random tokens brokered over the
authenticated control plane, and POSIX shared memory is same-UID
access like any local IPC -- the shm tier neither weakens nor replaces
the wire HMAC story, it just never crosses a machine boundary.
"""
from __future__ import annotations

import os
import secrets as _secrets
import socket as _socket
import struct
import time
import zlib
from multiprocessing import shared_memory

MAGIC = 0x4D50_4947          # "MPIG"
SEG_PREFIX = "mpig-"         # every segment name; chaos tests scan for it
_SEG_HDR = struct.Struct("<QQQ")     # (magic, nrings, ring_bytes)
_SEG_HDR_SIZE = 64
_RING_HDR_SIZE = 128         # head @ +0, tail @ +64 (distinct cache lines)
_U64 = struct.Struct("<Q")
_REC = struct.Struct("<II")  # record header: (length, crc32 of the bytes)

ENABLE_ENV = "MPIGNITE_SHM"
RING_BYTES_ENV = "MPIGNITE_SHM_RING_BYTES"
DEFAULT_RING_BYTES = 1 << 22         # 4 MiB per directed pair

_OFF = ("", "0", "false", "off", "no")


def enabled(default: bool = True) -> bool:
    """The ``MPIGNITE_SHM`` kill switch (default on). Read in the
    executor at segment creation and in the driver at pool construction
    (an explicit ``shm=`` argument to the pool wins)."""
    raw = os.environ.get(ENABLE_ENV)
    if raw is None:
        return default
    return raw.strip().lower() not in _OFF


def ring_bytes() -> int:
    """Per-ring capacity. tmpfs pages are allocated on first touch, so
    over-provisioning ring count is cheap; capacity bounds the largest
    single *record* -- frames bigger than that are fragmented across
    records by the sending channel and reassembled by the receiver."""
    raw = os.environ.get(RING_BYTES_ENV)
    if not raw:
        return DEFAULT_RING_BYTES
    try:
        n = int(raw)
    except ValueError:
        return DEFAULT_RING_BYTES
    return n if n >= (1 << 12) else DEFAULT_RING_BYTES


def host_token() -> str:
    """An identity two processes share iff they can plausibly share
    ``/dev/shm``. The boot id distinguishes hosts that happen to share
    a hostname; a false positive (containers sharing a kernel but not
    an ipc namespace) is caught by the attach-failure TCP fallback."""
    try:
        with open("/proc/sys/kernel/random/boot_id") as f:
            boot = f.read().strip()
    except OSError:
        boot = ""
    return f"{_socket.gethostname()}|{boot}"


def _untrack(seg: shared_memory.SharedMemory) -> None:
    """Detach a mapping from the stdlib resource tracker so that *this*
    process exiting never unlinks the name -- the driver owns that."""
    try:  # py >= 3.13 grew track=False; older versions need surgery
        from multiprocessing import resource_tracker
        resource_tracker.unregister(seg._name, "shared_memory")
    except Exception:  # noqa: BLE001 -- tracker internals are version-
        pass           # dependent; worst case is an early unlink at exit


class ShmRings:
    """One segment holding ``nrings`` SPSC rings. The owning rank reads
    every ring; each remote sender writes exactly one (its slot)."""

    def __init__(self, seg: shared_memory.SharedMemory, owned: bool):
        self._seg = seg
        self.owned = owned
        self.name = seg.name
        buf = seg.buf
        magic, nrings, cap = _SEG_HDR.unpack_from(buf, 0)
        if magic != MAGIC:
            raise ValueError(f"segment {seg.name!r} is not an MPIgnite "
                             f"ring segment")
        self.nrings = int(nrings)
        self.cap = int(cap)
        self._data0 = _SEG_HDR_SIZE + self.nrings * _RING_HDR_SIZE
        # producer-side monotonic floor under observed tails (see below)
        self._tail_floor: dict[int, int] = {}

    # -- construction -------------------------------------------------------
    @classmethod
    def create(cls, nrings: int, cap: int | None = None) -> "ShmRings":
        cap = ring_bytes() if cap is None else int(cap)
        size = _SEG_HDR_SIZE + nrings * _RING_HDR_SIZE + nrings * cap
        name = SEG_PREFIX + _secrets.token_hex(16)
        seg = shared_memory.SharedMemory(name=name, create=True, size=size)
        _untrack(seg)
        _SEG_HDR.pack_into(seg.buf, 0, MAGIC, nrings, cap)
        return cls(seg, owned=True)

    @classmethod
    def attach(cls, name: str) -> "ShmRings":
        seg = shared_memory.SharedMemory(name=name, create=False)
        _untrack(seg)
        return cls(seg, owned=False)

    # -- cursors ------------------------------------------------------------
    def _hdr(self, ring: int) -> int:
        return _SEG_HDR_SIZE + ring * _RING_HDR_SIZE

    def _head(self, ring: int) -> int:
        return _U64.unpack_from(self._seg.buf, self._hdr(ring))[0]

    def _tail(self, ring: int) -> int:
        return _U64.unpack_from(self._seg.buf, self._hdr(ring) + 64)[0]

    def _set_head(self, ring: int, v: int) -> None:
        _U64.pack_into(self._seg.buf, self._hdr(ring), v)

    def _set_tail(self, ring: int, v: int) -> None:
        _U64.pack_into(self._seg.buf, self._hdr(ring) + 64, v)

    def _data(self, ring: int) -> int:
        return self._data0 + ring * self.cap

    # -- producer -----------------------------------------------------------
    def max_record(self) -> int:
        """Largest record a ring can ever hold (one skip pad + header)."""
        return self.cap - 2 * _REC.size

    def _safe_tail(self, ring: int, head: int) -> int:
        """The consumer's tail as this producer may trust it. A stale
        read only ever *under*-reports freed space (tail is monotonic),
        which is merely conservative -- but a torn read could fabricate
        a larger tail and let us overwrite unread records. So clamp:
        accept an observed tail only if it is within [floor, head]."""
        t = self._tail(ring)
        floor = self._tail_floor.get(ring, 0)
        if t < floor or t > head:
            return floor
        self._tail_floor[ring] = t
        return t

    def write(self, ring: int, record: bytes,
              deadline: float = 30.0) -> bool:
        """Append one record to ``ring``. Returns False when the record
        can never fit (caller sends via TCP instead); raises
        ``ConnectionError`` when the ring stays full past ``deadline``
        seconds (the consumer is wedged or dead -- backpressure here is
        the moral equivalent of a TCP send blocking forever)."""
        if ring < 0 or ring >= self.nrings:
            return False
        n = len(record)
        if n > self.max_record():
            return False
        buf = self._seg.buf
        cap = self.cap
        head = self._head(ring)
        pos = head % cap
        pad = (cap - pos) if (cap - pos) < _REC.size else 0
        need = pad + _REC.size + n
        t_end = time.monotonic() + deadline
        delay = 0.0
        while cap - (head - self._safe_tail(ring, head)) < need:
            if time.monotonic() >= t_end:
                raise ConnectionError(
                    f"shm ring {ring} of {self.name} full for "
                    f"{deadline:.0f}s (record {n} bytes)")
            time.sleep(delay)
            delay = min(0.001, delay + 0.00005)
        if pad:
            head += pad
            pos = 0
        base = self._data(ring)
        _REC.pack_into(buf, base + pos, n, zlib.crc32(record))
        pos = (pos + _REC.size) % cap
        first = min(n, cap - pos)
        buf[base + pos:base + pos + first] = record[:first]
        if first < n:
            buf[base:base + (n - first)] = record[first:]
        # commit: the cursor store is what publishes the record
        self._set_head(ring, head + _REC.size + n)
        return True

    # -- consumer -----------------------------------------------------------
    def try_read(self, ring: int) -> bytes | None:
        """Pop one record (a copy), or None when the ring is empty *or*
        the next record is not yet fully visible from this process.

        Never raises and never advances ``tail`` speculatively: a
        garbled length, a record overrunning the published span, or a
        crc mismatch all mean some page of the producer's write has not
        reached us yet (see the module docstring), so the caller simply
        polls again. Validation, not ordering, is what makes the ring
        correct here."""
        buf = self._seg.buf
        cap = self.cap
        tail = self._tail(ring)
        head = self._head(ring)
        avail = head - tail
        if avail <= 0:                  # empty (or a stale head view)
            return None
        pos = tail % cap
        if (cap - pos) < _REC.size:     # producer skipped the end stub
            skip = cap - pos            # (a commit always covers its pad)
            if avail < skip + _REC.size:
                return None             # pad committed but not visible yet
            tail += skip
            avail -= skip
            pos = 0
        base = self._data(ring)
        n, crc = _REC.unpack_from(buf, base + pos)
        if n > self.max_record() or _REC.size + n > avail:
            return None                 # header bytes still stale
        pos = (pos + _REC.size) % cap
        first = min(n, cap - pos)
        out = bytes(buf[base + pos:base + pos + first])
        if first < n:
            out += bytes(buf[base:base + (n - first)])
        if zlib.crc32(out) != crc:
            return None                 # payload pages still stale
        self._set_tail(ring, tail + _REC.size + n)
        return out

    def pending(self, ring: int) -> int:
        """Unread bytes in a ring (diagnostics / adaptive-poll hints)."""
        return self._head(ring) - self._tail(ring)

    def close(self) -> None:
        try:
            self._seg.close()
        except (OSError, BufferError):
            pass


def unlink(name: str) -> bool:
    """Remove a segment name; True if it existed. Driver-only: called
    for a rank's advertised segment when that rank dies, shrinks away,
    or the pool shuts down. Attached survivors keep their mappings (a
    POSIX unlink removes the name, not live maps)."""
    try:
        seg = shared_memory.SharedMemory(name=name, create=False)
    except (FileNotFoundError, OSError):
        return False
    # no _untrack here: SharedMemory.unlink() unregisters the name
    # itself, pairing with the register this attach just performed
    try:
        seg.unlink()
    except (FileNotFoundError, OSError):
        pass
    finally:
        try:
            seg.close()
        except (OSError, BufferError):
            pass
    return True

"""Pure (numpy/python) rank-group machinery shared by every comm backend.

This module is deliberately free of JAX so that its invariants can be
property-tested with hypothesis directly: communicator splits, ring
permutations, chunking/padding and the byte-cost model of each collective
algorithm are all plain functions of python ints.

Terminology
-----------
- *axis rank*: a device's index along the mesh axis a communicator spans.
- *comm rank*: the rank the user sees inside a (possibly split)
  communicator -- its position within its group.
- *groups*: a partition of the axis ranks into equally-sized tuples.
  ``groups=None`` means the single group ``(0, 1, ..., P-1)``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import pickle
from typing import Any, Callable, Sequence

Groups = tuple[tuple[int, ...], ...]


def world_groups(size: int) -> Groups:
    return (tuple(range(size)),)


def validate_groups(groups: Groups, size: int) -> None:
    """Groups must partition range(size) into equal-size, duplicate-free sets."""
    flat = [r for g in groups for r in g]
    if sorted(flat) != list(range(size)):
        raise ValueError(
            f"groups {groups} do not partition range({size})")
    sizes = {len(g) for g in groups}
    if len(sizes) != 1:
        raise ValueError(
            f"unequal group sizes {sorted(len(g) for g in groups)}; the SPMD "
            "backends require uniform sub-communicator sizes")


def split_groups(parent: Groups, colors: Sequence[int],
                 keys: Sequence[int]) -> dict[int, Groups]:
    """MPI_Comm_split semantics (paper section 3.1).

    ``colors[i]``/``keys[i]`` are given per *comm rank* ``i`` of each parent
    group (every parent group is split with the same color/key tables, which
    is what a mesh-structured split needs). Within a color, members are
    ordered by (key, parent comm rank) -- exactly the sort the MPIgnite root
    performs before broadcasting the new rank mapping.

    Returns ``{color: groups}`` where each value partitions only the ranks
    holding that color (across all parent groups).
    """
    n = len(colors)
    if len(keys) != n:
        raise ValueError("colors and keys must have equal length")
    for g in parent:
        if len(g) != n:
            raise ValueError(
                f"color/key tables (len {n}) must match parent group size {len(g)}")
    out: dict[int, list[tuple[int, ...]]] = {}
    for g in parent:
        bycolor: dict[int, list[tuple[int, int]]] = {}
        for comm_rank, axis_rank in enumerate(g):
            bycolor.setdefault(colors[comm_rank], []).append(
                (keys[comm_rank], comm_rank))
        for color, members in bycolor.items():
            members.sort()  # by (key, parent comm rank)
            out.setdefault(color, []).append(
                tuple(g[comm_rank] for _, comm_rank in members))
    return {c: tuple(gs) for c, gs in out.items()}


def context_id(groups: Groups, parent_ctx: int) -> int:
    """Deterministic context identifier for a communicator (paper: used to
    fence messages within the group that participated in a split)."""
    h = hashlib.sha256(repr((parent_ctx, groups)).encode()).hexdigest()
    return int(h[:12], 16)


def comm_rank_table(groups: Groups, size: int) -> list[int]:
    """axis rank -> comm rank (position within its group)."""
    table = [-1] * size
    for g in groups:
        for i, axis_rank in enumerate(g):
            table[axis_rank] = i
    return table


def group_id_table(groups: Groups, size: int) -> list[int]:
    """axis rank -> index of the group containing it."""
    table = [-1] * size
    for gi, g in enumerate(groups):
        for axis_rank in g:
            table[axis_rank] = gi
    return table


def ring_perm(groups: Groups, shift: int) -> list[tuple[int, int]]:
    """Global (src, dst) pairs realizing a ring shift by ``shift`` within
    every group simultaneously. A union of in-group cycles is still a valid
    global permutation, which is what lax.ppermute requires."""
    pairs: list[tuple[int, int]] = []
    for g in groups:
        p = len(g)
        for i, src in enumerate(g):
            pairs.append((src, g[(i + shift) % p]))
    return pairs


def p2p_perm(groups: Groups, pairs: Sequence[tuple[int, int]],
             size: int) -> list[tuple[int, int]]:
    """Translate comm-rank (src, dst) pairs into global axis-rank pairs,
    enforcing the paper's context isolation *statically*: a pair that crosses
    group boundaries is a trace-time error, and duplicate senders/receivers
    (not a permutation) are rejected."""
    gid = group_id_table(groups, size)
    out: list[tuple[int, int]] = []
    seen_src: set[int] = set()
    seen_dst: set[int] = set()
    for src_cr, dst_cr in pairs:
        for g in groups:
            p = len(g)
            if not (0 <= src_cr < p and 0 <= dst_cr < p):
                raise ValueError(
                    f"p2p rank pair ({src_cr},{dst_cr}) out of range for "
                    f"communicator of size {p}")
            s, d = g[src_cr], g[dst_cr]
            if gid[s] != gid[d]:  # cannot happen given construction; guard anyway
                raise ValueError(
                    "message would cross sub-communicator boundary "
                    f"({s} -> {d}); context isolation violated")
            if s in seen_src:
                raise ValueError(f"duplicate sender comm-rank {src_cr}")
            if d in seen_dst:
                raise ValueError(f"duplicate receiver comm-rank {dst_cr}")
            seen_src.add(s)
            seen_dst.add(d)
            out.append((s, d))
    return out


# ---------------------------------------------------------------------------
# Byte-cost model (per device, per call) for each collective algorithm.
# These analytic counts back the §Roofline collective term and are asserted
# against the HLO-parsed byte counts in tests (within padding slack).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    op: str
    backend: str
    bytes_per_device: int
    steps: int
    #: logged from inside a nonblocking (i*) collective: these bytes are
    #: candidates for communication/compute overlap, so roofline terms
    #: may discount them against the compute term instead of serializing.
    overlap: bool = False


def collective_cost(op: str, backend: str, nbytes: int, p: int) -> CollectiveCost:
    """Bytes sent per device for one collective of payload ``nbytes`` over a
    group of size ``p``.

    linear -- the paper's phase-1 master-relay: gather-to-root then
    root-broadcast, O(p * S) wire bytes, 2(p-1) serial full-size steps.
    ring   -- phase-2 peer-to-peer: chunked reduce-scatter + all-gather,
    O(2S) bytes in 2(p-1) chunk-size steps.
    segmented -- the message-runtime segmented ring (reduce-scatter +
    all-gather over MPIGNITE_SEGMENT_BYTES pieces): same bandwidth-optimal
    byte count as ``ring``, pipelined into segment-size steps.
    native -- XLA collectives; modeled with the ring byte count (XLA lowers
    to ring/tree variants with the same asymptotics) but fusable/overlappable.
    """
    if p <= 1:
        return CollectiveCost(op, backend, 0, 0)
    S = nbytes
    if backend == "linear":
        table = {
            "allreduce": (2 * (p - 1) * S, 2 * (p - 1)),
            "broadcast": ((p - 1) * S, p - 1),
            "allgather": (2 * (p - 1) * S, 2 * (p - 1)),   # relay in + relay out
            "reducescatter": ((2 * p - 1) * S // 1, 2 * (p - 1)),
            "alltoall": ((p - 1) * S, p - 1),              # relay full buffer
            "p2p": (S, 1),
        }
    elif backend in ("ring", "native", "segmented"):
        table = {
            "allreduce": (2 * S * (p - 1) // p, 2 * (p - 1)),
            # segmented maps to the ring relay in SPMD (comm._algo), so
            # its broadcast moves ring's bytes, not native's fused S
            "broadcast": ((p - 1) * S if backend != "native" else S, p - 1),
            "allgather": (S * (p - 1) // p, p - 1),
            "reducescatter": (S * (p - 1) // p, p - 1),
            "alltoall": (S * (p - 1) // p, p - 1),
            "p2p": (S, 1),
        }
    else:
        raise ValueError(f"unknown backend {backend}")
    b, steps = table[op]
    return CollectiveCost(op, backend, int(b), steps)


#: Transport-tier rows for the analytic time estimate: nominal per-hop
#: latency and bandwidth for each data-plane channel the cluster runtime
#: can pick. These are planning figures (same spirit as the byte model
#: above), not measurements -- the shm benchmark gate compares *measured*
#: ratios and only uses these to annotate the expected direction.
#: ``relay`` is the driver-bounce fallback: two TCP hops per message.
TRANSPORT_COST = {
    "tcp": {"latency_us": 50.0, "gib_s": 3.0},
    "shm": {"latency_us": 5.0, "gib_s": 12.0},
    "relay": {"latency_us": 100.0, "gib_s": 1.5},
}


def transport_time_us(transport: str, nbytes: int, steps: int = 1) -> float:
    """Analytic wall-time estimate for moving ``nbytes`` over ``steps``
    serial hops of one transport tier (alpha-beta model over the
    ``TRANSPORT_COST`` rows)."""
    row = TRANSPORT_COST[transport]
    return steps * row["latency_us"] + \
        (nbytes / (row["gib_s"] * 2 ** 30)) * 1e6


def pad_to_multiple(n: int, p: int) -> int:
    return (n + p - 1) // p * p


# ---------------------------------------------------------------------------
# Segmented-ring chunk/segment math. Pure ints so every rank computes the
# identical partition from (payload size, world size, segment size) alone --
# no negotiation messages -- and so the invariants are hypothesis-testable.
# ---------------------------------------------------------------------------

def chunk_bounds(n: int, p: int) -> list[int]:
    """``p + 1`` boundaries splitting ``range(n)`` into ``p`` contiguous
    near-equal chunks (the first ``n % p`` chunks get one extra element,
    so no payload size needs padding). Chunk ``i`` is
    ``[bounds[i], bounds[i+1])``; chunks may be empty when ``n < p``."""
    if p < 1:
        raise ValueError(f"need at least one chunk, got p={p}")
    base, rem = divmod(n, p)
    bounds = [0]
    for i in range(p):
        bounds.append(bounds[-1] + base + (1 if i < rem else 0))
    return bounds


def segment_spans(length: int, seg: int) -> list[tuple[int, int]]:
    """``(start, stop)`` spans of at most ``seg`` elements covering
    ``range(length)`` in order -- the per-hop message schedule of a
    segmented transfer. Empty for ``length <= 0`` (an empty chunk moves
    zero messages, on both ends, by construction)."""
    if seg < 1:
        raise ValueError(f"segment size must be >= 1, got {seg}")
    if length <= 0:
        return []
    return [(a, min(a + seg, length)) for a in range(0, length, seg)]


# ---------------------------------------------------------------------------
# Elastic-world remap math. Membership changes (shrink-to-survivors,
# grow-on-join) re-number the world; every schedule above is a pure
# function of (rank, size), so remapping is nothing but a rank table.
# ---------------------------------------------------------------------------

def buddy_rank(rank: int, size: int, offset: int = 1) -> int:
    """The rank holding this rank's buddy snapshot: the next rank around
    the ring (``offset`` hops). A world of one is its own buddy."""
    if size < 1:
        raise ValueError(f"need at least one rank, got size={size}")
    return (rank + offset) % size


def survivor_map(world: Sequence[int], dead: Sequence[int]) -> dict[int, int]:
    """Contiguous re-numbering of the survivors of ``world`` (stable
    identities, e.g. launch slots) after ``dead`` members are removed:
    ``{member: new_rank}`` preserving the original order. Raises if
    nothing survives."""
    dead_set = set(dead)
    survivors = [m for m in world if m not in dead_set]
    if not survivors:
        raise ValueError(f"no survivors in world {list(world)} "
                         f"after deaths {sorted(dead_set)}")
    return {m: i for i, m in enumerate(survivors)}


def remap_group(group: Sequence[int], rank_map: dict[int, int]
                ) -> tuple[int, ...]:
    """Translate a group of old ranks through a membership remap,
    dropping members that did not survive. Order (and therefore every
    ring schedule derived from the group) is preserved."""
    return tuple(rank_map[r] for r in group if r in rank_map)


# ---------------------------------------------------------------------------
# Dataset partition placement (``repro.data.dataset``). Placement is a pure
# function of (partition, world size) -- every rank and the driver compute
# the identical owner table with zero negotiation messages -- and it is
# membership-aware by construction: after a shrink-to-survivors the same
# formula over the new size re-homes the dead ranks' partitions onto
# survivors, which is exactly what lineage recovery needs.
# ---------------------------------------------------------------------------

def partition_owner(part: int, nparts: int, size: int) -> int:
    """World rank owning dataset partition ``part`` (round-robin, so a
    shrink moves the fewest partitions and keeps load balanced)."""
    if not 0 <= part < nparts:
        raise ValueError(f"partition {part} out of range({nparts})")
    if size < 1:
        raise ValueError(f"need at least one rank, got size={size}")
    return part % size


def owned_partitions(rank: int, nparts: int, size: int) -> list[int]:
    """Partitions ``rank`` owns under round-robin placement, ascending.
    Empty when ``nparts < size`` leaves this rank without work (it still
    participates in every shuffle collective with empty contributions)."""
    if not 0 <= rank < size:
        raise ValueError(f"rank {rank} out of range({size})")
    return list(range(rank, nparts, size))


def shuffle_rounds(nparts: int, size: int) -> int:
    """Number of shuffle rounds every rank posts per wide stage. The
    collectives are matched by call order, so the count must be uniform:
    ranks owning fewer than ``shuffle_rounds`` partitions contribute
    empty chunks in their trailing rounds."""
    if size < 1:
        raise ValueError(f"need at least one rank, got size={size}")
    return -(-nparts // size)


def lost_partitions(nparts: int, dead_old_ranks: Sequence[int],
                    old_size: int) -> set[int]:
    """Partitions whose materialized copy died with their previous-epoch
    owner -- the set a post-shrink retry must recompute from lineage
    (``shrink_info['dead_old_ranks']`` / ``['old_size']`` feed this)."""
    dead = set(dead_old_ranks)
    return {p for p in range(nparts)
            if partition_owner(p, nparts, old_size) in dead}


def stable_key_hash(key: Any) -> int:
    """Process-stable shuffle hash of an arbitrary picklable key.

    Python's builtin ``hash`` is salted per process (PYTHONHASHSEED), so
    two executors would route the same key to different partitions;
    blake2b over the pickle of the key gives every process -- and the
    single-process oracle -- the identical bucket. Keys must pickle
    deterministically (strings, ints, tuples of those all do)."""
    blob = pickle.dumps(key, protocol=4)
    return int.from_bytes(hashlib.blake2b(blob, digest_size=8).digest(),
                          "big")


ReduceFn = Callable  # (a, b) -> elementwise combine; must be associative

"""Version compatibility shims for the JAX APIs this repo relies on.

The codebase targets the modern spellings (``jax.shard_map``,
``jax.set_mesh``); older installed versions (0.4.x) ship the same
functionality as ``jax.experimental.shard_map.shard_map`` and the
``Mesh`` context manager. Import from here instead of ``jax`` directly.
"""
from __future__ import annotations

import contextlib

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.6: experimental namespace; check_vma was called check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kw)


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return _enter_mesh(mesh)


@contextlib.contextmanager
def _enter_mesh(mesh):
    with mesh:
        yield mesh

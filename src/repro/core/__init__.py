"""MPIgnite-JAX core: the paper's contribution as a composable JAX module.

- ``groups``    : pure rank/group math (split, rings, byte-cost model)
- ``matching``  : transport-agnostic mailbox matching + p2p-composed
                  collectives (``MessageComm`` base)
- ``local``     : thread-runtime communicator (paper's local mode; oracle)
- ``cluster``   : multi-process peer runtime over TCP (wire protocol,
                  persistent executor pool, direct peer data channels,
                  heartbeats, elastic ``ClusterSupervisor`` recovery:
                  shrink-to-survivors, grow-on-join, checkpoint-restart)
- ``comm``      : SPMD ``PeerComm`` over mesh axes (linear/ring/native)
- ``closures``  : ``parallelize_func(f).execute(n)`` in local, cluster or
                  SPMD mode
- ``compat``    : shims over jax version differences (shard_map, set_mesh)
"""
from . import compat, groups
from .comm import PeerComm, cost_log, cost_scope
from .closures import (MPIgniteContext, ParallelClosure, RANK_AXIS, flat_mesh,
                       parallelize_func)
from .cluster import (ClusterComm, ClusterFuncRDD, ClusterPool,
                      CommandLauncher, ExecutorFailure, ExecutorPool,
                      ForkLauncher, get_pool, shutdown_pools)
from .local import LocalComm, ParallelFuncRDD
from .matching import (Mailbox, MessageComm, PeerDeadError, ProgressEngine,
                       Request, waitall, waitany)

__all__ = [
    "groups", "compat", "PeerComm", "cost_log", "cost_scope",
    "MPIgniteContext", "ParallelClosure",
    "RANK_AXIS", "flat_mesh", "parallelize_func", "LocalComm",
    "ParallelFuncRDD", "ClusterComm", "ClusterFuncRDD", "ClusterPool",
    "ClusterSupervisor", "CommandLauncher", "ExecutorFailure",
    "ExecutorPool", "ForkLauncher", "RunContext",
    "get_pool", "shutdown_pools", "Mailbox", "MessageComm",
    "PeerDeadError", "ProgressEngine", "Request", "waitall", "waitany",
]


def __getattr__(name):
    # Lazy like cluster.__init__: the supervisor imports repro.train,
    # which imports repro.core back -- resolving it at package init
    # would cycle.
    if name in ("ClusterSupervisor", "RunContext"):
        from . import cluster
        return getattr(cluster, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""MPIgnite-JAX core: the paper's contribution as a composable JAX module.

- ``groups``    : pure rank/group math (split, rings, byte-cost model)
- ``local``     : thread-runtime communicator (paper's local mode; oracle)
- ``comm``      : SPMD ``PeerComm`` over mesh axes (linear/ring/native)
- ``closures``  : ``parallelize_func(f).execute(n)`` in local or SPMD mode
"""
from . import groups
from .comm import PeerComm, cost_log, cost_scope
from .closures import (MPIgniteContext, ParallelClosure, RANK_AXIS, flat_mesh,
                       parallelize_func)
from .local import LocalComm, ParallelFuncRDD

__all__ = [
    "groups", "PeerComm", "cost_log", "cost_scope", "MPIgniteContext",
    "ParallelClosure",
    "RANK_AXIS", "flat_mesh", "parallelize_func", "LocalComm",
    "ParallelFuncRDD",
]

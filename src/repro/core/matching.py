"""Transport-agnostic message matching and p2p-composed collectives.

The paper's runtime semantics -- receiver-side buffering with dynamic
``(ctx, tag, src)`` matching, always-nonblocking sends, futures for
``receiveAsync``, and collectives composed from point-to-point messages
(phase-1 master relay through a root, phase-2 ring) -- do not depend on
*how* a message travels. This module holds everything above the
transport: the matched ``Mailbox`` and the ``MessageComm`` base class.

Two transports plug in underneath:

- ``local.LocalComm``      : in-process delivery between worker threads
  (the paper's local deployment; the semantic oracle).
- ``cluster.ClusterComm``  : length-prefixed TCP frames on direct
  executor-to-executor channels (or relayed through the driver) between
  genuinely separate executor processes (the paper's cluster
  deployment).

A subclass provides three hooks: ``_put`` (deliver a payload to a world
rank's mailbox), ``_get`` (matched receive from this rank's own mailbox)
and ``_clone`` (construct a same-transport communicator for ``split``).
"""
from __future__ import annotations

import functools
import hashlib
import heapq
import itertools
import os
import queue
import threading
import time
from collections import deque
from concurrent import futures as _futures
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Generator, Sequence

import numpy as np

from . import groups as G
from .obs.trace import current_span, set_current_span


def payload_nbytes(data: Any) -> int:
    """Payload size of a message body as the cost model counts it: array
    bytes (recursing through the small tuples/lists schedules send, e.g.
    a broadcast's ``("whole", data)`` meta); scalars/None count as zero
    -- they carry no model-priced payload, only latency."""
    if isinstance(data, np.ndarray):
        return data.nbytes
    if isinstance(data, (bytes, bytearray, memoryview)):
        return len(data)
    if isinstance(data, (list, tuple)):
        return sum(payload_nbytes(x) for x in data)
    return 0


class PeerDeadError(ConnectionError):
    """A peer rank died while an operation depended on it: the failure
    detector declared the world broken, so every pending receive (blocking
    or request-backed) is failed instead of waiting out its timeout."""

#: algorithms available to message-composed collectives. ``linear`` is the
#: paper's phase-1 (every byte relays through a root/master); ``ring`` is
#: the phase-2 peer-to-peer mode (large arrays stream through segmented
#: reduce-scatter/all-gather schedules automatically -- see
#: ``MPIGNITE_SEGMENT_BYTES``); ``segmented`` forces the segmented ring
#: schedules regardless of payload size (tests, benchmarks). ``native`` is
#: accepted as an alias of ``linear`` so closures written for the SPMD
#: backend run unchanged -- linear is the runtime default because its
#: root-ordered fold keeps ``allreduce`` deterministic for arbitrary
#: (non-commutative) functions, the property the thread oracle documents.
MESSAGE_BACKENDS = ("linear", "ring", "segmented")

_BACKEND_ALIASES = {"native": "linear", "segmented-ring": "segmented"}

#: env knob for the segmented ring schedules: arrays at least this many
#: bytes stream through the ring in segments of this size (<= 0 disables
#: the automatic upgrade; the explicit ``segmented`` backend then uses the
#: default size). Read at call time so executors honor per-job changes.
SEGMENT_ENV = "MPIGNITE_SEGMENT_BYTES"
DEFAULT_SEGMENT_BYTES = 256 * 1024


def normalize_backend(backend: str) -> str:
    backend = _BACKEND_ALIASES.get(backend, backend)
    if backend not in MESSAGE_BACKENDS:
        raise ValueError(f"unknown message backend {backend!r}; "
                         f"expected one of {MESSAGE_BACKENDS} or an alias "
                         f"in {tuple(_BACKEND_ALIASES)}")
    return backend


_warned_segment_env: set[str] = set()


def env_segment_bytes() -> int:
    """The process-wide segment-size default (``$MPIGNITE_SEGMENT_BYTES``),
    read at call time: per collective in the in-process runtime, and
    once per job *at the driver* in cluster mode (the resolved value
    ships in the job frame so all ranks agree -- see ``ExecutorPool.run``).
    A malformed value (e.g. ``1M`` -- only plain byte counts are
    accepted) warns once and falls back to the default, so a mis-set
    tuning knob is visible instead of silently ignored."""
    raw = os.environ.get(SEGMENT_ENV)
    if raw is None:
        return DEFAULT_SEGMENT_BYTES
    try:
        return int(raw)
    except ValueError:
        if raw not in _warned_segment_env:
            _warned_segment_env.add(raw)
            import warnings
            warnings.warn(
                f"${SEGMENT_ENV}={raw!r} is not an integer byte count; "
                f"using the default ({DEFAULT_SEGMENT_BYTES})",
                RuntimeWarning, stacklevel=2)
        return DEFAULT_SEGMENT_BYTES


def _cat(parts: list) -> Any:
    """Reassemble received 1-D segments (skip the copy when a transfer
    arrived as a single segment)."""
    return np.concatenate(parts) if len(parts) > 1 else parts[0]


@functools.lru_cache(maxsize=1024)
def stable_ctx(ctx: int, tag: int, key: tuple) -> int:
    """Deterministic collective-context id, identical across processes
    (``hash()`` is salted per interpreter, so it cannot go on the wire).
    Cached: one collective calls this with identical arguments for every
    constituent message (2(p-1) times at a linear allreduce root)."""
    h = hashlib.blake2b(repr((ctx, tag, key)).encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "big")


_DELIVER: tuple[int, ThreadPoolExecutor] | None = None
_DELIVER_LOCK = threading.Lock()


def _deliver_pool() -> ThreadPoolExecutor:
    """One shared worker that completes async-receive Futures, so user
    done-callbacks never run on (and never stall) a transport reader
    thread. Keyed by pid: a forked child would otherwise inherit an
    executor whose worker thread does not exist."""
    global _DELIVER
    with _DELIVER_LOCK:
        if _DELIVER is None or _DELIVER[0] != os.getpid():
            _DELIVER = (os.getpid(), ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="mailbox-deliver"))
        return _DELIVER[1]


class _Waiter:
    """One pending ``receive_async``: a Future registered on a mailbox key.
    Claiming (under the mailbox lock) decides exactly one outcome --
    delivery by ``Mailbox.put`` or expiry by the shared ``_Expiry``
    thread -- so the two can never both complete the Future.

    ``inline=True`` (progress-engine waiters) completes the Future on the
    delivering thread instead of hopping through the shared deliver pool:
    the engine's done-callback only enqueues a token, so it is safe on a
    transport reader, and skipping the hop halves the per-step wakeup
    latency a nonblocking collective pays under CPU contention."""
    __slots__ = ("mailbox", "key", "fut", "deadline", "claimed", "inline",
                 "t0")

    def __init__(self, mailbox: "Mailbox", key: tuple, fut: Future,
                 deadline: float, inline: bool = False, t0: int = 0):
        self.mailbox = mailbox
        self.key = key
        self.fut = fut
        self.deadline = deadline
        self.claimed = False
        self.inline = inline
        self.t0 = t0        # park time (perf_counter_ns); 0 when untraced

    def expire(self) -> None:
        with self.mailbox.lock:
            if self.claimed:
                return
            self.claimed = True
            dq = self.mailbox.waiters.get(self.key)
            if dq is not None:
                try:
                    dq.remove(self)
                except ValueError:
                    pass
                if not dq:
                    del self.mailbox.waiters[self.key]
        ctx, tag, src = self.key
        _deliver_pool().submit(self.fut.set_exception, TimeoutError(
            f"receive(src={src}, tag={tag}, ctx={ctx}) timed out"))

    def cancel(self) -> bool:
        """Claim the waiter for cancellation (MPI_Cancel on a receive):
        the message, if it ever arrives, stays buffered for someone else."""
        with self.mailbox.lock:
            if self.claimed:
                return False
            self.claimed = True
            dq = self.mailbox.waiters.get(self.key)
            if dq is not None:
                try:
                    dq.remove(self)
                except ValueError:
                    pass
                if not dq:
                    del self.mailbox.waiters[self.key]
        _deliver_pool().submit(self.fut.set_exception,
                               _futures.CancelledError())
        return True


class _Expiry(threading.Thread):
    """Single shared timer servicing every async waiter's deadline -- the
    'small shared waiter pool' that replaces thread-per-``receive_async``.
    One daemon thread per process, started on first use."""

    _instance: "_Expiry | None" = None
    _instance_lock = threading.Lock()

    def __init__(self):
        super().__init__(daemon=True, name="mailbox-expiry")
        self.cond = threading.Condition()
        self.heap: list[tuple[float, int, _Waiter]] = []
        self.seq = itertools.count()

    @classmethod
    def instance(cls) -> "_Expiry":
        with cls._instance_lock:
            if cls._instance is None or not cls._instance.is_alive():
                cls._instance = cls()
                cls._instance.start()
            return cls._instance

    def add(self, waiter: _Waiter) -> None:
        with self.cond:
            heapq.heappush(self.heap, (waiter.deadline, next(self.seq),
                                       waiter))
            self.cond.notify()

    def run(self) -> None:
        while True:
            with self.cond:
                while not self.heap:
                    self.cond.wait()
                deadline, _, waiter = self.heap[0]
                now = time.monotonic()
                if waiter.claimed:
                    heapq.heappop(self.heap)
                    continue
                if now < deadline:
                    self.cond.wait(deadline - now)
                    continue
                heapq.heappop(self.heap)
            waiter.expire()     # outside our cond; takes the mailbox lock


@dataclass
class Mailbox:
    """Receiver-side buffering: unmatched messages wait here (paper: 'we
    buffer messages on the receiving worker'). Messages are indexed by
    their full ``(ctx, tag, src)`` match key -- put/get are O(1) dict
    operations, not a scan of every buffered message -- with a deque per
    key preserving arrival order for same-key messages.

    Health counters (``depth``/``peak_depth``/``total_matched``/
    ``poisoned_waiters``) are always-on: integer adds under the lock the
    operation already holds, exposed so operators can see queue pressure
    without enabling tracing. ``tracer`` is the optional per-rank event
    recorder; every trace hook guards on it being non-None so the
    disabled path costs one pointer compare."""
    lock: threading.Lock = field(default_factory=threading.Lock)
    cond: threading.Condition = None  # type: ignore[assignment]
    queues: dict[tuple[int, int, int], deque] = field(default_factory=dict)
    waiters: dict[tuple[int, int, int], deque] = field(default_factory=dict)
    #: non-None once the failure detector declared a peer dead: every
    #: receive that would block raises PeerDeadError(poison) instead.
    poison: str | None = None
    #: messages currently buffered (arrived, not yet matched)
    depth: int = 0
    #: high-water mark of ``depth`` over the mailbox's lifetime
    peak_depth: int = 0
    #: receives satisfied (buffered hit, blocking wake, or waiter fire)
    total_matched: int = 0
    #: async waiters failed by ``poison_all``
    poisoned_waiters: int = 0
    #: per-rank ``obs.Tracer`` when tracing is enabled, else None
    tracer: Any = None

    def health(self) -> dict:
        with self.lock:
            return {"depth": self.depth, "peak_depth": self.peak_depth,
                    "total_matched": self.total_matched,
                    "poisoned_waiters": self.poisoned_waiters,
                    "waiting": sum(len(dq) for dq in self.waiters.values())}

    def __post_init__(self):
        self.cond = threading.Condition(self.lock)

    def poison_all(self, reason: str) -> None:
        """Fail every pending receive and every future blocking one with
        ``PeerDeadError(reason)``. Already-buffered messages stay
        deliverable (a matched message that arrived before the death is
        still a valid receive)."""
        with self.lock:
            if self.poison is not None:
                return
            self.poison = reason
            doomed = [w for dq in self.waiters.values() for w in dq
                      if not w.claimed]
            for w in doomed:
                w.claimed = True
            self.poisoned_waiters += len(doomed)
            self.waiters.clear()
            self.cond.notify_all()
        if self.tracer is not None:
            self.tracer.instant("mb.poison", "mb",
                                {"reason": reason, "waiters": len(doomed)})
        for w in doomed:
            _deliver_pool().submit(w.fut.set_exception, PeerDeadError(reason))

    def put(self, ctx: int, tag: int, src: int, payload: Any) -> None:
        key = (ctx, tag, src)
        deliver: _Waiter | None = None
        with self.lock:
            dq = self.waiters.get(key)
            while dq:
                w = dq.popleft()
                if not dq:
                    del self.waiters[key]
                if not w.claimed:
                    w.claimed = True
                    deliver = w
                    break
            if deliver is None:
                self.queues.setdefault(key, deque()).append(payload)
                self.depth += 1
                if self.depth > self.peak_depth:
                    self.peak_depth = self.depth
                self.cond.notify_all()
            else:
                self.total_matched += 1
        if deliver is not None:
            if self.tracer is not None and deliver.t0:
                # park -> wake latency of the satisfied async waiter
                self.tracer.complete("mb.wake", "mb", deliver.t0,
                                     args={"tag": tag, "src": src})
            if deliver.inline:      # engine waiter: callback just enqueues
                deliver.fut.set_result(payload)
            else:
                # complete on the shared delivery worker, not this (possibly
                # transport-reader) thread: user done-callbacks may block or
                # re-enter the mailbox
                _deliver_pool().submit(deliver.fut.set_result, payload)

    def get(self, ctx: int, tag: int, src: int, timeout: float) -> Any:
        key = (ctx, tag, src)
        # absolute deadline: unrelated arrivals wake the condition, and a
        # per-wait timeout would restart the clock on every one of them
        deadline = time.monotonic() + timeout
        t0 = 0
        with self.lock:
            while True:
                q = self.queues.get(key)
                if q:
                    payload = q.popleft()
                    if not q:
                        del self.queues[key]
                    self.depth -= 1
                    self.total_matched += 1
                    if t0:      # only when this receive actually blocked
                        self.tracer.complete("mb.wait", "mb", t0,
                                             args={"tag": tag, "src": src})
                    return payload
                if self.poison is not None:
                    raise PeerDeadError(self.poison)
                if not t0 and self.tracer is not None:
                    t0 = time.perf_counter_ns()
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self.cond.wait(timeout=remaining):
                    raise TimeoutError(
                        f"receive(src={src}, tag={tag}, ctx={ctx}) timed out")

    def get_async(self, ctx: int, tag: int, src: int,
                  timeout: float, inline: bool = False) -> Future:
        """Matched receive as a Future, without dedicating a thread to the
        wait: if the message is buffered the Future completes immediately;
        otherwise a ``_Waiter`` is registered and ``put`` completes it on
        arrival (the shared ``_Expiry`` thread enforces the deadline).
        ``inline`` marks the waiter safe for on-thread completion (see
        ``_Waiter``); only the progress engine passes True."""
        key = (ctx, tag, src)
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        with self.lock:
            q = self.queues.get(key)
            if q:
                payload = q.popleft()
                if not q:
                    del self.queues[key]
                self.depth -= 1
                self.total_matched += 1
            elif self.poison is not None:
                fut.set_exception(PeerDeadError(self.poison))
                return fut
            else:
                w = _Waiter(self, key, fut,
                            time.monotonic() + timeout, inline=inline,
                            t0=(time.perf_counter_ns()
                                if self.tracer is not None else 0))
                self.waiters.setdefault(key, deque()).append(w)
                _Expiry.instance().add(w)
                fut.mpignite_waiter = w     # cancel hook for Request
                return fut
        fut.set_result(payload)
        return fut


# ---------------------------------------------------------------------------
# Nonblocking requests + progress engine
# ---------------------------------------------------------------------------

class Request:
    """Handle for a nonblocking operation (MPI_Request). Returned by
    ``isend``/``irecv`` and the nonblocking collectives; settled by the
    transport (irecv: mailbox arrival) or the per-rank progress engine
    (collectives). ``wait`` ~ MPI_Wait, ``test`` ~ MPI_Test, ``cancel`` ~
    MPI_Cancel; module-level ``waitall``/``waitany`` complete sets."""
    __slots__ = ("_fut", "op", "_cancel_hook")

    def __init__(self, fut: Future, op: str = "",
                 cancel_hook: Callable[[], bool] | None = None):
        self._fut = fut
        self.op = op
        self._cancel_hook = cancel_hook

    @classmethod
    def completed(cls, value: Any = None, op: str = "") -> "Request":
        fut: Future = Future()
        fut.set_result(value)
        return cls(fut, op=op)

    @property
    def future(self) -> Future:
        return self._fut

    def done(self) -> bool:
        return self._fut.done()

    def wait(self, timeout: float | None = None) -> Any:
        """Block until the operation completes; return its value.
        Raises what the operation raised (``TimeoutError`` when the
        underlying receive deadline expired, ``PeerDeadError`` when the
        failure detector declared a participant dead) -- or
        ``TimeoutError`` if ``timeout`` elapses first (the request stays
        pending; wait again)."""
        try:
            return self._fut.result(timeout)
        except _futures.TimeoutError:
            if self._fut.done():
                # py3.11+: futures.TimeoutError aliases the builtin, so a
                # deadline-expired receive (terminal failure stored IN the
                # future) lands here too -- re-raise it, don't rewrite a
                # dead request as merely pending
                raise
            raise TimeoutError(
                f"request {self.op or 'op'} not complete within {timeout}s "
                "(still pending)") from None

    def test(self) -> tuple[bool, Any]:
        """(done, value) without blocking -- MPI_Test. ``value`` is None
        while pending; a failed operation raises here, like ``wait``."""
        if not self._fut.done():
            return False, None
        return True, self._fut.result(timeout=0)

    def exception(self) -> BaseException | None:
        return self._fut.exception() if self._fut.done() else None

    def cancel(self) -> bool:
        """Best-effort cancel of a still-pending operation. True iff this
        call retired the request; a completed/failed request returns
        False. A cancelled request's ``wait`` raises CancelledError."""
        if self._fut.done():
            return False
        if self._cancel_hook is not None:
            return bool(self._cancel_hook())
        return self._fut.cancel()


def waitall(requests: Sequence[Request],
            timeout: float | None = None) -> list:
    """Complete every request (MPI_Waitall); returns their values in
    order. The first failure propagates; ``timeout`` bounds the whole
    set, not each member."""
    deadline = None if timeout is None else time.monotonic() + timeout
    out = []
    for req in requests:
        left = None if deadline is None else deadline - time.monotonic()
        if left is not None and left <= 0:
            raise TimeoutError(f"waitall timed out with request "
                               f"{req.op or 'op'} still pending")
        out.append(req.wait(left))
    return out


def waitany(requests: Sequence[Request],
            timeout: float | None = None) -> tuple[int, Any]:
    """Block until at least one request completes (MPI_Waitany); returns
    ``(index, value)`` of the first completed one (failures propagate)."""
    if not requests:
        raise ValueError("waitany needs at least one request")
    done, _ = _futures.wait([r.future for r in requests], timeout=timeout,
                            return_when=_futures.FIRST_COMPLETED)
    if not done:
        raise TimeoutError(f"waitany: none of {len(requests)} requests "
                           f"completed within {timeout}s")
    for i, req in enumerate(requests):
        if req.future in done:
            return i, req.wait(0)
    raise AssertionError("unreachable")     # pragma: no cover


class _Schedule:
    """One in-flight nonblocking collective: a resumable generator plus
    the Future its Request exposes. The generator performs its sends
    inline and yields ``(ctx, tag, src_world)`` for every receive.
    ``span``/``tracer`` (set only when tracing) let the engine attribute
    sent bytes to the right collective while schedules interleave on its
    thread, and close the span at retirement."""
    __slots__ = ("gen", "fut", "mailbox", "timeout", "cancelled", "span",
                 "tracer")

    def __init__(self, gen: Generator, fut: Future, mailbox: Mailbox,
                 timeout: float, span=None, tracer=None):
        self.gen = gen
        self.fut = fut
        self.mailbox = mailbox
        self.timeout = timeout
        self.cancelled = False
        self.span = span
        self.tracer = tracer


class ProgressEngine:
    """Per-rank background engine that advances nonblocking collective
    schedules off the caller's thread (the MPI 'progress thread').

    A schedule runs to its next receive on the engine thread; the engine
    parks it as a mailbox waiter (``get_async``) and resumes it with the
    payload when ``Mailbox.put`` completes the waiter -- so any number of
    outstanding collectives cost one thread total, and the caller is free
    to compute while communication advances underneath (the overlap that
    blocking collectives make impossible).

    The thread starts lazily on the first ``submit`` and dies with
    ``close``; ``drain`` fails every outstanding request (job teardown:
    a leaked request must not poison the next pooled job)."""

    def __init__(self, name: str = "mpignite-progress"):
        self._name = name
        self._q: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._pending: set[_Schedule] = set()
        self._closed = False
        # always-on gauges (plain int adds; read by obs and tests)
        self.submitted = 0
        self.completed = 0
        self.wakeups = 0
        self.peak_pending = 0

    @property
    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)

    @property
    def thread_alive(self) -> bool:
        with self._lock:
            return self._thread is not None and self._thread.is_alive()

    def gauges(self) -> dict:
        with self._lock:
            return {"submitted": self.submitted, "completed": self.completed,
                    "wakeups": self.wakeups, "pending": len(self._pending),
                    "peak_pending": self.peak_pending,
                    "thread_alive": (self._thread is not None
                                     and self._thread.is_alive())}

    def submit(self, gen: Generator, mailbox: Mailbox, timeout: float,
               op: str = "", span=None, tracer=None) -> Request:
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        sched = _Schedule(gen, fut, mailbox, timeout, span=span,
                          tracer=tracer)
        with self._lock:
            if self._closed:
                raise RuntimeError(f"progress engine {self._name} is closed")
            self._pending.add(sched)
            self.submitted += 1
            if len(self._pending) > self.peak_pending:
                self.peak_pending = len(self._pending)
            if tracer is not None:
                tracer.counter("engine.pending", len(self._pending))
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._run, daemon=True,
                                                name=self._name)
                self._thread.start()
        self._q.put((sched, None, None))

        def cancel_hook() -> bool:
            sched.cancelled = True
            try:        # the engine may complete it concurrently: the
                fut.set_exception(_futures.CancelledError())    # Future
            except _futures.InvalidStateError:      # arbitrates the race
                return False
            return True
        return Request(fut, op=op, cancel_hook=cancel_hook)

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            self._advance(*item)

    def _advance(self, sched: _Schedule, value: Any,
                 exc: BaseException | None) -> None:
        self.wakeups += 1       # engine thread only; no lock needed
        if sched.fut.done():        # cancelled or drained while parked
            self._retire(sched, error="cancelled")
            sched.gen.close()
            return
        span = sched.span
        if span is not None:    # attribute this resume's sends to its coll
            prev_span = set_current_span(span)
        try:
            try:
                if exc is not None:
                    op = sched.gen.throw(exc)
                else:
                    op = sched.gen.send(value)
            except StopIteration as s:
                self._retire(sched)
                try:
                    sched.fut.set_result(s.value)
                except _futures.InvalidStateError:
                    pass        # drained/cancelled concurrently
            except BaseException as e:  # noqa: BLE001 -- user fn may raise
                self._retire(sched, error=repr(e))
                try:
                    sched.fut.set_exception(e)
                except _futures.InvalidStateError:
                    pass
            else:
                ctx, tag, src = op
                rfut = sched.mailbox.get_async(ctx, tag, src, sched.timeout,
                                               inline=True)

                def arrived(f: Future, sched=sched) -> None:
                    e = f.exception()
                    if e is not None:
                        self._q.put((sched, None, e))
                    else:
                        self._q.put((sched, f.result(), None))
                rfut.add_done_callback(arrived)
        finally:
            if span is not None:
                set_current_span(prev_span)

    def _retire(self, sched: _Schedule, error: str | None = None) -> None:
        with self._lock:
            self._pending.discard(sched)
            self.completed += 1
            pending = len(self._pending)
        if sched.tracer is not None:
            if sched.span is not None:
                sched.tracer.coll_end(sched.span, error=error)
                sched.span = None       # close exactly once
            sched.tracer.counter("engine.pending", pending)

    def drain(self, reason: str = "progress engine drained with the "
                                  "request still pending") -> int:
        """Fail every outstanding request; returns how many were failed.
        Parked schedules settle immediately (their mailbox waiter, when
        it fires or expires, finds the Future already done and the
        schedule is retired without resuming user code)."""
        with self._lock:
            doomed = list(self._pending)
            self._pending.clear()
            self.completed += len(doomed)
        n = 0
        for sched in doomed:
            sched.cancelled = True
            if sched.tracer is not None and sched.span is not None:
                sched.tracer.coll_end(sched.span, error="drained")
                sched.span = None
            try:
                sched.fut.set_exception(PeerDeadError(reason))
                n += 1
            except _futures.InvalidStateError:
                pass        # completed concurrently: nothing to fail
        return n

    def close(self, reason: str = "progress engine closed with the "
                                  "request still pending") -> None:
        self.drain(reason)
        with self._lock:
            self._closed = True
            thread = self._thread
        self._q.put(None)
        if thread is not None and thread is not threading.current_thread():
            thread.join(timeout=5.0)


class _CallCounter:
    """Mutable collective-call counter. ``with_backend`` clones *share* the
    parent's counter object: a parent and its clones are the same logical
    communicator used sequentially, so their collectives must draw from one
    key sequence (value-copied counters would let two steps issue identical
    keys, and staggered ranks could then cross-match messages)."""
    __slots__ = ("n",)

    def __init__(self, n: int = 0):
        self.n = n

    def next(self) -> int:
        self.n += 1
        return self.n


class MessageComm:
    """Base communicator: the full MPIgnite API composed from matched
    point-to-point messages (paper's ``SparkComm``). Method names keep the
    paper's spelling alongside pythonic aliases."""

    #: per-rank ``obs.Tracer`` when tracing is enabled. Class attribute so
    #: every instance reads None for free; transports overwrite it on the
    #: instance when a traced job runs. All instrumentation guards on it.
    _obs = None

    def __init__(self, group: tuple[int, ...], rank_in_group: int, ctx: int,
                 epoch: tuple = (), backend: str = "linear",
                 segment_bytes: int | None = None):
        self._group = group           # world ranks, ordered by comm rank
        self._rank = rank_in_group
        self._ctx = ctx
        # epoch disambiguates successive collectives on the same communicator
        # (each rank counts its own calls; SPMD => counts agree).
        self._calls = _CallCounter()
        self._epoch = epoch
        self._backend = normalize_backend(backend)
        # explicit per-communicator segment size; None defers to the env
        # knob at call time (per-job override beats env beats default)
        self._segment_bytes = segment_bytes

    # -- transport hooks (subclass responsibility) --------------------------
    def _put(self, world_dst: int, ctx: int, tag: int, src_world: int,
             payload: Any) -> None:
        raise NotImplementedError

    def _get(self, ctx: int, tag: int, src_world: int) -> Any:
        raise NotImplementedError

    def _clone(self, group: tuple[int, ...], rank_in_group: int, ctx: int,
               epoch: tuple) -> "MessageComm":
        """Construct a same-transport communicator (``split`` /
        ``with_backend``). Implementations must carry over this
        communicator's ``backend`` and ``segment_bytes``."""
        raise NotImplementedError

    def _async_mailbox(self) -> tuple["Mailbox", float] | None:
        """(this rank's mailbox, receive timeout) when the transport is
        mailbox-backed -- lets ``receive_async`` register a waiter instead
        of parking a thread. None => thread-per-call fallback."""
        return None

    # -- introspection ------------------------------------------------------
    def get_rank(self) -> int:
        return self._rank

    def get_size(self) -> int:
        return len(self._group)

    getRank = property(get_rank)   # paper spelling: world.getRank
    getSize = property(get_size)

    def buddy(self, offset: int = 1) -> int:
        """The comm rank holding this rank's buddy snapshot (next rank
        around the ring; ``groups.buddy_rank``). Elastic checkpointing
        streams each rank's state shard to its buddy so a single failure
        never loses a shard: the dead rank's copy survives one hop away."""
        return G.buddy_rank(self._rank, len(self._group), offset)

    @property
    def context_id(self) -> int:
        return self._ctx

    @property
    def backend(self) -> str:
        return self._backend

    def with_backend(self, backend: str) -> "MessageComm":
        """Same transport and group, different collective algorithm (the
        supervisor's degrade/resume switch). The clone shares the parent's
        call counter -- see ``_CallCounter``."""
        clone = self._clone(self._group, self._rank, self._ctx, self._epoch)
        clone._calls = self._calls          # shared object, not a copy
        clone._backend = normalize_backend(backend)
        return clone

    def with_segment_bytes(self, segment_bytes: int | None) -> "MessageComm":
        """Same transport, group, and backend, different segmented-ring
        tuning (None = this process's env default). The deterministic
        way for a closure to retune mid-job -- unlike mutating the env,
        the clone's value is explicit on every rank that runs the same
        closure, so schedules stay compatible across hosts."""
        clone = self._clone(self._group, self._rank, self._ctx, self._epoch)
        clone._calls = self._calls          # shared object, not a copy
        clone._segment_bytes = segment_bytes
        return clone

    # -- segmented-ring policy ----------------------------------------------
    def _segment_limit(self) -> int:
        """Effective segment size in bytes (explicit override wins, else
        the env knob). <= 0 means 'never auto-upgrade'."""
        if self._segment_bytes is not None:
            return self._segment_bytes
        return env_segment_bytes()

    def _segment_elems(self, dtype: np.dtype) -> int:
        limit = self._segment_limit()
        if limit <= 0:              # forced-segmented with auto disabled
            limit = DEFAULT_SEGMENT_BYTES
        return max(1, limit // max(1, dtype.itemsize))

    def _use_segments(self, data: Any, fold: Callable | None = None,
                      forced_only: bool = False) -> bool:
        """Whether this payload takes a segmented ring schedule.

        The explicit ``segmented`` backend always segments eligible
        arrays -- the user opted into the segmented contract (congruent
        payloads across ranks; elementwise folds). Plain ``ring``
        auto-upgrades only when that contract is *provable*, because
        upgrading must never change the semantics of a call the
        whole-buffer ring handled:

        - a reduction auto-upgrades only for ``np.ufunc`` folds
          (elementwise by construction -- applying them per segment and
          concatenating is exact). Arbitrary callables (top-k merges,
          sorted merges, lambdas) keep the whole-buffer ring.
        - ``forced_only`` ops (allgather: per-rank payloads need not be
          congruent in the message runtime) never auto-upgrade.
        - broadcast auto-upgrades for any array: the root's meta message
          carries the segmentation decision, so no cross-rank contract
          is assumed.

        Non-array pytrees, object arrays, and (under ``ring``) arrays
        below the segment threshold always fall back. The decision and
        the chunk/segment boundaries are pure functions of (backend,
        segment size, payload shape), so congruent payloads yield the
        same answer on every rank -- no negotiation."""
        if len(self._group) == 1 or self._backend == "linear":
            return False
        if not isinstance(data, np.ndarray) or data.dtype.hasobject:
            return False
        if self._backend == "segmented":
            return True
        if forced_only:
            return False
        if fold is not None and not isinstance(fold, np.ufunc):
            return False
        limit = self._segment_limit()
        return 0 < limit <= data.nbytes

    # -- point to point -----------------------------------------------------
    def send(self, dst: int, tag: int, data: Any) -> None:
        """Always non-blocking (paper: 'sending in MPIgnite is always
        nonblocking'); buffered at the receiver."""
        self._put(self._group[dst], self._ctx, tag,
                  self._group[self._rank], data)

    def receive(self, src: int, tag: int) -> Any:
        """Blocking receive ~ MPI_Recv."""
        return self._get(self._ctx, tag, self._group[src])

    def receive_async(self, src: int, tag: int) -> Future:
        """Non-blocking receive ~ MPI_Irecv; returns a Future (Scala Future
        in the paper; ``Await.result`` ~ ``future.result()`` ~ MPI_Wait).

        Mailbox-backed transports service the Future by waiter
        registration on the mailbox itself -- ``Mailbox.put`` completes it
        on arrival and one shared expiry thread enforces the deadline --
        so issuing many concurrent async receives costs zero extra
        threads. Transports without a mailbox fall back to a helper
        thread per call."""
        mb = self._async_mailbox()
        if mb is not None:
            mailbox, timeout = mb
            return mailbox.get_async(self._ctx, tag, self._group[src],
                                     timeout)
        fut: Future = Future()

        def run():
            try:
                fut.set_result(self.receive(src, tag))
            except BaseException as e:  # noqa: BLE001
                fut.set_exception(e)
        threading.Thread(target=run, daemon=True).start()
        return fut

    receiveAsync = receive_async  # paper spelling

    # -- collectives composed from p2p (phase-1 ``linear`` routes through
    #    the root; phase-2 ``ring`` circulates peer-to-peer) -----------------
    #
    # Each multi-step collective is written ONCE, as a resumable schedule
    # generator: sends execute inline, receives are ``yield``ed as
    # ``(ctx, tag, src_world)`` descriptors. The blocking API drives the
    # generator synchronously (``_run_sched``); the nonblocking API hands
    # the same generator to the per-rank ``ProgressEngine``, which parks
    # it as a mailbox waiter between steps -- one algorithm, two
    # completion disciplines, conformant by construction.

    def _next_key(self) -> tuple:
        return (*self._epoch, self._ctx, self._calls.next())

    def _send_coll(self, dst: int, tag: int, key: tuple, data: Any) -> None:
        if self._obs is not None:
            span = current_span()
            if span is not None:    # bytes belong to the advancing coll
                span.add(payload_nbytes(data))
        self._put(self._group[dst], stable_ctx(self._ctx, tag, key), tag,
                  self._group[self._rank], data)

    def _recv_coll(self, src: int, tag: int, key: tuple) -> Any:
        return self._get(stable_ctx(self._ctx, tag, key), tag,
                         self._group[src])

    def _recv_op(self, src: int, tag: int, key: tuple) -> tuple:
        """The receive descriptor a schedule yields: directly the
        ``(ctx, tag, src_world)`` match key of the awaited message."""
        return (stable_ctx(self._ctx, tag, key), tag, self._group[src])

    def _send_segments(self, dst: int, tag: int, key: tuple, phase: Any,
                       flat: np.ndarray, spans: list) -> None:
        """Send ``flat``'s segments to ``dst`` under per-segment subkeys
        ``(*key, phase, s)`` -- one half of the segmented wire protocol
        (``_recv_segments`` is the other; both ends derive identical
        ``spans`` from pure math, so the subkeys line up)."""
        if self._obs is None:
            for s, (a, b) in enumerate(spans):
                self._send_coll(dst, tag, (*key, phase, s), flat[a:b])
            return
        t0 = time.perf_counter_ns()
        for s, (a, b) in enumerate(spans):
            self._send_coll(dst, tag, (*key, phase, s), flat[a:b])
        if spans:
            self._seg_span("seg.send", t0,
                           {"phase": str(phase), "nseg": len(spans)})

    def _recv_segments(self, src: int, tag: int, key: tuple, phase: Any,
                       nseg: int):
        """Yield the ``nseg`` receive descriptors matching a
        ``_send_segments`` call; returns the received pieces in order
        (drive with ``yield from``)."""
        if self._obs is None:
            parts = []
            for s in range(nseg):
                parts.append((yield self._recv_op(src, tag,
                                                  (*key, phase, s))))
            return parts
        t0 = time.perf_counter_ns()
        parts = []
        for s in range(nseg):
            parts.append((yield self._recv_op(src, tag, (*key, phase, s))))
        self._seg_span("seg.recv", t0, {"phase": str(phase), "nseg": nseg})
        return parts

    def _fold_segments(self, src: int, tag: int, key: tuple, phase: Any,
                       cur: np.ndarray, spans: list, f: Callable,
                       step: int):
        """Receive one reduce-scatter hop's segments and fold them into
        ``cur``, double-buffered: the receive for segment s+1 is posted
        (yielded) *before* segment s is folded, so on the progress
        engine the fold of s overlaps the transfer of s+1 (and on the
        blocking driver s+1 is already draining into the mailbox while
        s folds). The per-segment arithmetic ``f(cur[a:b], piece)`` and
        the concatenation order are identical to the receive-all-then-
        fold-all form, so results stay bit-exact."""
        t0 = time.perf_counter_ns() if self._obs is not None else 0
        folded = []
        prev = yield self._recv_op(src, tag, (*key, phase, 0))
        for s in range(1, len(spans)):
            nxt = yield self._recv_op(src, tag, (*key, phase, s))
            a, b = spans[s - 1]
            folded.append(f(cur[a:b], prev))
            prev = nxt
        a, b = spans[-1]
        folded.append(f(cur[a:b], prev))
        if t0:
            self._seg_span("seg.fold", t0,
                           {"step": step, "nseg": len(spans)})
        return _cat(folded)

    def _send_meta_payload(self, dst: int, tag: int, key: tuple,
                           phase: Any, data: Any) -> None:
        """Send one directed payload under the broadcast-style meta
        protocol: a meta message announces whether the payload streams
        as segments (and in how many) or rides whole inside the meta --
        so the receiver, who cannot evaluate the sender's segmentation
        eligibility, needs no cross-rank contract. Segmentation here is
        pure transport: the receiver reassembles the full array before
        any fold touches it, so arbitrary (non-elementwise) folds stay
        legal."""
        if self._use_segments(data):
            flat = data.reshape(-1)
            spans = G.segment_spans(flat.size,
                                    self._segment_elems(data.dtype))
            self._send_coll(dst, tag, (*key, phase, "m"),
                            ("seg", len(spans), data.shape,
                             data.dtype.str))
            self._send_segments(dst, tag, key, (phase, "d"), flat, spans)
        else:
            self._send_coll(dst, tag, (*key, phase, "m"), ("whole", data))

    def _recv_meta_payload(self, src: int, tag: int, key: tuple,
                           phase: Any):
        """Receive one ``_send_meta_payload`` transfer (drive with
        ``yield from``); returns the reassembled payload."""
        meta = yield self._recv_op(src, tag, (*key, phase, "m"))
        if meta[0] != "seg":
            return meta[1]
        _, nseg, shape, dtype_str = meta
        parts = yield from self._recv_segments(src, tag, key,
                                               (phase, "d"), nseg)
        flat = (_cat(parts) if parts
                else np.empty(0, dtype=np.dtype(dtype_str)))
        return flat.reshape(shape)

    def _seg_span(self, name: str, t0: int, args: dict) -> None:
        """Record a segment-phase span on the owning collective's track
        (so Perfetto nests it under the collective). Caller has already
        checked ``self._obs is not None``. Also retags the owning span's
        backend as ``segmented``: the span must report the schedule that
        actually ran, not the ``ring`` the caller asked for -- the byte
        cross-check prices the two differently."""
        span = current_span()
        if span is not None:
            span.backend = "segmented"
        self._obs.complete(name, "seg", t0, args=args,
                           tid=span.tid if span is not None else None)

    def _run_sched(self, gen) -> Any:
        """Drive a schedule generator to completion with blocking
        receives on the caller's thread -- the blocking collectives."""
        try:
            op = next(gen)
            while True:
                op = gen.send(self._get(*op))
        except StopIteration as s:
            return s.value

    def _run_coll(self, gen, op: str, data: Any = None) -> Any:
        """Blocking-collective entry: ``_run_sched`` plus, when traced, a
        collective span installed as this thread's current span so the
        schedule's sends attribute their bytes to it."""
        obs = self._obs
        if obs is None:
            return self._run_sched(gen)
        span = obs.coll_begin(op, self._backend, len(self._group),
                              payload_nbytes(data))
        prev = set_current_span(span)
        try:
            result = self._run_sched(gen)
        except BaseException as e:
            obs.coll_end(span, error=repr(e))
            raise
        finally:
            set_current_span(prev)
        obs.coll_end(span)
        return result

    def _barrier_sched(self, tag: int, key: tuple):
        p = len(self._group)
        if self._rank == 0:
            for r in range(1, p):
                yield self._recv_op(r, tag, key)
            for r in range(1, p):
                self._send_coll(r, tag, key, None)
        else:
            self._send_coll(0, tag, key, None)
            yield self._recv_op(0, tag, key)

    def _broadcast_sched(self, root: int, data: Any, tag: int, key: tuple):
        p = len(self._group)
        if self._backend in ("ring", "segmented"):
            # pass-along ring from root: root -> root+1 -> ... (P-1 hops).
            # A meta message leads each hop so non-roots -- who hold no
            # data and therefore cannot evaluate segmentation eligibility
            # themselves -- learn whether (and in how many segments) the
            # payload streams; a non-segmented payload rides *inside* the
            # meta, keeping the small-payload path at one message per hop.
            # Segmented payloads pipeline: each rank forwards segment s
            # before receiving s+1, so the ring drains in
            # ~O(n + p*segment) instead of O(p*n).
            if p == 1:
                return data
            prev, succ = (self._rank - 1) % p, (self._rank + 1) % p
            forward = succ != root      # last ring rank closes the loop
            if self._rank == root:
                if self._use_segments(data):
                    flat = data.reshape(-1)
                    spans = G.segment_spans(
                        flat.size, self._segment_elems(data.dtype))
                    self._send_coll(succ, tag, (*key, "m"),
                                    ("seg", len(spans), data.shape,
                                     data.dtype.str))
                    self._send_segments(succ, tag, key, "b", flat, spans)
                else:
                    self._send_coll(succ, tag, (*key, "m"),
                                    ("whole", data))
                return data
            meta = yield self._recv_op(prev, tag, (*key, "m"))
            if forward:
                self._send_coll(succ, tag, (*key, "m"), meta)
            if meta[0] != "seg":
                return meta[1]
            _, nseg, shape, dtype_str = meta
            # interleaved receive-and-forward (the pipelining), so this
            # loop matches _send_segments' subkeys by hand instead of
            # driving _recv_segments
            parts = []
            for s in range(nseg):
                piece = yield self._recv_op(prev, tag, (*key, "b", s))
                if forward:
                    self._send_coll(succ, tag, (*key, "b", s), piece)
                parts.append(piece)
            flat = (_cat(parts) if parts
                    else np.empty(0, dtype=np.dtype(dtype_str)))
            return flat.reshape(shape)
        if self._rank == root:
            for r in range(p):
                if r != root:
                    self._send_coll(r, tag, key, data)
            return data
        return (yield self._recv_op(root, tag, key))

    def _allreduce_sched(self, data: Any, f: Callable, tag: int, key: tuple):
        p = len(self._group)
        if p == 1:
            return data
        if self._backend in ("ring", "segmented"):
            if self._use_segments(data, fold=f):
                return (yield from self._allreduce_segmented_sched(
                    data, f, tag, key))
            acc, v = data, data
            right = (self._rank + 1) % p
            left = (self._rank - 1) % p
            for _ in range(p - 1):
                self._send_coll(right, tag, key, v)
                v = yield self._recv_op(left, tag, key)
                acc = f(acc, v)
            return acc
        if self._rank == 0:
            acc = data
            for r in range(1, p):
                acc = f(acc, (yield self._recv_op(r, tag, key)))
            for r in range(1, p):
                self._send_coll(r, tag, key, acc)
            return acc
        self._send_coll(0, tag, key, data)
        return (yield self._recv_op(0, tag, key))

    def _allreduce_segmented_sched(self, data: np.ndarray, f: Callable,
                                   tag: int, key: tuple):
        """Bandwidth-optimal segmented ring allreduce: a reduce-scatter
        phase (each rank ends owning the full fold of one chunk) followed
        by an all-gather phase (the reduced chunks circulate back), both
        streaming each chunk as segments of at most
        ``MPIGNITE_SEGMENT_BYTES``. ~2S(p-1)/p bytes per rank instead of
        the whole-buffer ring's (p-1)S.

        ``f`` must be elementwise (applied per segment and concatenated)
        as well as associative/commutative -- the numpy-ufunc shape every
        ring reduction already assumes. Buffers are never mutated: folds
        rebind chunk slots, so a segment view sent earlier (delivered by
        reference in local mode) stays valid however late its receiver
        consumes it."""
        p = len(self._group)
        flat = data.reshape(-1)
        bounds = G.chunk_bounds(flat.size, p)
        seg = self._segment_elems(data.dtype)
        right, left = (self._rank + 1) % p, (self._rank - 1) % p
        chunks: list[np.ndarray] = [flat[bounds[i]:bounds[i + 1]]
                                    for i in range(p)]

        def spans_of(idx: int) -> list[tuple[int, int]]:
            return G.segment_spans(bounds[idx + 1] - bounds[idx], seg)

        # reduce-scatter: after step s, the fold of chunk c has advanced
        # one hop; after p-1 steps rank r owns the full fold of chunk
        # (r+1) % p. Sends complete inline (always-nonblocking), so each
        # step's segments pipeline through the ring; the fold is
        # double-buffered (_fold_segments), so folding segment s
        # overlaps the transfer of segment s+1.
        for step in range(p - 1):
            send_idx = (self._rank - step) % p
            recv_idx = (self._rank - step - 1) % p
            self._send_segments(right, tag, key, ("rs", step),
                                chunks[send_idx], spans_of(send_idx))
            spans = spans_of(recv_idx)
            if spans:
                chunks[recv_idx] = yield from self._fold_segments(
                    left, tag, key, ("rs", step), chunks[recv_idx],
                    spans, f, step)
        # all-gather: circulate the reduced chunks; receive chunk c this
        # step, forward it the next.
        for step in range(p - 1):
            send_idx = (self._rank - step + 1) % p
            recv_idx = (self._rank - step) % p
            self._send_segments(right, tag, key, ("ag", step),
                                chunks[send_idx], spans_of(send_idx))
            spans = spans_of(recv_idx)
            if spans:
                chunks[recv_idx] = _cat((yield from self._recv_segments(
                    left, tag, key, ("ag", step), len(spans))))
        out = np.concatenate([np.asarray(c).reshape(-1) for c in chunks])
        return out.reshape(data.shape)

    def _allgather_sched(self, data: Any, tag: int, key: tuple):
        p = len(self._group)
        if p == 1:
            return [data]
        out = [None] * p
        out[self._rank] = data
        if self._backend in ("ring", "segmented"):
            right = (self._rank + 1) % p
            left = (self._rank - 1) % p
            if self._use_segments(data, forced_only=True):
                # under the forced segmented backend every rank opted
                # into congruent payloads, so each derives identical
                # spans from its own block -- no negotiation needed
                flat = data.reshape(-1)
                spans = G.segment_spans(flat.size,
                                        self._segment_elems(data.dtype))
                for step in range(p - 1):
                    self._send_segments(right, tag, key, step, flat, spans)
                    if spans:
                        parts = yield from self._recv_segments(
                            left, tag, key, step, len(spans))
                        flat = _cat(parts)
                    out[(self._rank - step - 1) % p] = \
                        flat.reshape(data.shape)
                return out
            v = data
            for step in range(p - 1):
                self._send_coll(right, tag, key, v)
                v = yield self._recv_op(left, tag, key)
                out[(self._rank - step - 1) % p] = v
            return out
        if self._rank == 0:
            for r in range(1, p):
                out[r] = yield self._recv_op(r, tag, key)
            for r in range(1, p):
                self._send_coll(r, tag, key, out)
            return out
        self._send_coll(0, tag, key, data)
        return (yield self._recv_op(0, tag, key))

    def _reduce_sched(self, root: int, data: Any, f: Callable, tag: int,
                      key: tuple):
        """Fold everyone's data at ``root`` (None elsewhere), rank-ordered
        at the root so non-commutative ``f`` stays deterministic."""
        p = len(self._group)
        if self._rank == root:
            acc = data
            for r in range(p):
                if r != root:
                    acc = f(acc, (yield self._recv_op(r, tag, key)))
            return acc
        self._send_coll(root, tag, key, data)
        return None

    def _gather_sched(self, root: int, data: Any, tag: int, key: tuple):
        p = len(self._group)
        if self._rank == root:
            out = [None] * p
            out[root] = data
            for r in range(p):
                if r != root:
                    out[r] = yield self._recv_op(r, tag, key)
            return out
        self._send_coll(root, tag, key, data)
        return None

    def _scan_sched(self, data: Any, f: Callable, tag: int, key: tuple):
        """Inclusive prefix reduction as a linear chain through the
        ranks: rank r receives f(x_0, ..., x_{r-1}), folds its own."""
        if self._rank == 0:
            acc = data
        else:
            acc = f((yield self._recv_op(self._rank - 1, tag, key)), data)
        if self._rank + 1 < len(self._group):
            self._send_coll(self._rank + 1, tag, key, acc)
        return acc

    def _require_per_rank(self, seq: Sequence[Any] | None, op: str) -> None:
        """Eager misuse check for list-per-rank collectives: raise on the
        *caller's* thread, before any message moves or any schedule is
        handed to the engine -- not from inside a parked generator."""
        if seq is None or len(seq) != len(self._group):
            raise ValueError(
                f"{op} needs one item per rank "
                f"(got {None if seq is None else len(seq)}, "
                f"world size {len(self._group)})")

    def _alltoall_sched(self, chunks: Sequence[Any], tag: int, key: tuple):
        p = len(self._group)
        out = [None] * p
        out[self._rank] = chunks[self._rank]
        if p == 1:
            return out
        if self._backend in ("ring", "segmented"):
            # pairwise exchange: at offset k, send to (r+k) and receive
            # from (r-k) -- every directed pair exchanges exactly once,
            # staggered so no receiver sees p-1 simultaneous bursts.
            # Each directed chunk travels under the meta protocol, so
            # eligible arrays stream as bounded segments instead of one
            # whole-buffer message per destination.
            for k in range(1, p):
                dst = (self._rank + k) % p
                src = (self._rank - k) % p
                self._send_meta_payload(dst, tag, key, ("a2a", k),
                                        chunks[dst])
                out[src] = yield from self._recv_meta_payload(
                    src, tag, key, ("a2a", k))
            return out
        for r in range(p):
            if r != self._rank:
                self._send_coll(r, tag, key, chunks[r])
        for r in range(p):
            if r != self._rank:
                out[r] = yield self._recv_op(r, tag, key)
        return out

    def _scatter_sched(self, root: int, items: Sequence[Any] | None,
                       tag: int, key: tuple):
        """MPI_Scatter: ``items`` (one per rank, significant only at
        root) are fanned out; each rank returns its own item."""
        p = len(self._group)
        if self._rank == root:
            for r in range(p):
                if r != root:
                    self._send_coll(r, tag, key, items[r])
            return items[root]
        return (yield self._recv_op(root, tag, key))

    def _reducescatter_sched(self, chunks: Sequence[Any], f: Callable,
                             tag: int, key: tuple):
        """Each rank contributes P chunks; rank i ends with the f-fold
        of everyone's chunk i.

        linear: allgather then fold locally, rank-ordered --
        deterministic for non-commutative ``f`` but moves (p-1)S per
        rank. ring/segmented: a true ring reduce-scatter -- p-1 hops,
        each forwarding a partial fold one hop closer to its owner, so
        every rank moves ~S(p-1)/p bytes (the bandwidth-optimal half of
        the segmented allreduce). Each hop's partial travels under the
        meta protocol, so eligible arrays stream as segments; the fold
        is applied to the reassembled chunk, so ``f`` only needs the
        ring contract (associative + commutative), not elementwise-ness.
        """
        p = len(self._group)
        if p == 1:
            return chunks[0]
        if self._backend in ("ring", "segmented"):
            right, left = (self._rank + 1) % p, (self._rank - 1) % p
            acc = list(chunks)
            # at step s: forward the partial of chunk (r-s-1) to the
            # right, fold the incoming partial of chunk (r-s-2); after
            # p-1 steps rank r holds the full fold of chunk r.
            for step in range(p - 1):
                send_idx = (self._rank - step - 1) % p
                recv_idx = (self._rank - step - 2) % p
                self._send_meta_payload(right, tag, key, ("rs", step),
                                        acc[send_idx])
                piece = yield from self._recv_meta_payload(
                    left, tag, key, ("rs", step))
                acc[recv_idx] = f(acc[recv_idx], piece)
            return acc[self._rank]
        gathered = yield from self._allgather_sched(list(chunks), tag, key)
        mine = gathered[0][self._rank]
        for contrib in gathered[1:]:
            mine = f(mine, contrib[self._rank])
        return mine

    def barrier(self) -> None:
        """Message-realized barrier: gather a token at rank 0, then release
        everyone (works over any transport, unlike threading.Barrier)."""
        return self._run_coll(self._barrier_sched(-10, self._next_key()),
                              "barrier")

    def broadcast(self, root: int, data: Any = None) -> Any:
        """comm.broadcast[T](root, data): only the root's payload matters."""
        return self._run_coll(
            self._broadcast_sched(root, data, -2, self._next_key()),
            "broadcast", data)

    def allreduce(self, data: Any, f: Callable[[Any, Any], Any]) -> Any:
        """comm.allReduce[T](data, f) with an arbitrary reduction function
        (the paper's enhancement over MPI's fixed op set).

        linear (phase-1): gather to rank 0, fold in comm-rank order,
        broadcast back -- deterministic for non-commutative ``f``.
        ring (phase-2): circulate values around the ring, each rank folding
        as they arrive -- ``f`` must be associative and commutative (same
        restriction as the SPMD ring backend)."""
        return self._run_coll(
            self._allreduce_sched(data, f, -3, self._next_key()),
            "allreduce", data)

    def allgather(self, data: Any) -> list:
        return self._run_coll(
            self._allgather_sched(data, -4, self._next_key()),
            "allgather", data)

    # -- nonblocking API (MPI-3 shape): Request-returning twins -------------
    def _progress_engine(self) -> ProgressEngine:
        """The engine advancing this rank's nonblocking collectives.
        Transports with a shared per-rank home (LocalComm's world slot,
        ClusterComm's channel+job) override this; the base fallback keeps
        one lazily-created engine per communicator object."""
        eng = getattr(self, "_engine", None)
        if eng is None:
            eng = self._engine = ProgressEngine(
                name=f"mpignite-progress-r{self._rank}")
        return eng

    def _submit_sched(self, gen, op: str, data: Any = None) -> Request:
        mb = self._async_mailbox()
        if mb is None:
            raise NotImplementedError(
                "nonblocking collectives need a mailbox-backed transport "
                "(LocalComm / ClusterComm); this transport has none")
        mailbox, timeout = mb
        obs = self._obs
        span = None
        if obs is not None:
            # overlap=True gives the span its own synthetic track, so
            # concurrently outstanding collectives render side by side
            span = obs.coll_begin(op, self._backend, len(self._group),
                                  payload_nbytes(data), overlap=True)
        return self._progress_engine().submit(gen, mailbox, timeout, op=op,
                                              span=span, tracer=obs)

    def isend(self, dst: int, tag: int, data: Any) -> Request:
        """MPI_Isend. MPIgnite sends are always nonblocking and buffered
        at the receiver, so the request is born complete -- it exists for
        API symmetry (waitall over mixed send/recv requests)."""
        self.send(dst, tag, data)
        return Request.completed(None, op="isend")

    def ibsend(self, dst: int, tag: int, data: Any) -> Request:
        """MPI_Ibsend: a buffered send performed *off* the caller's
        thread, on the progress engine -- serialization and the socket
        write included. ``isend`` completes the send inline before
        returning, which puts a large payload's full streaming cost on
        the critical path; ``ibsend`` is what lets it overlap with
        compute (buddy snapshots stream this way). Ordering: engine
        sends are FIFO among themselves but NOT ordered against
        caller-thread sends to the same (dst, tag) -- use distinct tags
        when mixing. Transports without an engine fall back to the
        inline send."""
        if self._async_mailbox() is None:
            return self.isend(dst, tag, data)

        def sched():
            self.send(dst, tag, data)
            return None
            yield   # pragma: no cover -- makes this a (sendless) schedule

        return self._submit_sched(sched(), op="ibsend", data=data)

    def irecv(self, src: int, tag: int) -> Request:
        """MPI_Irecv: a Request completed by message arrival (waiter
        registration on this rank's mailbox -- zero threads parked),
        failed by deadline expiry or peer death. Supports ``cancel``."""
        mb = self._async_mailbox()
        if mb is None:                      # thread-per-call fallback
            return Request(self.receive_async(src, tag), op="irecv")
        mailbox, timeout = mb
        fut = mailbox.get_async(self._ctx, tag, self._group[src], timeout)
        waiter = getattr(fut, "mpignite_waiter", None)
        hook = waiter.cancel if waiter is not None else None
        return Request(fut, op="irecv", cancel_hook=hook)

    def ibarrier(self) -> Request:
        """Nonblocking barrier: completes when every rank has entered."""
        return self._submit_sched(self._barrier_sched(-10, self._next_key()),
                                  op="ibarrier")

    def ibcast(self, root: int, data: Any = None) -> Request:
        """Nonblocking broadcast; ``wait`` returns the root's payload."""
        return self._submit_sched(
            self._broadcast_sched(root, data, -2, self._next_key()),
            op="ibcast", data=data)

    ibroadcast = ibcast

    def iallreduce(self, data: Any, f: Callable[[Any, Any], Any]) -> Request:
        """Nonblocking allreduce: the ring/linear schedule advances on the
        progress engine while the caller computes -- the MPI-3 overlap
        primitive (``wait`` returns the reduced value)."""
        return self._submit_sched(
            self._allreduce_sched(data, f, -3, self._next_key()),
            op="iallreduce", data=data)

    def iallgather(self, data: Any) -> Request:
        """Nonblocking allgather; ``wait`` returns the rank-ordered list."""
        return self._submit_sched(
            self._allgather_sched(data, -4, self._next_key()),
            op="iallgather", data=data)

    def ireduce(self, root: int, data: Any,
                f: Callable[[Any, Any], Any]) -> Request:
        """Nonblocking reduce; ``wait`` returns the fold at ``root`` and
        None elsewhere."""
        return self._submit_sched(
            self._reduce_sched(root, data, f, -7, self._next_key()),
            op="ireduce", data=data)

    def igather(self, root: int, data: Any) -> Request:
        """Nonblocking gather; ``wait`` returns the rank-ordered list at
        ``root`` and None elsewhere."""
        return self._submit_sched(
            self._gather_sched(root, data, -8, self._next_key()),
            op="igather", data=data)

    def iscatter(self, root: int, items: Sequence[Any] | None = None
                 ) -> Request:
        """Nonblocking scatter; ``wait`` returns this rank's item."""
        if self._rank == root:
            self._require_per_rank(items, "iscatter")
        return self._submit_sched(
            self._scatter_sched(root, items, -11, self._next_key()),
            op="iscatter", data=items)

    def iscan(self, data: Any, f: Callable[[Any, Any], Any]) -> Request:
        """Nonblocking inclusive prefix reduction."""
        return self._submit_sched(
            self._scan_sched(data, f, -9, self._next_key()),
            op="iscan", data=data)

    def ialltoall(self, chunks: Sequence[Any]) -> Request:
        """Nonblocking alltoall; ``wait`` returns the source-ordered
        list of received chunks."""
        self._require_per_rank(chunks, "ialltoall")
        return self._submit_sched(
            self._alltoall_sched(chunks, -5, self._next_key()),
            op="ialltoall", data=chunks)

    def ireducescatter(self, chunks: Sequence[Any], f: Callable) -> Request:
        """Nonblocking reducescatter; ``wait`` returns this rank's fold."""
        self._require_per_rank(chunks, "ireducescatter")
        return self._submit_sched(
            self._reducescatter_sched(chunks, f, -12, self._next_key()),
            op="ireducescatter", data=chunks)

    def reducescatter(self, chunks: Sequence[Any], f: Callable) -> Any:
        """Each rank contributes a list of P chunks; rank i gets the f-fold
        of everyone's chunk i."""
        self._require_per_rank(chunks, "reducescatter")
        return self._run_coll(
            self._reducescatter_sched(chunks, f, -12, self._next_key()),
            "reducescatter", chunks)

    def reduce(self, root: int, data: Any, f: Callable[[Any, Any], Any]) -> Any:
        """MPI_Reduce: fold everyone's data at ``root`` (None elsewhere).
        One of the 'more methods' the paper's section 6 plans."""
        return self._run_coll(
            self._reduce_sched(root, data, f, -7, self._next_key()),
            "reduce", data)

    def gather(self, root: int, data: Any) -> list | None:
        """MPI_Gather: rank-ordered list at ``root`` (None elsewhere)."""
        return self._run_coll(
            self._gather_sched(root, data, -8, self._next_key()),
            "gather", data)

    def scatter(self, root: int, items: Sequence[Any] | None = None) -> Any:
        """MPI_Scatter: the root's ``items`` list (one per rank) is fanned
        out; each rank returns its own item (non-roots pass None). A bad
        ``items`` raises at the root immediately; already-parked peers
        unblock at their receive deadline (rooted-collective misuse is
        asymmetric by nature)."""
        if self._rank == root:
            self._require_per_rank(items, "scatter")
        return self._run_coll(
            self._scatter_sched(root, items, -11, self._next_key()),
            "scatter", items)

    def scan(self, data: Any, f: Callable[[Any, Any], Any]) -> Any:
        """MPI_Scan: inclusive prefix reduction -- rank r receives
        f(x_0, ..., x_r). Linear chain through the ranks."""
        return self._run_coll(
            self._scan_sched(data, f, -9, self._next_key()),
            "scan", data)

    def alltoall(self, chunks: Sequence[Any]) -> list:
        self._require_per_rank(chunks, "alltoall")
        return self._run_coll(
            self._alltoall_sched(chunks, -5, self._next_key()),
            "alltoall", chunks)

    # -- split (paper section 3.1: ranks send (global rank, key, color) to the
    #    lowest participating rank; it groups by color, sorts by key, and
    #    broadcasts the new rank mapping) ------------------------------------
    def split(self, color: int, key: int) -> "MessageComm":
        tag = -6
        ckey = self._next_key()
        root = 0
        if self._rank == root:
            triples = [(self._rank, key, color)]
            for r in range(1, len(self._group)):
                triples.append(self._recv_coll(r, tag, ckey))
            colors = {}
            for r, k, c in triples:
                colors.setdefault(c, []).append((k, r))
            mapping = {}
            for c, members in colors.items():
                members.sort()
                mapping[c] = tuple(r for _, r in members)
            for r in range(1, len(self._group)):
                self._send_coll(r, tag, ckey, mapping)
        else:
            self._send_coll(root, tag, ckey, (self._rank, key, color))
            mapping = self._recv_coll(root, tag, ckey)
        my_group_parent_ranks = mapping[color]
        new_group = tuple(self._group[r] for r in my_group_parent_ranks)
        new_rank = my_group_parent_ranks.index(self._rank)
        new_ctx = G.context_id((tuple(sorted(new_group)),), self._ctx) ^ \
            stable_ctx(self._ctx, tag, ("split", *ckey, color)) & 0xFFFFFFFF
        return self._clone(new_group, new_rank, new_ctx,
                           (*self._epoch, "s", self._calls.n, color))

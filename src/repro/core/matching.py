"""Transport-agnostic message matching and p2p-composed collectives.

The paper's runtime semantics -- receiver-side buffering with dynamic
``(ctx, tag, src)`` matching, always-nonblocking sends, futures for
``receiveAsync``, and collectives composed from point-to-point messages
(phase-1 master relay through a root, phase-2 ring) -- do not depend on
*how* a message travels. This module holds everything above the
transport: the matched ``Mailbox`` and the ``MessageComm`` base class.

Two transports plug in underneath:

- ``local.LocalComm``      : in-process delivery between worker threads
  (the paper's local deployment; the semantic oracle).
- ``cluster.ClusterComm``  : length-prefixed TCP frames on direct
  executor-to-executor channels (or relayed through the driver) between
  genuinely separate executor processes (the paper's cluster
  deployment).

A subclass provides three hooks: ``_put`` (deliver a payload to a world
rank's mailbox), ``_get`` (matched receive from this rank's own mailbox)
and ``_clone`` (construct a same-transport communicator for ``split``).
"""
from __future__ import annotations

import functools
import hashlib
import heapq
import itertools
import os
import queue
import threading
import time
from collections import deque
from concurrent import futures as _futures
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Generator, Sequence

from . import groups as G


class PeerDeadError(ConnectionError):
    """A peer rank died while an operation depended on it: the failure
    detector declared the world broken, so every pending receive (blocking
    or request-backed) is failed instead of waiting out its timeout."""

#: algorithms available to message-composed collectives. ``linear`` is the
#: paper's phase-1 (every byte relays through a root/master); ``ring`` is
#: the phase-2 peer-to-peer mode. ``native`` is accepted as an alias of
#: ``linear`` so closures written for the SPMD backend run unchanged --
#: linear is the runtime default because its root-ordered fold keeps
#: ``allreduce`` deterministic for arbitrary (non-commutative) functions,
#: the property the thread oracle documents.
MESSAGE_BACKENDS = ("linear", "ring")


def normalize_backend(backend: str) -> str:
    backend = "linear" if backend == "native" else backend
    if backend not in MESSAGE_BACKENDS:
        raise ValueError(f"unknown message backend {backend!r}; "
                         f"expected one of {MESSAGE_BACKENDS} or 'native'")
    return backend


@functools.lru_cache(maxsize=1024)
def stable_ctx(ctx: int, tag: int, key: tuple) -> int:
    """Deterministic collective-context id, identical across processes
    (``hash()`` is salted per interpreter, so it cannot go on the wire).
    Cached: one collective calls this with identical arguments for every
    constituent message (2(p-1) times at a linear allreduce root)."""
    h = hashlib.blake2b(repr((ctx, tag, key)).encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "big")


_DELIVER: tuple[int, ThreadPoolExecutor] | None = None
_DELIVER_LOCK = threading.Lock()


def _deliver_pool() -> ThreadPoolExecutor:
    """One shared worker that completes async-receive Futures, so user
    done-callbacks never run on (and never stall) a transport reader
    thread. Keyed by pid: a forked child would otherwise inherit an
    executor whose worker thread does not exist."""
    global _DELIVER
    with _DELIVER_LOCK:
        if _DELIVER is None or _DELIVER[0] != os.getpid():
            _DELIVER = (os.getpid(), ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="mailbox-deliver"))
        return _DELIVER[1]


class _Waiter:
    """One pending ``receive_async``: a Future registered on a mailbox key.
    Claiming (under the mailbox lock) decides exactly one outcome --
    delivery by ``Mailbox.put`` or expiry by the shared ``_Expiry``
    thread -- so the two can never both complete the Future.

    ``inline=True`` (progress-engine waiters) completes the Future on the
    delivering thread instead of hopping through the shared deliver pool:
    the engine's done-callback only enqueues a token, so it is safe on a
    transport reader, and skipping the hop halves the per-step wakeup
    latency a nonblocking collective pays under CPU contention."""
    __slots__ = ("mailbox", "key", "fut", "deadline", "claimed", "inline")

    def __init__(self, mailbox: "Mailbox", key: tuple, fut: Future,
                 deadline: float, inline: bool = False):
        self.mailbox = mailbox
        self.key = key
        self.fut = fut
        self.deadline = deadline
        self.claimed = False
        self.inline = inline

    def expire(self) -> None:
        with self.mailbox.lock:
            if self.claimed:
                return
            self.claimed = True
            dq = self.mailbox.waiters.get(self.key)
            if dq is not None:
                try:
                    dq.remove(self)
                except ValueError:
                    pass
                if not dq:
                    del self.mailbox.waiters[self.key]
        ctx, tag, src = self.key
        _deliver_pool().submit(self.fut.set_exception, TimeoutError(
            f"receive(src={src}, tag={tag}, ctx={ctx}) timed out"))

    def cancel(self) -> bool:
        """Claim the waiter for cancellation (MPI_Cancel on a receive):
        the message, if it ever arrives, stays buffered for someone else."""
        with self.mailbox.lock:
            if self.claimed:
                return False
            self.claimed = True
            dq = self.mailbox.waiters.get(self.key)
            if dq is not None:
                try:
                    dq.remove(self)
                except ValueError:
                    pass
                if not dq:
                    del self.mailbox.waiters[self.key]
        _deliver_pool().submit(self.fut.set_exception,
                               _futures.CancelledError())
        return True


class _Expiry(threading.Thread):
    """Single shared timer servicing every async waiter's deadline -- the
    'small shared waiter pool' that replaces thread-per-``receive_async``.
    One daemon thread per process, started on first use."""

    _instance: "_Expiry | None" = None
    _instance_lock = threading.Lock()

    def __init__(self):
        super().__init__(daemon=True, name="mailbox-expiry")
        self.cond = threading.Condition()
        self.heap: list[tuple[float, int, _Waiter]] = []
        self.seq = itertools.count()

    @classmethod
    def instance(cls) -> "_Expiry":
        with cls._instance_lock:
            if cls._instance is None or not cls._instance.is_alive():
                cls._instance = cls()
                cls._instance.start()
            return cls._instance

    def add(self, waiter: _Waiter) -> None:
        with self.cond:
            heapq.heappush(self.heap, (waiter.deadline, next(self.seq),
                                       waiter))
            self.cond.notify()

    def run(self) -> None:
        while True:
            with self.cond:
                while not self.heap:
                    self.cond.wait()
                deadline, _, waiter = self.heap[0]
                now = time.monotonic()
                if waiter.claimed:
                    heapq.heappop(self.heap)
                    continue
                if now < deadline:
                    self.cond.wait(deadline - now)
                    continue
                heapq.heappop(self.heap)
            waiter.expire()     # outside our cond; takes the mailbox lock


@dataclass
class Mailbox:
    """Receiver-side buffering: unmatched messages wait here (paper: 'we
    buffer messages on the receiving worker'). Messages are indexed by
    their full ``(ctx, tag, src)`` match key -- put/get are O(1) dict
    operations, not a scan of every buffered message -- with a deque per
    key preserving arrival order for same-key messages."""
    lock: threading.Lock = field(default_factory=threading.Lock)
    cond: threading.Condition = None  # type: ignore[assignment]
    queues: dict[tuple[int, int, int], deque] = field(default_factory=dict)
    waiters: dict[tuple[int, int, int], deque] = field(default_factory=dict)
    #: non-None once the failure detector declared a peer dead: every
    #: receive that would block raises PeerDeadError(poison) instead.
    poison: str | None = None

    def __post_init__(self):
        self.cond = threading.Condition(self.lock)

    def poison_all(self, reason: str) -> None:
        """Fail every pending receive and every future blocking one with
        ``PeerDeadError(reason)``. Already-buffered messages stay
        deliverable (a matched message that arrived before the death is
        still a valid receive)."""
        with self.lock:
            if self.poison is not None:
                return
            self.poison = reason
            doomed = [w for dq in self.waiters.values() for w in dq
                      if not w.claimed]
            for w in doomed:
                w.claimed = True
            self.waiters.clear()
            self.cond.notify_all()
        for w in doomed:
            _deliver_pool().submit(w.fut.set_exception, PeerDeadError(reason))

    def put(self, ctx: int, tag: int, src: int, payload: Any) -> None:
        key = (ctx, tag, src)
        deliver: _Waiter | None = None
        with self.lock:
            dq = self.waiters.get(key)
            while dq:
                w = dq.popleft()
                if not dq:
                    del self.waiters[key]
                if not w.claimed:
                    w.claimed = True
                    deliver = w
                    break
            if deliver is None:
                self.queues.setdefault(key, deque()).append(payload)
                self.cond.notify_all()
        if deliver is not None:
            if deliver.inline:      # engine waiter: callback just enqueues
                deliver.fut.set_result(payload)
            else:
                # complete on the shared delivery worker, not this (possibly
                # transport-reader) thread: user done-callbacks may block or
                # re-enter the mailbox
                _deliver_pool().submit(deliver.fut.set_result, payload)

    def get(self, ctx: int, tag: int, src: int, timeout: float) -> Any:
        key = (ctx, tag, src)
        # absolute deadline: unrelated arrivals wake the condition, and a
        # per-wait timeout would restart the clock on every one of them
        deadline = time.monotonic() + timeout
        with self.lock:
            while True:
                q = self.queues.get(key)
                if q:
                    payload = q.popleft()
                    if not q:
                        del self.queues[key]
                    return payload
                if self.poison is not None:
                    raise PeerDeadError(self.poison)
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self.cond.wait(timeout=remaining):
                    raise TimeoutError(
                        f"receive(src={src}, tag={tag}, ctx={ctx}) timed out")

    def get_async(self, ctx: int, tag: int, src: int,
                  timeout: float, inline: bool = False) -> Future:
        """Matched receive as a Future, without dedicating a thread to the
        wait: if the message is buffered the Future completes immediately;
        otherwise a ``_Waiter`` is registered and ``put`` completes it on
        arrival (the shared ``_Expiry`` thread enforces the deadline).
        ``inline`` marks the waiter safe for on-thread completion (see
        ``_Waiter``); only the progress engine passes True."""
        key = (ctx, tag, src)
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        with self.lock:
            q = self.queues.get(key)
            if q:
                payload = q.popleft()
                if not q:
                    del self.queues[key]
            elif self.poison is not None:
                fut.set_exception(PeerDeadError(self.poison))
                return fut
            else:
                w = _Waiter(self, key, fut,
                            time.monotonic() + timeout, inline=inline)
                self.waiters.setdefault(key, deque()).append(w)
                _Expiry.instance().add(w)
                fut.mpignite_waiter = w     # cancel hook for Request
                return fut
        fut.set_result(payload)
        return fut


# ---------------------------------------------------------------------------
# Nonblocking requests + progress engine
# ---------------------------------------------------------------------------

class Request:
    """Handle for a nonblocking operation (MPI_Request). Returned by
    ``isend``/``irecv`` and the nonblocking collectives; settled by the
    transport (irecv: mailbox arrival) or the per-rank progress engine
    (collectives). ``wait`` ~ MPI_Wait, ``test`` ~ MPI_Test, ``cancel`` ~
    MPI_Cancel; module-level ``waitall``/``waitany`` complete sets."""
    __slots__ = ("_fut", "op", "_cancel_hook")

    def __init__(self, fut: Future, op: str = "",
                 cancel_hook: Callable[[], bool] | None = None):
        self._fut = fut
        self.op = op
        self._cancel_hook = cancel_hook

    @classmethod
    def completed(cls, value: Any = None, op: str = "") -> "Request":
        fut: Future = Future()
        fut.set_result(value)
        return cls(fut, op=op)

    @property
    def future(self) -> Future:
        return self._fut

    def done(self) -> bool:
        return self._fut.done()

    def wait(self, timeout: float | None = None) -> Any:
        """Block until the operation completes; return its value.
        Raises what the operation raised (``TimeoutError`` when the
        underlying receive deadline expired, ``PeerDeadError`` when the
        failure detector declared a participant dead) -- or
        ``TimeoutError`` if ``timeout`` elapses first (the request stays
        pending; wait again)."""
        try:
            return self._fut.result(timeout)
        except _futures.TimeoutError:
            if self._fut.done():
                # py3.11+: futures.TimeoutError aliases the builtin, so a
                # deadline-expired receive (terminal failure stored IN the
                # future) lands here too -- re-raise it, don't rewrite a
                # dead request as merely pending
                raise
            raise TimeoutError(
                f"request {self.op or 'op'} not complete within {timeout}s "
                "(still pending)") from None

    def test(self) -> tuple[bool, Any]:
        """(done, value) without blocking -- MPI_Test. ``value`` is None
        while pending; a failed operation raises here, like ``wait``."""
        if not self._fut.done():
            return False, None
        return True, self._fut.result(timeout=0)

    def exception(self) -> BaseException | None:
        return self._fut.exception() if self._fut.done() else None

    def cancel(self) -> bool:
        """Best-effort cancel of a still-pending operation. True iff this
        call retired the request; a completed/failed request returns
        False. A cancelled request's ``wait`` raises CancelledError."""
        if self._fut.done():
            return False
        if self._cancel_hook is not None:
            return bool(self._cancel_hook())
        return self._fut.cancel()


def waitall(requests: Sequence[Request],
            timeout: float | None = None) -> list:
    """Complete every request (MPI_Waitall); returns their values in
    order. The first failure propagates; ``timeout`` bounds the whole
    set, not each member."""
    deadline = None if timeout is None else time.monotonic() + timeout
    out = []
    for req in requests:
        left = None if deadline is None else deadline - time.monotonic()
        if left is not None and left <= 0:
            raise TimeoutError(f"waitall timed out with request "
                               f"{req.op or 'op'} still pending")
        out.append(req.wait(left))
    return out


def waitany(requests: Sequence[Request],
            timeout: float | None = None) -> tuple[int, Any]:
    """Block until at least one request completes (MPI_Waitany); returns
    ``(index, value)`` of the first completed one (failures propagate)."""
    if not requests:
        raise ValueError("waitany needs at least one request")
    done, _ = _futures.wait([r.future for r in requests], timeout=timeout,
                            return_when=_futures.FIRST_COMPLETED)
    if not done:
        raise TimeoutError(f"waitany: none of {len(requests)} requests "
                           f"completed within {timeout}s")
    for i, req in enumerate(requests):
        if req.future in done:
            return i, req.wait(0)
    raise AssertionError("unreachable")     # pragma: no cover


class _Schedule:
    """One in-flight nonblocking collective: a resumable generator plus
    the Future its Request exposes. The generator performs its sends
    inline and yields ``(ctx, tag, src_world)`` for every receive."""
    __slots__ = ("gen", "fut", "mailbox", "timeout", "cancelled")

    def __init__(self, gen: Generator, fut: Future, mailbox: Mailbox,
                 timeout: float):
        self.gen = gen
        self.fut = fut
        self.mailbox = mailbox
        self.timeout = timeout
        self.cancelled = False


class ProgressEngine:
    """Per-rank background engine that advances nonblocking collective
    schedules off the caller's thread (the MPI 'progress thread').

    A schedule runs to its next receive on the engine thread; the engine
    parks it as a mailbox waiter (``get_async``) and resumes it with the
    payload when ``Mailbox.put`` completes the waiter -- so any number of
    outstanding collectives cost one thread total, and the caller is free
    to compute while communication advances underneath (the overlap that
    blocking collectives make impossible).

    The thread starts lazily on the first ``submit`` and dies with
    ``close``; ``drain`` fails every outstanding request (job teardown:
    a leaked request must not poison the next pooled job)."""

    def __init__(self, name: str = "mpignite-progress"):
        self._name = name
        self._q: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._pending: set[_Schedule] = set()
        self._closed = False

    @property
    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)

    def submit(self, gen: Generator, mailbox: Mailbox, timeout: float,
               op: str = "") -> Request:
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        sched = _Schedule(gen, fut, mailbox, timeout)
        with self._lock:
            if self._closed:
                raise RuntimeError(f"progress engine {self._name} is closed")
            self._pending.add(sched)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._run, daemon=True,
                                                name=self._name)
                self._thread.start()
        self._q.put((sched, None, None))

        def cancel_hook() -> bool:
            sched.cancelled = True
            try:        # the engine may complete it concurrently: the
                fut.set_exception(_futures.CancelledError())    # Future
            except _futures.InvalidStateError:      # arbitrates the race
                return False
            return True
        return Request(fut, op=op, cancel_hook=cancel_hook)

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            self._advance(*item)

    def _advance(self, sched: _Schedule, value: Any,
                 exc: BaseException | None) -> None:
        if sched.fut.done():        # cancelled or drained while parked
            self._retire(sched)
            sched.gen.close()
            return
        try:
            if exc is not None:
                op = sched.gen.throw(exc)
            else:
                op = sched.gen.send(value)
        except StopIteration as s:
            self._retire(sched)
            try:
                sched.fut.set_result(s.value)
            except _futures.InvalidStateError:
                pass        # drained/cancelled concurrently
        except BaseException as e:  # noqa: BLE001 -- user reduce fn may raise
            self._retire(sched)
            try:
                sched.fut.set_exception(e)
            except _futures.InvalidStateError:
                pass
        else:
            ctx, tag, src = op
            rfut = sched.mailbox.get_async(ctx, tag, src, sched.timeout,
                                           inline=True)

            def arrived(f: Future, sched=sched) -> None:
                e = f.exception()
                if e is not None:
                    self._q.put((sched, None, e))
                else:
                    self._q.put((sched, f.result(), None))
            rfut.add_done_callback(arrived)

    def _retire(self, sched: _Schedule) -> None:
        with self._lock:
            self._pending.discard(sched)

    def drain(self, reason: str = "progress engine drained with the "
                                  "request still pending") -> int:
        """Fail every outstanding request; returns how many were failed.
        Parked schedules settle immediately (their mailbox waiter, when
        it fires or expires, finds the Future already done and the
        schedule is retired without resuming user code)."""
        with self._lock:
            doomed = list(self._pending)
            self._pending.clear()
        n = 0
        for sched in doomed:
            sched.cancelled = True
            try:
                sched.fut.set_exception(PeerDeadError(reason))
                n += 1
            except _futures.InvalidStateError:
                pass        # completed concurrently: nothing to fail
        return n

    def close(self, reason: str = "progress engine closed with the "
                                  "request still pending") -> None:
        self.drain(reason)
        with self._lock:
            self._closed = True
            thread = self._thread
        self._q.put(None)
        if thread is not None and thread is not threading.current_thread():
            thread.join(timeout=5.0)


class _CallCounter:
    """Mutable collective-call counter. ``with_backend`` clones *share* the
    parent's counter object: a parent and its clones are the same logical
    communicator used sequentially, so their collectives must draw from one
    key sequence (value-copied counters would let two steps issue identical
    keys, and staggered ranks could then cross-match messages)."""
    __slots__ = ("n",)

    def __init__(self, n: int = 0):
        self.n = n

    def next(self) -> int:
        self.n += 1
        return self.n


class MessageComm:
    """Base communicator: the full MPIgnite API composed from matched
    point-to-point messages (paper's ``SparkComm``). Method names keep the
    paper's spelling alongside pythonic aliases."""

    def __init__(self, group: tuple[int, ...], rank_in_group: int, ctx: int,
                 epoch: tuple = (), backend: str = "linear"):
        self._group = group           # world ranks, ordered by comm rank
        self._rank = rank_in_group
        self._ctx = ctx
        # epoch disambiguates successive collectives on the same communicator
        # (each rank counts its own calls; SPMD => counts agree).
        self._calls = _CallCounter()
        self._epoch = epoch
        self._backend = normalize_backend(backend)

    # -- transport hooks (subclass responsibility) --------------------------
    def _put(self, world_dst: int, ctx: int, tag: int, src_world: int,
             payload: Any) -> None:
        raise NotImplementedError

    def _get(self, ctx: int, tag: int, src_world: int) -> Any:
        raise NotImplementedError

    def _clone(self, group: tuple[int, ...], rank_in_group: int, ctx: int,
               epoch: tuple) -> "MessageComm":
        raise NotImplementedError

    def _async_mailbox(self) -> tuple["Mailbox", float] | None:
        """(this rank's mailbox, receive timeout) when the transport is
        mailbox-backed -- lets ``receive_async`` register a waiter instead
        of parking a thread. None => thread-per-call fallback."""
        return None

    # -- introspection ------------------------------------------------------
    def get_rank(self) -> int:
        return self._rank

    def get_size(self) -> int:
        return len(self._group)

    getRank = property(get_rank)   # paper spelling: world.getRank
    getSize = property(get_size)

    @property
    def context_id(self) -> int:
        return self._ctx

    @property
    def backend(self) -> str:
        return self._backend

    def with_backend(self, backend: str) -> "MessageComm":
        """Same transport and group, different collective algorithm (the
        supervisor's degrade/resume switch). The clone shares the parent's
        call counter -- see ``_CallCounter``."""
        clone = self._clone(self._group, self._rank, self._ctx, self._epoch)
        clone._calls = self._calls          # shared object, not a copy
        clone._backend = normalize_backend(backend)
        return clone

    # -- point to point -----------------------------------------------------
    def send(self, dst: int, tag: int, data: Any) -> None:
        """Always non-blocking (paper: 'sending in MPIgnite is always
        nonblocking'); buffered at the receiver."""
        self._put(self._group[dst], self._ctx, tag,
                  self._group[self._rank], data)

    def receive(self, src: int, tag: int) -> Any:
        """Blocking receive ~ MPI_Recv."""
        return self._get(self._ctx, tag, self._group[src])

    def receive_async(self, src: int, tag: int) -> Future:
        """Non-blocking receive ~ MPI_Irecv; returns a Future (Scala Future
        in the paper; ``Await.result`` ~ ``future.result()`` ~ MPI_Wait).

        Mailbox-backed transports service the Future by waiter
        registration on the mailbox itself -- ``Mailbox.put`` completes it
        on arrival and one shared expiry thread enforces the deadline --
        so issuing many concurrent async receives costs zero extra
        threads. Transports without a mailbox fall back to a helper
        thread per call."""
        mb = self._async_mailbox()
        if mb is not None:
            mailbox, timeout = mb
            return mailbox.get_async(self._ctx, tag, self._group[src],
                                     timeout)
        fut: Future = Future()

        def run():
            try:
                fut.set_result(self.receive(src, tag))
            except BaseException as e:  # noqa: BLE001
                fut.set_exception(e)
        threading.Thread(target=run, daemon=True).start()
        return fut

    receiveAsync = receive_async  # paper spelling

    # -- collectives composed from p2p (phase-1 ``linear`` routes through
    #    the root; phase-2 ``ring`` circulates peer-to-peer) -----------------
    #
    # Each multi-step collective is written ONCE, as a resumable schedule
    # generator: sends execute inline, receives are ``yield``ed as
    # ``(ctx, tag, src_world)`` descriptors. The blocking API drives the
    # generator synchronously (``_run_sched``); the nonblocking API hands
    # the same generator to the per-rank ``ProgressEngine``, which parks
    # it as a mailbox waiter between steps -- one algorithm, two
    # completion disciplines, conformant by construction.

    def _next_key(self) -> tuple:
        return (*self._epoch, self._ctx, self._calls.next())

    def _send_coll(self, dst: int, tag: int, key: tuple, data: Any) -> None:
        self._put(self._group[dst], stable_ctx(self._ctx, tag, key), tag,
                  self._group[self._rank], data)

    def _recv_coll(self, src: int, tag: int, key: tuple) -> Any:
        return self._get(stable_ctx(self._ctx, tag, key), tag,
                         self._group[src])

    def _recv_op(self, src: int, tag: int, key: tuple) -> tuple:
        """The receive descriptor a schedule yields: directly the
        ``(ctx, tag, src_world)`` match key of the awaited message."""
        return (stable_ctx(self._ctx, tag, key), tag, self._group[src])

    def _run_sched(self, gen) -> Any:
        """Drive a schedule generator to completion with blocking
        receives on the caller's thread -- the blocking collectives."""
        try:
            op = next(gen)
            while True:
                op = gen.send(self._get(*op))
        except StopIteration as s:
            return s.value

    def _barrier_sched(self, tag: int, key: tuple):
        p = len(self._group)
        if self._rank == 0:
            for r in range(1, p):
                yield self._recv_op(r, tag, key)
            for r in range(1, p):
                self._send_coll(r, tag, key, None)
        else:
            self._send_coll(0, tag, key, None)
            yield self._recv_op(0, tag, key)

    def _broadcast_sched(self, root: int, data: Any, tag: int, key: tuple):
        p = len(self._group)
        if self._backend == "ring":
            # pass-along ring from root: root -> root+1 -> ... (P-1 hops)
            if self._rank == root:
                if p > 1:
                    self._send_coll((root + 1) % p, tag, key, data)
                return data
            data = yield self._recv_op((self._rank - 1) % p, tag, key)
            if (self._rank + 1) % p != root:
                self._send_coll((self._rank + 1) % p, tag, key, data)
            return data
        if self._rank == root:
            for r in range(p):
                if r != root:
                    self._send_coll(r, tag, key, data)
            return data
        return (yield self._recv_op(root, tag, key))

    def _allreduce_sched(self, data: Any, f: Callable, tag: int, key: tuple):
        p = len(self._group)
        if p == 1:
            return data
        if self._backend == "ring":
            acc, v = data, data
            right = (self._rank + 1) % p
            left = (self._rank - 1) % p
            for _ in range(p - 1):
                self._send_coll(right, tag, key, v)
                v = yield self._recv_op(left, tag, key)
                acc = f(acc, v)
            return acc
        if self._rank == 0:
            acc = data
            for r in range(1, p):
                acc = f(acc, (yield self._recv_op(r, tag, key)))
            for r in range(1, p):
                self._send_coll(r, tag, key, acc)
            return acc
        self._send_coll(0, tag, key, data)
        return (yield self._recv_op(0, tag, key))

    def _allgather_sched(self, data: Any, tag: int, key: tuple):
        p = len(self._group)
        if p == 1:
            return [data]
        out = [None] * p
        out[self._rank] = data
        if self._backend == "ring":
            right = (self._rank + 1) % p
            left = (self._rank - 1) % p
            v = data
            for step in range(p - 1):
                self._send_coll(right, tag, key, v)
                v = yield self._recv_op(left, tag, key)
                out[(self._rank - step - 1) % p] = v
            return out
        if self._rank == 0:
            for r in range(1, p):
                out[r] = yield self._recv_op(r, tag, key)
            for r in range(1, p):
                self._send_coll(r, tag, key, out)
            return out
        self._send_coll(0, tag, key, data)
        return (yield self._recv_op(0, tag, key))

    def barrier(self) -> None:
        """Message-realized barrier: gather a token at rank 0, then release
        everyone (works over any transport, unlike threading.Barrier)."""
        return self._run_sched(self._barrier_sched(-10, self._next_key()))

    def broadcast(self, root: int, data: Any = None) -> Any:
        """comm.broadcast[T](root, data): only the root's payload matters."""
        return self._run_sched(
            self._broadcast_sched(root, data, -2, self._next_key()))

    def allreduce(self, data: Any, f: Callable[[Any, Any], Any]) -> Any:
        """comm.allReduce[T](data, f) with an arbitrary reduction function
        (the paper's enhancement over MPI's fixed op set).

        linear (phase-1): gather to rank 0, fold in comm-rank order,
        broadcast back -- deterministic for non-commutative ``f``.
        ring (phase-2): circulate values around the ring, each rank folding
        as they arrive -- ``f`` must be associative and commutative (same
        restriction as the SPMD ring backend)."""
        return self._run_sched(
            self._allreduce_sched(data, f, -3, self._next_key()))

    def allgather(self, data: Any) -> list:
        return self._run_sched(
            self._allgather_sched(data, -4, self._next_key()))

    # -- nonblocking API (MPI-3 shape): Request-returning twins -------------
    def _progress_engine(self) -> ProgressEngine:
        """The engine advancing this rank's nonblocking collectives.
        Transports with a shared per-rank home (LocalComm's world slot,
        ClusterComm's channel+job) override this; the base fallback keeps
        one lazily-created engine per communicator object."""
        eng = getattr(self, "_engine", None)
        if eng is None:
            eng = self._engine = ProgressEngine(
                name=f"mpignite-progress-r{self._rank}")
        return eng

    def _submit_sched(self, gen, op: str) -> Request:
        mb = self._async_mailbox()
        if mb is None:
            raise NotImplementedError(
                "nonblocking collectives need a mailbox-backed transport "
                "(LocalComm / ClusterComm); this transport has none")
        mailbox, timeout = mb
        return self._progress_engine().submit(gen, mailbox, timeout, op=op)

    def isend(self, dst: int, tag: int, data: Any) -> Request:
        """MPI_Isend. MPIgnite sends are always nonblocking and buffered
        at the receiver, so the request is born complete -- it exists for
        API symmetry (waitall over mixed send/recv requests)."""
        self.send(dst, tag, data)
        return Request.completed(None, op="isend")

    def irecv(self, src: int, tag: int) -> Request:
        """MPI_Irecv: a Request completed by message arrival (waiter
        registration on this rank's mailbox -- zero threads parked),
        failed by deadline expiry or peer death. Supports ``cancel``."""
        mb = self._async_mailbox()
        if mb is None:                      # thread-per-call fallback
            return Request(self.receive_async(src, tag), op="irecv")
        mailbox, timeout = mb
        fut = mailbox.get_async(self._ctx, tag, self._group[src], timeout)
        waiter = getattr(fut, "mpignite_waiter", None)
        hook = waiter.cancel if waiter is not None else None
        return Request(fut, op="irecv", cancel_hook=hook)

    def ibarrier(self) -> Request:
        """Nonblocking barrier: completes when every rank has entered."""
        return self._submit_sched(self._barrier_sched(-10, self._next_key()),
                                  op="ibarrier")

    def ibcast(self, root: int, data: Any = None) -> Request:
        """Nonblocking broadcast; ``wait`` returns the root's payload."""
        return self._submit_sched(
            self._broadcast_sched(root, data, -2, self._next_key()),
            op="ibcast")

    ibroadcast = ibcast

    def iallreduce(self, data: Any, f: Callable[[Any, Any], Any]) -> Request:
        """Nonblocking allreduce: the ring/linear schedule advances on the
        progress engine while the caller computes -- the MPI-3 overlap
        primitive (``wait`` returns the reduced value)."""
        return self._submit_sched(
            self._allreduce_sched(data, f, -3, self._next_key()),
            op="iallreduce")

    def iallgather(self, data: Any) -> Request:
        """Nonblocking allgather; ``wait`` returns the rank-ordered list."""
        return self._submit_sched(
            self._allgather_sched(data, -4, self._next_key()),
            op="iallgather")

    def reducescatter(self, chunks: Sequence[Any], f: Callable) -> Any:
        """Each rank contributes a list of P chunks; rank i gets the f-fold
        of everyone's chunk i."""
        if len(chunks) != len(self._group):
            raise ValueError("reducescatter needs one chunk per rank")
        gathered = self.allgather(list(chunks))
        mine = gathered[0][self._rank]
        for contrib in gathered[1:]:
            mine = f(mine, contrib[self._rank])
        return mine

    def reduce(self, root: int, data: Any, f: Callable[[Any, Any], Any]) -> Any:
        """MPI_Reduce: fold everyone's data at ``root`` (None elsewhere).
        One of the 'more methods' the paper's section 6 plans."""
        tag = -7
        key = self._next_key()
        if self._rank == root:
            acc = data
            for r in range(len(self._group)):
                if r != root:
                    acc = f(acc, self._recv_coll(r, tag, key))
            return acc
        self._send_coll(root, tag, key, data)
        return None

    def gather(self, root: int, data: Any) -> list | None:
        """MPI_Gather: rank-ordered list at ``root`` (None elsewhere)."""
        tag = -8
        key = self._next_key()
        if self._rank == root:
            out = [None] * len(self._group)
            out[root] = data
            for r in range(len(self._group)):
                if r != root:
                    out[r] = self._recv_coll(r, tag, key)
            return out
        self._send_coll(root, tag, key, data)
        return None

    def scan(self, data: Any, f: Callable[[Any, Any], Any]) -> Any:
        """MPI_Scan: inclusive prefix reduction -- rank r receives
        f(x_0, ..., x_r). Linear chain through the ranks."""
        tag = -9
        key = self._next_key()
        if self._rank == 0:
            acc = data
        else:
            acc = f(self._recv_coll(self._rank - 1, tag, key), data)
        if self._rank + 1 < len(self._group):
            self._send_coll(self._rank + 1, tag, key, acc)
        return acc

    def alltoall(self, chunks: Sequence[Any]) -> list:
        if len(chunks) != len(self._group):
            raise ValueError("alltoall needs one chunk per rank")
        tag = -5
        key = self._next_key()
        for r in range(len(self._group)):
            if r != self._rank:
                self._send_coll(r, tag, key, chunks[r])
        out = [None] * len(self._group)
        out[self._rank] = chunks[self._rank]
        for r in range(len(self._group)):
            if r != self._rank:
                out[r] = self._recv_coll(r, tag, key)
        return out

    # -- split (paper section 3.1: ranks send (global rank, key, color) to the
    #    lowest participating rank; it groups by color, sorts by key, and
    #    broadcasts the new rank mapping) ------------------------------------
    def split(self, color: int, key: int) -> "MessageComm":
        tag = -6
        ckey = self._next_key()
        root = 0
        if self._rank == root:
            triples = [(self._rank, key, color)]
            for r in range(1, len(self._group)):
                triples.append(self._recv_coll(r, tag, ckey))
            colors = {}
            for r, k, c in triples:
                colors.setdefault(c, []).append((k, r))
            mapping = {}
            for c, members in colors.items():
                members.sort()
                mapping[c] = tuple(r for _, r in members)
            for r in range(1, len(self._group)):
                self._send_coll(r, tag, ckey, mapping)
        else:
            self._send_coll(root, tag, ckey, (self._rank, key, color))
            mapping = self._recv_coll(root, tag, ckey)
        my_group_parent_ranks = mapping[color]
        new_group = tuple(self._group[r] for r in my_group_parent_ranks)
        new_rank = my_group_parent_ranks.index(self._rank)
        new_ctx = G.context_id((tuple(sorted(new_group)),), self._ctx) ^ \
            stable_ctx(self._ctx, tag, ("split", *ckey, color)) & 0xFFFFFFFF
        return self._clone(new_group, new_rank, new_ctx,
                           (*self._epoch, "s", self._calls.n, color))

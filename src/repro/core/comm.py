"""PeerComm -- MPIgnite's SparkComm adapted to SPMD JAX ("cluster mode").

A ``PeerComm`` spans one mesh axis (optionally restricted to equal-size
rank groups, the result of ``split``) and exposes the paper's communicator
API inside ``shard_map``/``jit``. Three interchangeable backends implement
every collective:

- ``linear``  -- the paper's phase-1 implementation: every byte relays
                 through a master/root. Realized in SPMD as full-buffer
                 rotate/relay chains with the same wire-byte and
                 serialization structure (see DESIGN.md section 10).
- ``ring``    -- the paper's phase-2 true peer-to-peer mode: chunked
                 ring reduce-scatter/all-gather composed from
                 ``lax.ppermute`` (ICI collective-permute).
- ``native``  -- beyond-paper: XLA's fused collectives (psum/all_gather/
                 psum_scatter/all_to_all), overlappable by the compiler's
                 latency-hiding scheduler.

Every backend logs the bytes it moves to a trace-time ``CostLog`` so that
benchmarks and the roofline harness can cross-check analytic collective
bytes against HLO-parsed ones.

Restrictions relative to the Spark runtime (adaptation, not omission --
DESIGN.md section 2): routing is static (trace-time), receive-side
buffering does not exist (a p2p op is a rendezvous), and user reduction
functions must be elementwise-associative/commutative.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from . import groups as G
from .matching import Request
from .obs.trace import process_tracer

# ---------------------------------------------------------------------------
# Cost logging
# ---------------------------------------------------------------------------

_COST_LOG: contextvars.ContextVar[list | None] = contextvars.ContextVar(
    "mpignite_cost_log", default=None)
_COST_MULT: contextvars.ContextVar[int] = contextvars.ContextVar(
    "mpignite_cost_mult", default=1)
_COST_OVERLAP: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "mpignite_cost_overlap", default=False)


@contextlib.contextmanager
def cost_log():
    """Collect a CollectiveCost record for every comm call traced while the
    context is active (use around ``jax.eval_shape``/``.lower()``)."""
    log: list[G.CollectiveCost] = []
    tok = _COST_LOG.set(log)
    try:
        yield log
    finally:
        _COST_LOG.reset(tok)


@contextlib.contextmanager
def cost_scope(multiplier: int):
    """Scale costs logged inside (e.g. a ``lax.scan`` body traced once but
    executed ``multiplier`` times). Nests multiplicatively."""
    tok = _COST_MULT.set(_COST_MULT.get() * int(multiplier))
    try:
        yield
    finally:
        _COST_MULT.reset(tok)


def _log(op: str, backend: str, nbytes: int, steps: int) -> None:
    log = _COST_LOG.get()
    if log is not None:
        mult = _COST_MULT.get()
        log.append(G.CollectiveCost(op, backend, int(nbytes) * mult,
                                    int(steps) * mult,
                                    overlap=_COST_OVERLAP.get()))
    tracer = process_tracer()       # None unless $MPIGNITE_TRACE is set
    if tracer is not None:
        # SPMD collectives are priced at trace time, not observed at run
        # time (they live inside jit); mirror the analytic record as an
        # instant event so a traced session shows all three modes.
        tracer.instant(op, "spmd",
                       {"backend": backend,
                        "nbytes": int(nbytes) * _COST_MULT.get(),
                        "steps": int(steps) * _COST_MULT.get(),
                        "overlap": _COST_OVERLAP.get()})


@contextlib.contextmanager
def _overlap_scope():
    """Everything logged inside was issued through a nonblocking wrapper:
    mark it overlappable so the roofline can discount it against
    compute (XLA's latency-hiding scheduler is free to move it)."""
    tok = _COST_OVERLAP.set(True)
    try:
        yield
    finally:
        _COST_OVERLAP.reset(tok)


_REDUCERS = {
    "add": (lax.psum, jnp.add),
    "max": (lax.pmax, jnp.maximum),
    "min": (lax.pmin, jnp.minimum),
}


def _resolve_op(op) -> tuple[Callable | None, Callable]:
    """-> (native collective or None, elementwise combine fn)."""
    if callable(op):
        return None, op
    if op in _REDUCERS:
        return _REDUCERS[op]
    raise ValueError(f"unknown reduction {op!r}; pass 'add'/'max'/'min' or a "
                     "binary elementwise function")


@dataclasses.dataclass(frozen=True)
class PeerComm:
    """SPMD communicator over mesh axis ``axis`` (paper's SparkComm)."""
    axis: str
    axis_size: int
    backend: str = "native"
    groups: G.Groups | None = None          # None => single world group
    ctx: int = 0

    # -- construction -------------------------------------------------------
    @staticmethod
    def world(axis: str, axis_size: int, backend: str = "native") -> "PeerComm":
        return PeerComm(axis, axis_size, backend, None, 0)

    def _groups(self) -> G.Groups:
        return (self.groups if self.groups is not None
                else G.world_groups(self.axis_size))

    @property
    def size(self) -> int:
        """Static size of (each) group -- the communicator size."""
        return len(self._groups()[0])

    def get_size(self) -> int:
        return self.size

    def with_backend(self, backend: str) -> "PeerComm":
        return dataclasses.replace(self, backend=backend)

    @property
    def _algo(self) -> str:
        """Collective algorithm after alias resolution: the message
        runtimes' ``segmented`` backend maps to ``ring`` here -- the SPMD
        ring collectives are already chunked (reduce-scatter/all-gather)
        at trace time, so segmentation is a no-op refinement and one
        closure text stays valid across all three modes."""
        if self.backend in ("segmented", "segmented-ring"):
            return "ring"
        return self.backend

    # -- traced introspection -------------------------------------------------
    def axis_index(self):
        return lax.axis_index(self.axis)

    def rank(self):
        """Traced comm rank of the calling program instance."""
        if self.groups is None:
            return lax.axis_index(self.axis)
        table = jnp.asarray(G.comm_rank_table(self._groups(), self.axis_size),
                            dtype=jnp.int32)
        return table[lax.axis_index(self.axis)]

    def get_rank(self):
        return self.rank()

    # -- split ------------------------------------------------------------------
    def split(self, colors: Sequence[int], keys: Sequence[int] | None = None
              ) -> "PeerComm":
        """MPI_Comm_split with *static* color/key tables indexed by comm rank
        (trace-time analogue of the paper's runtime color exchange; the
        LocalComm backend performs the real message-based exchange). All
        resulting color groups must be equal-size (SPMD restriction)."""
        if keys is None:
            keys = list(range(self.size))
        per_color = G.split_groups(self._groups(), list(colors), list(keys))
        merged: list[tuple[int, ...]] = []
        for color in sorted(per_color):
            merged.extend(per_color[color])
        merged_t = tuple(merged)
        G.validate_groups(merged_t, self.axis_size)
        return dataclasses.replace(
            self, groups=merged_t, ctx=G.context_id(merged_t, self.ctx))

    # -- point-to-point -----------------------------------------------------------
    def _ppermute(self, x, pairs_axis: list[tuple[int, int]], op: str = "p2p"):
        x = jnp.asarray(x)
        _log(op, self.backend, x.nbytes, 1)
        return lax.ppermute(x, self.axis, pairs_axis)

    def p2p(self, x, pairs: Sequence[tuple[int, int]], tag: int = 0):
        """Static sendrecv pattern: ``pairs`` are (src, dst) in comm-rank
        space; context isolation (no cross-group messages) is enforced at
        trace time. Ranks not named as a destination receive zeros."""
        del tag  # structural in SPMD; kept for API parity with the paper
        axis_pairs = G.p2p_perm(self._groups(), list(pairs), self.axis_size)
        return self._ppermute(x, axis_pairs)

    def shift(self, x, k: int = 1):
        """Ring shift by k within every group (the PP/ring primitive):
        rank r's value goes to rank (r+k) mod P."""
        return self._ppermute(x, G.ring_perm(self._groups(), k))

    # -- collectives ----------------------------------------------------------------
    def barrier(self):
        """Cross-group sync point; returns a (traced) zero token."""
        return self.allreduce(jnp.zeros((), jnp.int32), "add")

    def _native_groups_ok(self) -> bool:
        """XLA's SPMD collectives accept axis_index_groups under jit, but
        shard_map's psum/pmax rules do not implement them (verified on
        jax 0.8). Split communicators therefore realize `native` calls
        with the ring algorithms (identical wire bytes; the fused-overlap
        advantage only ever applied to whole-axis collectives anyway)."""
        return self.groups is None

    def allreduce(self, x, op="add", *, tag: int = 0):
        del tag
        x = jnp.asarray(x)
        if self.size == 1:
            return x
        native, combine = _resolve_op(op)
        if self._algo == "native" and native is not None \
                and self._native_groups_ok():
            _log("allreduce", "native",
                 2 * x.nbytes * (self.size - 1) // self.size,
                 2 * (self.size - 1))
            return native(x, self.axis, axis_index_groups=self._axis_groups())
        if self._algo in ("native", "ring"):
            return self._ring_allreduce(x, combine)
        return self._linear_allreduce(x, combine)

    def broadcast(self, x, root: int = 0):
        x = jnp.asarray(x)
        if self.size == 1:
            return x
        if self._algo == "native" and self._native_groups_ok():
            work = x.astype(jnp.int32) if x.dtype == jnp.bool_ else x
            sel = jnp.where(self.rank() == root, work, jnp.zeros_like(work))
            _log("broadcast", "native", x.nbytes, 1)
            out = lax.psum(sel, self.axis, axis_index_groups=self._axis_groups())
            return out.astype(x.dtype)
        # ring / linear: pass-along relay from root ((P-1) full-size steps --
        # under `linear` the root IS the paper's master, so relay == phase-1).
        return self._relay_from(x, root)

    def allgather(self, x, *, axis: int = 0, tiled: bool = False):
        """Gather per-rank contributions. ``tiled=False`` stacks a new
        leading group dimension at position ``axis``; ``tiled=True``
        concatenates along ``axis``."""
        x = jnp.asarray(x)
        if self.size == 1:
            return x if tiled else jnp.expand_dims(x, axis)
        if self._algo == "native" and self._native_groups_ok():
            _log("allgather", "native", x.nbytes * (self.size - 1),
                 self.size - 1)
            return lax.all_gather(x, self.axis, axis=axis, tiled=tiled,
                                  axis_index_groups=self._axis_groups())
        stacked = self._ring_allgather(x)          # (P, ...)
        if self._algo == "linear":
            # master relay-out: the root re-broadcasts the full P*S buffer
            # ((P-1) steps of P*S bytes -- the phase-1 cost structure).
            stacked = self._relay_from(stacked, root=0)
        if tiled:
            return jnp.concatenate([stacked[i] for i in range(self.size)],
                                   axis=axis)
        return stacked if axis == 0 else jnp.moveaxis(stacked, 0, axis)

    def reducescatter(self, x, op="add", *, axis: int = 0):
        """Tiled reduce-scatter: dim ``axis`` (size P*c) is reduced across
        ranks and this rank keeps its c-slice (slice index = comm rank)."""
        x = jnp.asarray(x)
        if self.size == 1:
            return x
        _, combine = _resolve_op(op)
        if self._algo == "native" and op == "add" \
                and self._native_groups_ok():
            _log("reducescatter", "native",
                 x.nbytes * (self.size - 1) // self.size, self.size - 1)
            return lax.psum_scatter(x, self.axis, scatter_dimension=axis,
                                    tiled=True,
                                    axis_index_groups=self._axis_groups())
        if self._algo in ("native", "ring"):
            return self._ring_reducescatter(x, combine, axis)
        # linear: the master computes the full reduction, then scatters.
        full = self._linear_allreduce(x, combine)
        c = x.shape[axis] // self.size
        return lax.dynamic_slice_in_dim(full, self.rank() * c, c, axis=axis)

    def alltoall(self, x, *, split_axis: int = 0, concat_axis: int = 0):
        """lax.all_to_all(tiled=True) semantics: split into P pieces along
        ``split_axis`` (piece i -> comm rank i), concatenate received pieces
        along ``concat_axis`` in source-rank order."""
        x = jnp.asarray(x)
        if self.size == 1:
            return x
        if self._algo == "native" and self._native_groups_ok():
            _log("alltoall", "native",
                 x.nbytes * (self.size - 1) // self.size, self.size - 1)
            return lax.all_to_all(x, self.axis, split_axis, concat_axis,
                                  tiled=True,
                                  axis_index_groups=self._axis_groups())
        return self._pairwise_alltoall(x, split_axis, concat_axis)

    def reduce(self, x, root: int = 0, op="add"):
        """MPI_Reduce in SPMD form: every rank computes the reduction (a
        rendezvous program cannot idle non-roots); non-roots receive
        zeros, mirroring 'significant only at root' semantics."""
        full = self.allreduce(x, op)
        return jnp.where(self.rank() == root, full, jnp.zeros_like(full))

    def gather(self, x, root: int = 0, *, axis: int = 0):
        """MPI_Gather: stacked (P, ...) at root, zeros elsewhere."""
        stacked = self.allgather(x, axis=axis)
        return jnp.where(self.rank() == root, stacked,
                         jnp.zeros_like(stacked))

    def scan(self, x, op="add"):
        """MPI_Scan (inclusive prefix reduction) via a shifted ring:
        after step k, rank r has folded ranks [r-2^k+1 .. r] -- a
        log-step Hillis-Steele scan over ppermute."""
        x = jnp.asarray(x)
        if self.size == 1:
            return x
        _, combine = _resolve_op(op)
        rank = self.rank()
        acc = x
        shift = 1
        while shift < self.size:
            moved = self._ppermute(acc, G.ring_perm(self._groups(), shift),
                                   op="scan")
            acc = jnp.where(rank >= shift, combine(acc, moved), acc)
            shift *= 2
        return acc

    def scatter(self, x, root: int = 0, *, axis: int = 0):
        """MPI_Scatter in SPMD form: dim ``axis`` (size P*c) of the
        root's buffer is split into P slices and rank i keeps slice i
        (every rank passes a congruent buffer -- rendezvous; only the
        root's content matters, mirroring 'significant only at root')."""
        x = jnp.asarray(x)
        if self.size == 1:
            return x
        if x.shape[axis] % self.size:
            raise ValueError(f"scatter dim {axis} size {x.shape[axis]} not "
                             f"divisible by group size {self.size}")
        full = self.broadcast(x, root)
        c = x.shape[axis] // self.size
        return lax.dynamic_slice_in_dim(full, self.rank() * c, c, axis=axis)

    # -- nonblocking wrappers (MPI-3 shape) ---------------------------------
    # In SPMD the runtime cannot defer a collective at the Python level --
    # XLA's latency-hiding scheduler IS the progress engine, free to
    # overlap any collective whose result is not yet consumed. These
    # wrappers keep one program text valid across all three modes: they
    # trace the collective eagerly, flag its logged cost as overlappable,
    # and return a born-complete ``Request`` whose ``wait`` yields the
    # traced value (the data dependency the compiler schedules around).

    def iallreduce(self, x, op="add", *, tag: int = 0) -> Request:
        with _overlap_scope():
            return Request.completed(self.allreduce(x, op, tag=tag),
                                     op="iallreduce")

    def iallgather(self, x, *, axis: int = 0, tiled: bool = False) -> Request:
        with _overlap_scope():
            return Request.completed(
                self.allgather(x, axis=axis, tiled=tiled), op="iallgather")

    def ibcast(self, x, root: int = 0) -> Request:
        with _overlap_scope():
            return Request.completed(self.broadcast(x, root), op="ibcast")

    ibroadcast = ibcast

    def ibarrier(self) -> Request:
        with _overlap_scope():
            return Request.completed(self.barrier(), op="ibarrier")

    def ireduce(self, x, root: int = 0, op="add") -> Request:
        with _overlap_scope():
            return Request.completed(self.reduce(x, root, op), op="ireduce")

    def igather(self, x, root: int = 0, *, axis: int = 0) -> Request:
        with _overlap_scope():
            return Request.completed(self.gather(x, root, axis=axis),
                                     op="igather")

    def iscatter(self, x, root: int = 0, *, axis: int = 0) -> Request:
        with _overlap_scope():
            return Request.completed(self.scatter(x, root, axis=axis),
                                     op="iscatter")

    def iscan(self, x, op="add") -> Request:
        with _overlap_scope():
            return Request.completed(self.scan(x, op), op="iscan")

    def ialltoall(self, x, *, split_axis: int = 0,
                  concat_axis: int = 0) -> Request:
        with _overlap_scope():
            return Request.completed(
                self.alltoall(x, split_axis=split_axis,
                              concat_axis=concat_axis), op="ialltoall")

    def ireducescatter(self, x, op="add", *, axis: int = 0) -> Request:
        with _overlap_scope():
            return Request.completed(self.reducescatter(x, op, axis=axis),
                                     op="ireducescatter")

    # -- pytree conveniences ----------------------------------------------------
    def tree_allreduce(self, tree, op="add"):
        return jax.tree.map(lambda v: self.allreduce(v, op), tree)

    def tree_allgather(self, tree, *, axis: int = 0, tiled: bool = False):
        return jax.tree.map(
            lambda v: self.allgather(v, axis=axis, tiled=tiled), tree)

    # -- internals -----------------------------------------------------------------
    def _axis_groups(self):
        return None if self.groups is None else [list(g) for g in self.groups]

    def _chunked(self, x):
        """Flatten + pad to (P, chunk)."""
        p = self.size
        flat = x.reshape(-1)
        padded = G.pad_to_multiple(flat.shape[0], p)
        if padded != flat.shape[0]:
            flat = jnp.pad(flat, (0, padded - flat.shape[0]))
        return flat.reshape(p, padded // p), x.shape, x.size

    def _relay_from(self, val, root: int):
        """Pass-along ring relay of ``val`` from ``root``; (P-1) full-size
        steps. After s hops, rank r holds root's copy iff (r-root)%P == s."""
        p = self.size
        rank = self.rank()
        v = val
        out = val
        for s in range(1, p):
            v = self._ppermute(v, G.ring_perm(self._groups(), 1),
                               op="broadcast")
            out = jnp.where((rank - root) % p == s, v, out)
        return out

    def _ring_allreduce(self, x, combine):
        """Chunked ring: reduce-scatter then all-gather; 2S(P-1)/P bytes."""
        p = self.size
        buf, orig_shape, orig_size = self._chunked(x)
        rank = self.rank()
        for step in range(p - 1):               # reduce-scatter phase
            send_idx = (rank - step) % p
            recv_idx = (rank - step - 1) % p
            msg = lax.dynamic_slice_in_dim(buf, send_idx, 1, axis=0)
            msg = self._ppermute(msg, G.ring_perm(self._groups(), 1),
                                 op="allreduce")
            cur = lax.dynamic_slice_in_dim(buf, recv_idx, 1, axis=0)
            buf = lax.dynamic_update_slice_in_dim(
                buf, combine(cur, msg), recv_idx, axis=0)
        for step in range(p - 1):               # all-gather phase
            send_idx = (rank - step + 1) % p
            recv_idx = (rank - step) % p
            msg = lax.dynamic_slice_in_dim(buf, send_idx, 1, axis=0)
            msg = self._ppermute(msg, G.ring_perm(self._groups(), 1),
                                 op="allreduce")
            buf = lax.dynamic_update_slice_in_dim(buf, msg, recv_idx, axis=0)
        return buf.reshape(-1)[:orig_size].reshape(orig_shape)

    def _linear_allreduce(self, x, combine):
        """Paper phase-1: gather-to-master + master-broadcast, emulated with
        2(P-1) full-buffer steps (same wire bytes / serialization depth).
        ``combine`` must be commutative: accumulation order is rank-relative."""
        p = self.size
        acc, v = x, x
        for _ in range(p - 1):                  # gather phase
            v = self._ppermute(v, G.ring_perm(self._groups(), 1),
                               op="allreduce")
            acc = combine(acc, v)
        return self._relay_from(acc, root=0)    # master-broadcast phase

    def _ring_allgather(self, x):
        """-> (P, ...) stacked in comm-rank order; (P-1) steps of S bytes."""
        p = self.size
        rank = self.rank()
        buf = jnp.zeros((p,) + x.shape, x.dtype)
        buf = lax.dynamic_update_slice_in_dim(buf, x[None], rank, axis=0)
        msg = x
        for step in range(p - 1):
            msg = self._ppermute(msg, G.ring_perm(self._groups(), 1),
                                 op="allgather")
            src = (rank - step - 1) % p
            buf = lax.dynamic_update_slice_in_dim(buf, msg[None], src, axis=0)
        return buf

    def _ring_reducescatter(self, x, combine, axis):
        p = self.size
        rank = self.rank()
        if x.shape[axis] % p:
            raise ValueError(f"reducescatter dim {axis} size {x.shape[axis]} "
                             f"not divisible by group size {p}")
        buf = jnp.moveaxis(x, axis, 0)
        c = buf.shape[0] // p
        buf = buf.reshape((p, c) + buf.shape[1:])
        for step in range(p - 1):
            send_idx = (rank - step) % p
            recv_idx = (rank - step - 1) % p
            msg = lax.dynamic_slice_in_dim(buf, send_idx, 1, axis=0)
            msg = self._ppermute(msg, G.ring_perm(self._groups(), 1),
                                 op="reducescatter")
            cur = lax.dynamic_slice_in_dim(buf, recv_idx, 1, axis=0)
            buf = lax.dynamic_update_slice_in_dim(
                buf, combine(cur, msg), recv_idx, axis=0)
        mine = lax.dynamic_slice_in_dim(buf, (rank + 1) % p, 1, axis=0)[0]
        return jnp.moveaxis(mine, 0, axis) if axis != 0 else mine

    def _pairwise_alltoall(self, x, split_axis, concat_axis):
        """ring: P-1 direct chunk exchanges ((P-1)/P * S bytes);
        linear: P-1 full-buffer relay hops ((P-1) * S bytes)."""
        p = self.size
        rank = self.rank()
        xs = jnp.moveaxis(x, split_axis, 0)
        if xs.shape[0] % p:
            raise ValueError("alltoall split dim not divisible by group size")
        c = xs.shape[0] // p
        xs = xs.reshape((p, c) + xs.shape[1:])   # xs[j] = piece for comm rank j
        res = jnp.zeros_like(xs)                 # res[j] = piece from comm rank j
        own = lax.dynamic_slice_in_dim(xs, rank, 1, axis=0)
        res = lax.dynamic_update_slice_in_dim(res, own, rank, axis=0)
        if self._algo == "linear":
            v = xs
            for s in range(1, p):
                v = self._ppermute(v, G.ring_perm(self._groups(), 1),
                                   op="alltoall")
                # v holds rank (r-s)'s full buffer; extract the piece for me.
                mine = lax.dynamic_slice_in_dim(v, rank, 1, axis=0)
                res = lax.dynamic_update_slice_in_dim(
                    res, mine, (rank - s) % p, axis=0)
        else:
            for s in range(1, p):
                # send the piece destined for rank+s directly (shift by s)
                msg = lax.dynamic_slice_in_dim(xs, (rank + s) % p, 1, axis=0)
                msg = self._ppermute(msg, G.ring_perm(self._groups(), s),
                                     op="alltoall")
                res = lax.dynamic_update_slice_in_dim(
                    res, msg, (rank - s) % p, axis=0)
        # Each piece restored to original rank layout with split dim = c,
        # then concatenated along concat_axis in source-rank order.
        pieces = [jnp.moveaxis(res[j], 0, split_axis) if split_axis != 0
                  else res[j] for j in range(p)]
        return jnp.concatenate(pieces, axis=concat_axis)

"""Runtime observability: per-rank tracing, metrics, structured logging.

Enable tracing with ``MPIGNITE_TRACE=1`` (or ``pool.run(...,
trace=True)`` in cluster mode); set log verbosity with
``MPIGNITE_LOG=info``. See the README "Observability" section.
"""
from .log import LOG_ENV, RankLogger, get_logger
from .metrics import ChannelStats, cross_check_collectives, format_cross_check
from .trace import (
    DEFAULT_CAPACITY,
    TRACE_ENV,
    TRACE_EVENTS_ENV,
    CollSpan,
    JobTrace,
    Tracer,
    current_span,
    process_tracer,
    reset_process_tracer,
    set_current_span,
    trace_enabled,
)

__all__ = [
    "LOG_ENV", "RankLogger", "get_logger",
    "ChannelStats", "cross_check_collectives", "format_cross_check",
    "DEFAULT_CAPACITY", "TRACE_ENV", "TRACE_EVENTS_ENV",
    "CollSpan", "JobTrace", "Tracer",
    "current_span", "set_current_span",
    "process_tracer", "reset_process_tracer", "trace_enabled",
]

"""Always-on runtime metrics + the measured-vs-analytic cost cross-check.

Two halves:

- :class:`ChannelStats` -- cheap per-channel tx/rx byte and frame
  counters kept by the cluster wire layer regardless of tracing (integer
  adds; no allocation on the hot path).
- :func:`cross_check_collectives` -- compares the payload bytes a traced
  collective *actually* sent against ``groups.collective_cost``'s
  analytic prediction. This extends the SPMD HLO byte cross-check to the
  message runtime: the analytic model is what benchmarks and roofline
  terms are built on, so a drift here means either the model or the
  schedule is wrong.

Cross-check rules (the "documented overhead allowance" in the README):

- Measured bytes are *payload* bytes counted where the schedule hands a
  message to the transport (``matching.payload_nbytes``), so wire
  framing/HMAC/pickle overhead never enters; the slack covers the small
  meta messages segmented schedules lead with and the rounding of
  near-equal chunking (``chunk_bounds``).
- Each (op, backend) pair is checked at the scope where the
  implementation and the model actually describe the same quantity
  (``_CHECKS``):

  * ``allreduce/segmented`` -- per rank. The segmented reduce-scatter +
    all-gather schedule is exactly the model's bandwidth-optimal ring:
    every rank moves ``2*S*(p-1)/p`` bytes.
  * ``allreduce/linear``, ``broadcast/linear`` -- group total. The relay
    concentrates traffic at the root (root moves O(p*S), leaves S), and
    the model's ``bytes_per_device`` equals the *total* relay volume.
  * ``broadcast/ring|segmented`` -- group total. The pass-along ring
    moves S per hop over p-1 hops; the model's ``(p-1)*S`` counts the
    same bytes summed over the ring (per-device in SPMD, where every
    device participates in each ppermute hop).

- Combinations *not* in the table are skipped, deliberately: the
  whole-buffer ring allreduce circulates full payloads ((p-1)*S per
  rank) and is not the chunked algorithm the ring model prices -- the
  segmented upgrade is what realizes that model on the message runtime.
- ``barrier`` and 0-byte payloads are skipped (pure latency, no byte
  model).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

from ..groups import collective_cost

#: (base op, backend) -> comparison scope. Scopes: "per-rank" compares
#: every rank's sent bytes against ``bytes_per_device``; "group-total"
#: compares the sum over one call's spans (all ranks) against it.
_CHECKS = {
    ("allreduce", "segmented"): "per-rank",
    ("allreduce", "linear"): "group-total",
    ("broadcast", "linear"): "group-total",
    ("broadcast", "ring"): "group-total",
    ("broadcast", "segmented"): "group-total",
}

_I_OPS = ("allreduce", "broadcast", "allgather", "reducescatter",
          "alltoall", "barrier", "bcast", "gather", "scatter", "reduce",
          "scan")


@dataclass
class ChannelStats:
    """Tx/rx totals for one executor's wire channels (control plane +
    every peer link). Updated from socket read/write paths; all fields
    monotonic."""
    tx_frames: int = 0
    tx_bytes: int = 0
    rx_frames: int = 0
    rx_bytes: int = 0
    #: frames/bytes that traveled the shared-memory rings instead of a
    #: socket. These are *subsets* of the totals above (an shm frame is
    #: byte-identical to its TCP form and counts in both), so the
    #: measured-vs-analytic byte cross-check holds regardless of which
    #: transport the broker picked.
    shm_tx_frames: int = 0
    shm_tx_bytes: int = 0
    shm_rx_frames: int = 0
    shm_rx_bytes: int = 0
    #: per-peer-rank breakdown; the driver appears as rank -1.
    per_peer: dict = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    def _peer(self, peer: int) -> dict:
        p = self.per_peer.get(peer)
        if p is None:
            p = self.per_peer[peer] = {"tx_frames": 0, "tx_bytes": 0,
                                       "rx_frames": 0, "rx_bytes": 0,
                                       "shm_tx_bytes": 0,
                                       "shm_rx_bytes": 0}
        return p

    def on_tx(self, peer: int, nbytes: int, shm: bool = False) -> None:
        with self._lock:
            self.tx_frames += 1
            self.tx_bytes += nbytes
            p = self._peer(peer)
            p["tx_frames"] += 1
            p["tx_bytes"] += nbytes
            if shm:
                self.shm_tx_frames += 1
                self.shm_tx_bytes += nbytes
                p["shm_tx_bytes"] += nbytes

    def on_rx(self, peer: int, nbytes: int, shm: bool = False) -> None:
        with self._lock:
            self.rx_frames += 1
            self.rx_bytes += nbytes
            p = self._peer(peer)
            p["rx_frames"] += 1
            p["rx_bytes"] += nbytes
            if shm:
                self.shm_rx_frames += 1
                self.shm_rx_bytes += nbytes
                p["shm_rx_bytes"] += nbytes

    def summary(self) -> dict:
        with self._lock:
            return {"tx_frames": self.tx_frames, "tx_bytes": self.tx_bytes,
                    "rx_frames": self.rx_frames, "rx_bytes": self.rx_bytes,
                    "shm_tx_frames": self.shm_tx_frames,
                    "shm_tx_bytes": self.shm_tx_bytes,
                    "shm_rx_frames": self.shm_rx_frames,
                    "shm_rx_bytes": self.shm_rx_bytes,
                    "peers": {k: dict(v) for k, v in self.per_peer.items()}}


@dataclass
class AcceptanceStats:
    """Speculative-decoding acceptance accounting for one engine.

    The draft model proposes ``gamma`` tokens per slot per spec round;
    the target accepts a prefix. This tracks the aggregate ratio (the
    number every capacity model of speculative decoding turns on) plus
    a live per-request breakdown so a finished ``Generation`` can carry
    its own acceptance ratio. Per-request entries are popped when the
    request finishes, so memory stays bounded by in-flight requests,
    not by requests served.
    """
    proposed: int = 0
    accepted: int = 0
    rounds: int = 0
    #: uid -> [proposed, accepted] for requests still in flight
    live: dict = field(default_factory=dict)

    def record(self, uid: int, proposed: int, accepted: int) -> None:
        self.proposed += proposed
        self.accepted += accepted
        self.rounds += 1
        ent = self.live.setdefault(uid, [0, 0])
        ent[0] += proposed
        ent[1] += accepted

    def pop_request(self, uid: int) -> float | None:
        """Finish one request: drop its live entry, return its mean
        acceptance ratio (None when it never ran a spec round)."""
        ent = self.live.pop(uid, None)
        if ent is None or ent[0] == 0:
            return None
        return ent[1] / ent[0]

    @property
    def ratio(self) -> float:
        """Aggregate accepted/proposed over the engine's lifetime."""
        return self.accepted / max(self.proposed, 1)

    def summary(self) -> dict:
        return {"proposed": self.proposed, "accepted": self.accepted,
                "rounds": self.rounds, "ratio": self.ratio}

    def publish(self, tracer, prefix: str = "serve.spec") -> None:
        """Drop the aggregate into a Tracer's free-form counters so the
        ratio lands in the job's traced snapshot (JobTrace.counters)."""
        if tracer is None:
            return
        tracer.counters[f"{prefix}.proposed"] = self.proposed
        tracer.counters[f"{prefix}.accepted"] = self.accepted
        tracer.counters[f"{prefix}.rounds"] = self.rounds
        tracer.counters[f"{prefix}.accept_ratio"] = round(self.ratio, 4)


def base_op(op: str) -> str:
    """``iallreduce`` -> ``allreduce`` etc.; the byte model is identical,
    only the overlap flag differs."""
    return op[1:] if op.startswith("i") and op[1:] in _I_OPS else op


def cross_check_collectives(rows: list[dict], rel_tol: float = 0.25,
                            abs_tol: int = 4096) -> list[dict]:
    """Compare traced collective spans against the analytic byte model.

    ``rows`` come from ``JobTrace.collectives()``. Returns one verdict
    dict per checked site with ``ok``, ``measured``, ``expected`` and
    the comparison scope; callers assert ``all(v["ok"] for v in
    verdicts)``. Ops/backends outside the documented ``_CHECKS`` table
    are ignored (see module docstring for why).
    """
    verdicts: list[dict] = []

    def tol(expected: int) -> float:
        return max(abs_tol, rel_tol * expected)

    sites: dict[tuple, list[dict]] = {}
    for r in rows:
        base = base_op(r["op"])
        scope = _CHECKS.get((base, r["backend"]))
        if scope is None or r["nbytes"] <= 0 or r["p"] <= 1:
            continue
        sites.setdefault((base, r["backend"], r["p"], r["nbytes"], scope),
                         []).append(r)

    for (base, backend, p, nbytes, scope), group in sorted(
            sites.items(), key=lambda kv: str(kv[0])):
        expected = collective_cost(base, backend, nbytes, p).bytes_per_device
        if scope == "group-total":
            # the group may hold several identical calls (every rank
            # contributes one span per call) -- normalize per call.
            calls = max(1, round(len(group) / p))
            measured = sum(r["sent_bytes"] for r in group) / calls
            verdicts.append({
                "op": base, "backend": backend, "p": p, "nbytes": nbytes,
                "scope": scope, "calls": calls,
                "measured": int(measured), "expected": expected,
                "ok": abs(measured - expected) <= tol(expected)})
        else:
            for r in group:
                verdicts.append({
                    "op": base, "backend": backend, "p": p,
                    "nbytes": nbytes, "rank": r["rank"],
                    "scope": scope, "calls": 1,
                    "measured": r["sent_bytes"], "expected": expected,
                    "ok": abs(r["sent_bytes"] - expected) <= tol(expected)})
    return verdicts


def format_cross_check(verdicts: list[dict]) -> str:
    lines = [f"{'op':<14}{'backend':<11}{'p':>3}{'scope':>13}"
             f"{'measured':>12}{'expected':>12}  ok"]
    for v in verdicts:
        lines.append(
            f"{v['op']:<14}{v['backend']:<11}{v['p']:>3}{v['scope']:>13}"
            f"{v['measured']:>12}{v['expected']:>12}  "
            f"{'yes' if v['ok'] else 'NO'}")
    return "\n".join(lines)

"""Structured rank-tagged logging for the runtime.

``$MPIGNITE_LOG`` selects the level (``debug``/``info``/``warning``/
``error``; unset means ``warning`` so a quiet run stays quiet). Every
line carries a ``[rank R/N job J]`` prefix when the emitting component
knows its coordinates, so executor-side failures are attributable to a
rank instead of vanishing into a silent ``except`` clause.

Built on stdlib :mod:`logging` (one ``mpignite`` logger hierarchy, a
single stderr handler installed lazily) so embedders can reroute it with
ordinary logging config; the helpers here only add the rank tagging.
"""
from __future__ import annotations

import logging
import os
import sys
import threading

LOG_ENV = "MPIGNITE_LOG"

_LEVELS = {"debug": logging.DEBUG, "info": logging.INFO,
           "warning": logging.WARNING, "warn": logging.WARNING,
           "error": logging.ERROR, "critical": logging.CRITICAL,
           "off": logging.CRITICAL + 10, "none": logging.CRITICAL + 10}

_configured = False
_lock = threading.Lock()


def env_level() -> int:
    raw = os.environ.get(LOG_ENV, "").strip().lower()
    if not raw:
        return logging.WARNING
    if raw in _LEVELS:
        return _LEVELS[raw]
    try:
        return int(raw)
    except ValueError:
        return logging.WARNING


def _configure() -> None:
    global _configured
    with _lock:
        if _configured:
            return
        root = logging.getLogger("mpignite")
        root.setLevel(env_level())
        if not root.handlers:
            h = logging.StreamHandler(sys.stderr)
            h.setFormatter(logging.Formatter(
                "%(asctime)s %(levelname).1s %(name)s %(message)s",
                datefmt="%H:%M:%S"))
            root.addHandler(h)
        root.propagate = False
        _configured = True


def get_logger(component: str) -> "RankLogger":
    """A rank-taggable logger for one runtime component, e.g.
    ``get_logger("cluster.executor")``."""
    _configure()
    return RankLogger(logging.getLogger(f"mpignite.{component}"))


def reconfigure() -> None:
    """Test hook: re-read ``$MPIGNITE_LOG``."""
    global _configured
    with _lock:
        _configured = False
    _configure()


class RankLogger:
    """Thin wrapper adding ``[rank R/N job J]`` prefixes. Bind
    coordinates once with :meth:`bound` and log freely after; unbound
    loggers emit untagged lines (driver side)."""

    __slots__ = ("_log", "_prefix")

    def __init__(self, log: logging.Logger, prefix: str = ""):
        self._log = log
        self._prefix = prefix

    def bound(self, rank: int | None = None, world: int | None = None,
              job: int | None = None) -> "RankLogger":
        parts = []
        if rank is not None:
            parts.append(f"rank {rank}/{world}" if world is not None
                         else f"rank {rank}")
        if job is not None:
            parts.append(f"job {job}")
        prefix = f"[{' '.join(parts)}] " if parts else ""
        return RankLogger(self._log, prefix)

    def isEnabledFor(self, level: int) -> bool:
        return self._log.isEnabledFor(level)

    def debug(self, msg: str, *args) -> None:
        self._log.debug(self._prefix + msg, *args)

    def info(self, msg: str, *args) -> None:
        self._log.info(self._prefix + msg, *args)

    def warning(self, msg: str, *args) -> None:
        self._log.warning(self._prefix + msg, *args)

    def error(self, msg: str, *args) -> None:
        self._log.error(self._prefix + msg, *args)

    def exception(self, msg: str, *args) -> None:
        self._log.error(self._prefix + msg, *args, exc_info=True)

"""Per-rank runtime tracing: a low-overhead event recorder + exporters.

The message runtime (mailbox matching, segmented ring schedules, the
progress engines, the wire channels) is instrumented with *spans* --
``perf_counter_ns`` intervals recorded into a preallocated per-rank ring
buffer -- and merged at the driver into a per-job :class:`JobTrace` that
exports Chrome trace-event JSON (loadable in Perfetto / ``chrome://
tracing``) and plain metrics tables.

Design constraints, in order:

1. **The disabled path must cost nothing.** Tracing is off unless
   ``$MPIGNITE_TRACE`` is set (or a job was dispatched with
   ``trace=True``). Every instrumentation point in the runtime guards on
   ``tracer is not None`` / ``current_span() is not None`` -- a pointer
   compare -- and allocates nothing when the answer is no. Tests pin
   this with a tracemalloc filter over this module.
2. **The enabled path must be cheap.** Events are plain tuples appended
   to a preallocated ring buffer under one lock; when the buffer wraps,
   the *oldest* events are dropped (a counter records how many), so a
   long job degrades to "most recent window" instead of unbounded
   memory.
3. **Cross-process mergeable.** ``perf_counter_ns`` has a per-process
   epoch, so each tracer also records a wall-clock anchor
   (``time_ns - perf_counter_ns`` at construction); the exporter shifts
   every rank onto the wall clock, which same-host ranks share to well
   under a scheduling quantum. Multi-host merges inherit NTP skew --
   documented, not hidden.

Event tuples are ``(ph, cat, name, ts_ns, dur_ns, tid, args)`` where
``ph`` is the Chrome trace phase (``"X"`` complete span, ``"i"``
instant, ``"C"`` counter), ``ts_ns`` is raw ``perf_counter_ns``, and
``args`` is a small dict or None.

Track layout in the export: one *process* per rank (``pid = rank``,
named ``rank R/N``; the driver is ``pid = world``), and within a rank
one *thread* track per concurrency context (the calling thread for
blocking ops; one synthetic track per outstanding nonblocking schedule)
so overlapping spans never interleave on a single track and nesting --
collective > schedule step > segment -- renders correctly.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any

TRACE_ENV = "MPIGNITE_TRACE"
TRACE_EVENTS_ENV = "MPIGNITE_TRACE_EVENTS"
TRACE_FLUSH_ENV = "MPIGNITE_TRACE_FLUSH"
DEFAULT_CAPACITY = 32768
DEFAULT_FLUSH_INTERVAL = 1.0

#: pid used for driver-side events in the merged export (ranks use their
#: own number; the driver sits after them).
DRIVER_RANK = -1


def trace_enabled() -> bool:
    """Whether ``$MPIGNITE_TRACE`` asks for tracing ("", "0", "false",
    "off" and unset all mean no)."""
    raw = os.environ.get(TRACE_ENV)
    if not raw:                 # unset/empty: allocation-free fast path
        return False
    return raw.lower() not in ("0", "false", "off", "no")


def env_capacity() -> int:
    raw = os.environ.get(TRACE_EVENTS_ENV)
    if not raw:
        return DEFAULT_CAPACITY
    try:
        return max(16, int(raw))
    except ValueError:
        return DEFAULT_CAPACITY


def trace_flush_interval() -> float:
    """Seconds between *mid-job* incremental trace flushes from traced
    executors (``$MPIGNITE_TRACE_FLUSH``; values <= 0 disable streaming
    -- the end-of-job flush always happens). Each incremental frame is
    a cumulative snapshot that replaces the previous one driver-side,
    which is what makes ``pool.last_trace`` recoverable while a job is
    still running (or hung). Read in each traced executor at job start."""
    raw = os.environ.get(TRACE_FLUSH_ENV)
    if not raw:
        return DEFAULT_FLUSH_INTERVAL
    try:
        return float(raw)
    except ValueError:
        return DEFAULT_FLUSH_INTERVAL


# -- the active-collective span, per thread ---------------------------------
#
# Schedules perform their sends deep inside ``MessageComm._send_coll``,
# which does not know which collective it is serving. The span of the
# collective currently advancing *on this thread* lives here; senders
# attribute payload bytes to it. Blocking collectives set it around
# ``_run_sched``; the progress engine sets it around every generator
# resume (schedules interleave on the engine thread, but only one
# advances at a time, so a thread-local is exact).

_tls = threading.local()


def current_span() -> "CollSpan | None":
    return getattr(_tls, "span", None)


def set_current_span(span: "CollSpan | None") -> "CollSpan | None":
    """Install ``span`` as this thread's active collective; returns the
    previous one (restore it when done -- collectives nest via
    ``reducescatter``'s inner allgather)."""
    prev = getattr(_tls, "span", None)
    _tls.span = span
    return prev


class CollSpan:
    """One in-flight collective: accumulates the bytes/messages its
    schedule sends, plus identity for the exported span. Created only
    when tracing is enabled."""
    __slots__ = ("op", "backend", "p", "nbytes", "bytes", "msgs",
                 "t0", "tid", "overlap")

    #: total CollSpans ever constructed in this process -- the
    #: zero-allocation test pins that the disabled path creates none.
    created = 0

    def __init__(self, op: str, backend: str, p: int, nbytes: int,
                 t0: int, tid: str, overlap: bool = False):
        self.op = op
        self.backend = backend
        self.p = p
        self.nbytes = nbytes        # input payload size (cost-model S)
        self.bytes = 0              # payload bytes actually sent
        self.msgs = 0               # messages actually sent
        self.t0 = t0
        self.tid = tid
        self.overlap = overlap
        CollSpan.created += 1

    def add(self, nbytes: int) -> None:
        self.bytes += nbytes
        self.msgs += 1


class Tracer:
    """Per-rank event recorder over a preallocated ring buffer.

    Thread-safe: transport readers, the progress engine, heartbeat
    threads and the closure thread all record concurrently. ``events()``
    returns the surviving window oldest-first; ``snapshot()`` packages
    everything (events, drop counter, clock anchor, runtime counters)
    for shipment to the driver.
    """

    def __init__(self, rank: int, world: int, job: int = 0,
                 capacity: int | None = None):
        self.rank = rank
        self.world = world
        self.job = job
        self.capacity = env_capacity() if capacity is None else int(capacity)
        self._buf: list = [None] * self.capacity
        self._i = 0                 # next write slot
        self._n = 0                 # live events (<= capacity)
        self.dropped = 0
        self._lock = threading.Lock()
        self._open: dict[int, list] = {}    # thread id -> begin stack
        self._track_seq = 0
        #: wall-clock anchor: add to any perf_counter_ns timestamp from
        #: this process to land on the (shared) wall clock.
        self.wall_minus_perf = time.time_ns() - time.perf_counter_ns()
        #: free-form runtime counters merged into the snapshot at flush
        #: (mailbox highs, channel byte totals, engine gauges).
        self.counters: dict[str, Any] = {}

    # -- recording ----------------------------------------------------------
    @staticmethod
    def now() -> int:
        return time.perf_counter_ns()

    def _record(self, ev: tuple) -> None:
        with self._lock:
            if self._buf[self._i] is not None:
                self.dropped += 1           # overwriting the oldest event
            self._buf[self._i] = ev
            self._i = (self._i + 1) % self.capacity
            if self._n < self.capacity:
                self._n += 1

    def complete(self, name: str, cat: str, t0: int, t1: int | None = None,
                 args: dict | None = None, tid: str | None = None) -> None:
        """Record a complete span ("X") from ``t0`` to ``t1`` (now if
        omitted), both ``perf_counter_ns``."""
        if t1 is None:
            t1 = time.perf_counter_ns()
        if tid is None:
            tid = threading.current_thread().name
        self._record(("X", cat, name, t0, max(0, t1 - t0), tid, args))

    def instant(self, name: str, cat: str = "", args: dict | None = None,
                tid: str | None = None) -> None:
        if tid is None:
            tid = threading.current_thread().name
        self._record(("i", cat, name, time.perf_counter_ns(), 0, tid, args))

    def counter(self, name: str, value: float, cat: str = "") -> None:
        self._record(("C", cat, name, time.perf_counter_ns(), 0, "counters",
                      {"value": value}))

    # -- begin/end (balanced-span API; per-thread stack) --------------------
    def begin(self, name: str, cat: str = "", args: dict | None = None
              ) -> None:
        """Open a span on this thread's stack; ``end()`` closes the most
        recent one and records the X event. Strictly LIFO per thread."""
        stack = self._open.setdefault(threading.get_ident(), [])
        stack.append((name, cat, time.perf_counter_ns(), args))

    def end(self) -> None:
        stack = self._open.get(threading.get_ident())
        if not stack:
            raise RuntimeError("Tracer.end() with no open span on this "
                               "thread (begin/end imbalance)")
        name, cat, t0, args = stack.pop()
        self.complete(name, cat, t0, args=args)

    def open_spans(self) -> int:
        """How many begin()s have no matching end() yet, across all
        threads -- 0 after balanced instrumentation."""
        return sum(len(s) for s in self._open.values())

    # -- collective spans ---------------------------------------------------
    def coll_begin(self, op: str, backend: str, p: int, nbytes: int,
                   overlap: bool = False) -> CollSpan:
        if overlap:
            with self._lock:
                self._track_seq += 1
                tid = f"sched-{self._track_seq}"
        else:
            tid = threading.current_thread().name
        return CollSpan(op, backend, p, nbytes, time.perf_counter_ns(),
                        tid, overlap=overlap)

    def coll_end(self, span: CollSpan, error: str | None = None) -> None:
        args = {"backend": span.backend, "p": span.p,
                "nbytes": span.nbytes, "sent_bytes": span.bytes,
                "sent_msgs": span.msgs, "overlap": span.overlap}
        if error is not None:
            args["error"] = error
        self.complete(span.op, "coll", span.t0, args=args, tid=span.tid)

    # -- readback -----------------------------------------------------------
    def events(self) -> list:
        """Surviving events, oldest first."""
        with self._lock:
            if self._n < self.capacity:
                return [e for e in self._buf[:self._n]]
            return (self._buf[self._i:] + self._buf[:self._i])

    def __len__(self) -> int:
        return self._n

    def snapshot(self) -> dict:
        """Everything the driver needs to merge this rank into a
        JobTrace (plain picklable data)."""
        return {"rank": self.rank, "world": self.world, "job": self.job,
                "wall_minus_perf": self.wall_minus_perf,
                "dropped": self.dropped, "events": self.events(),
                "counters": dict(self.counters)}


# ---------------------------------------------------------------------------
# Process-level tracer (SPMD trace-time records, boot-time spans)
# ---------------------------------------------------------------------------

_PROCESS: tuple[int, Tracer | None] | None = None
_PROCESS_LOCK = threading.Lock()


def process_tracer() -> Tracer | None:
    """The per-process tracer used outside any job (SPMD trace-time cost
    records, executor bootstrap spans). None when tracing is disabled.
    Keyed by pid so forked executors get their own."""
    global _PROCESS
    with _PROCESS_LOCK:
        if _PROCESS is None or _PROCESS[0] != os.getpid():
            _PROCESS = (os.getpid(),
                        Tracer(0, 1) if trace_enabled() else None)
        return _PROCESS[1]


def reset_process_tracer() -> None:
    """Test hook: force re-evaluation of ``$MPIGNITE_TRACE``."""
    global _PROCESS
    with _PROCESS_LOCK:
        _PROCESS = None


# ---------------------------------------------------------------------------
# Driver-side aggregation + exporters
# ---------------------------------------------------------------------------

class JobTrace:
    """One job's merged trace: per-rank snapshots plus (optionally) the
    driver's own events, on a common wall-clock timebase.

    ``to_chrome()`` emits Chrome trace-event JSON: one process per rank
    (named ``rank R/N``), spans nested collective -> schedule step ->
    segment on per-context thread tracks. ``table()`` is the plain
    metrics summary; ``cross_check()`` compares measured wire bytes per
    collective against the analytic ``groups.collective_cost`` model.
    """

    def __init__(self, job: int, world: int,
                 snapshots: dict[int, dict],
                 driver_snapshot: dict | None = None):
        self.job = job
        self.world = world
        self.snapshots = dict(snapshots)
        self.driver_snapshot = driver_snapshot

    @classmethod
    def from_tracers(cls, tracers, job: int = 0,
                     driver: "Tracer | None" = None) -> "JobTrace":
        """Build directly from in-process tracers (local mode)."""
        snaps = {t.rank: t.snapshot() for t in tracers if t is not None}
        world = max((t.world for t in tracers if t is not None), default=0)
        return cls(job, world, snaps,
                   driver.snapshot() if driver is not None else None)

    @property
    def ranks(self) -> list[int]:
        return sorted(self.snapshots)

    def dropped(self) -> int:
        return sum(s.get("dropped", 0) for s in self.snapshots.values())

    def events(self, rank: int) -> list:
        """One rank's events with timestamps shifted onto the wall clock
        (ns), oldest first."""
        snap = self.snapshots[rank]
        off = snap["wall_minus_perf"]
        return [(ph, cat, name, ts + off, dur, tid, args)
                for ph, cat, name, ts, dur, tid, args in snap["events"]]

    def counters(self, rank: int) -> dict:
        return dict(self.snapshots[rank].get("counters") or {})

    # -- Chrome trace-event export ------------------------------------------
    def to_chrome(self) -> dict:
        """Trace-event JSON (dict; ``json.dump`` it or use
        ``write_chrome``). Timestamps are wall-clock microseconds."""
        out: list[dict] = []

        def emit(pid: int, pname: str, snap: dict) -> None:
            out.append({"ph": "M", "pid": pid, "tid": 0,
                        "name": "process_name",
                        "args": {"name": pname}})
            off = snap["wall_minus_perf"]
            for ph, cat, name, ts, dur, tid, args in snap["events"]:
                ev = {"ph": ph, "pid": pid, "tid": str(tid), "name": name,
                      "cat": cat or "runtime",
                      "ts": (ts + off) / 1000.0}
                if ph == "X":
                    ev["dur"] = dur / 1000.0
                if ph == "i":
                    ev["s"] = "t"       # thread-scoped instant
                if ph == "C":
                    ev["args"] = {"value": (args or {}).get("value", 0)}
                elif args:
                    ev["args"] = args
                out.append(ev)

        for rank in self.ranks:
            emit(rank, f"rank {rank}/{self.world}", self.snapshots[rank])
        if self.driver_snapshot is not None:
            emit(self.world, "driver", self.driver_snapshot)
        return {"traceEvents": out,
                "displayTimeUnit": "ms",
                "otherData": {"job": self.job, "world": self.world,
                              "dropped_events": self.dropped()}}

    def write_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path

    # -- metrics summary ----------------------------------------------------
    def collectives(self) -> list[dict]:
        """Every collective span across ranks: op, backend, rank, group
        size, input nbytes, measured sent bytes/messages, duration."""
        rows = []
        for rank in self.ranks:
            for ph, cat, name, ts, dur, tid, args in self.events(rank):
                if ph == "X" and cat == "coll":
                    a = args or {}
                    rows.append({"rank": rank, "op": name,
                                 "backend": a.get("backend", "?"),
                                 "p": a.get("p", 0),
                                 "nbytes": a.get("nbytes", 0),
                                 "sent_bytes": a.get("sent_bytes", 0),
                                 "sent_msgs": a.get("sent_msgs", 0),
                                 "overlap": bool(a.get("overlap")),
                                 "dur_ns": dur, "ts_ns": ts})
        return rows

    def op_summary(self) -> dict[str, dict]:
        """Per-op totals across ranks: calls, wall ns (sum over ranks),
        wire bytes, messages."""
        summary: dict[str, dict] = {}
        for row in self.collectives():
            s = summary.setdefault(row["op"], {
                "calls": 0, "wall_ns": 0, "bytes": 0, "msgs": 0})
            s["calls"] += 1
            s["wall_ns"] += row["dur_ns"]
            s["bytes"] += row["sent_bytes"]
            s["msgs"] += row["sent_msgs"]
        return summary

    def table(self) -> str:
        """Plain-text metrics summary: per-op wall time + wire bytes,
        then per-rank runtime counters (wire totals, queue-depth highs,
        engine gauges)."""
        lines = [f"job {self.job} trace: {len(self.ranks)} ranks, "
                 f"{sum(len(self.snapshots[r]['events']) for r in self.ranks)}"
                 f" events, {self.dropped()} dropped"]
        summary = self.op_summary()
        if summary:
            lines.append(f"{'op':<16}{'calls':>6}{'wall_ms':>10}"
                         f"{'MiB_sent':>10}{'msgs':>7}")
            for op in sorted(summary, key=lambda o: -summary[o]["wall_ns"]):
                s = summary[op]
                lines.append(f"{op:<16}{s['calls']:>6}"
                             f"{s['wall_ns'] / 1e6:>10.2f}"
                             f"{s['bytes'] / 2**20:>10.3f}{s['msgs']:>7}")
        for rank in self.ranks:
            ctr = self.counters(rank)
            if ctr:
                kv = " ".join(f"{k}={v}" for k, v in sorted(ctr.items()))
                lines.append(f"rank {rank}: {kv}")
        return "\n".join(lines)

    def phase_breakdown(self) -> str:
        """One-line per-phase breakdown (benchmarks embed this in a
        derived column): top categories by total span time."""
        by_cat: dict[str, int] = {}
        for rank in self.ranks:
            for ph, cat, name, ts, dur, tid, args in self.events(rank):
                if ph == "X":
                    by_cat[cat or "runtime"] = \
                        by_cat.get(cat or "runtime", 0) + dur
        top = sorted(by_cat.items(), key=lambda kv: -kv[1])[:4]
        return " ".join(f"{c}={ns / 1e6:.1f}ms" for c, ns in top)

    def cross_check(self, rel_tol: float = 0.25,
                    abs_tol: int = 4096) -> list[dict]:
        """Measured-vs-analytic wire bytes per collective (the message
        runtime's twin of the SPMD HLO cross-check). See
        ``obs.metrics.cross_check_collectives`` for the rules."""
        from .metrics import cross_check_collectives
        return cross_check_collectives(self.collectives(), rel_tol=rel_tol,
                                       abs_tol=abs_tol)

"""Parallel closures -- the paper's ``sc.parallelizeFunc(f).execute(n)``.

Three execution modes mirror Spark's deployments:

- ``mode="local"``   : n lockstep python threads with a real message-matching
  runtime (``LocalComm``) -- arbitrary payloads, futures, runtime split.
- ``mode="cluster"`` : n genuinely separate executor *processes* joined by
  the TCP wire protocol in ``core.cluster`` -- same runtime semantics as
  local (receiver-side buffering, dynamic matching), plus heartbeat
  failure detection and checkpoint-restart supervision. Closures are
  dispatched as jobs to a persistent warm ``ExecutorPool`` (msg frames
  travel direct executor-to-executor channels, not through the driver).
- ``mode="spmd"``    : one program instance per device of a flat JAX mesh,
  compiled with ``shard_map``; the closure receives a ``PeerComm`` and its
  comm calls lower to ICI collectives. The closure's return values are
  gathered to the driver as a list (paper: "an array of return values from
  each process"), and the jit boundary is the implicit end-of-closure
  barrier the paper describes.

The same closure can run in all three modes when it restricts itself to the
static-routing subset (DESIGN.md section 2), which is how the equivalence
tests pin SPMD semantics to the runtime oracle and the cluster transport
to both.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import compat
from .comm import PeerComm
from .local import ParallelFuncRDD

RANK_AXIS = "ranks"


def flat_mesh(n: int | None = None, devices=None) -> Mesh:
    """A 1-D mesh over the first n devices (paper's flat rank space)."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices) if n is None else n
    if n > len(devices):
        raise ValueError(f"execute({n}) exceeds available devices "
                         f"({len(devices)}); use mode='local' for "
                         "oversubscription")
    return jax.make_mesh((n,), (RANK_AXIS,),
                         devices=np.asarray(devices[:n]))


class ParallelClosure:
    """RDD-of-a-function (paper section 3.2)."""

    def __init__(self, fn: Callable, backend: str = "native",
                 timeout: float = 60.0, segment_bytes: int | None = None,
                 trace: bool | None = None):
        self._fn = fn
        self._backend = backend
        self._timeout = timeout
        # segmented-ring tuning for the message runtimes (local/cluster);
        # None defers to $MPIGNITE_SEGMENT_BYTES. SPMD mode ignores it:
        # PeerComm's ring collectives are already chunked at trace time.
        self._segment_bytes = segment_bytes
        # runtime tracing for the message runtimes; None defers to
        # $MPIGNITE_TRACE. The resulting obs.JobTrace of the most recent
        # traced execute() lands on ``self.last_trace``.
        self._trace = trace
        self.last_trace = None

    def execute(self, n: int | None = None, *, mode: str = "local",
                mesh: Mesh | None = None, jit: bool = True) -> list:
        if mode == "local":
            if n is None:
                raise ValueError("local mode requires an instance count")
            rdd = ParallelFuncRDD(self._fn, timeout=self._timeout,
                                  backend=self._backend,
                                  segment_bytes=self._segment_bytes,
                                  trace=self._trace)
            out = rdd.execute(n)
            self.last_trace = rdd.last_trace
            return out
        if mode == "cluster":
            from .cluster import get_pool
            if n is None:
                raise ValueError("cluster mode requires an instance count")
            # warm path: repeated execute() calls reuse the cached
            # ExecutorPool -- live processes, established peer channels --
            # so only the first call on a given (n, backend) pays fork +
            # connect + address brokering.
            pool = get_pool(n, backend=self._backend)
            out = pool.run(self._fn, backend=self._backend,
                           timeout=self._timeout,
                           segment_bytes=self._segment_bytes,
                           trace=self._trace)
            self.last_trace = pool.last_trace
            return out
        if mode != "spmd":
            raise ValueError(f"unknown mode {mode!r}")
        mesh = mesh if mesh is not None else flat_mesh(n)
        size = mesh.shape[RANK_AXIS]
        comm = PeerComm.world(RANK_AXIS, size, backend=self._backend)

        def body():
            out = self._fn(comm)
            if out is None:
                out = jnp.zeros((), jnp.int32)
            return jax.tree.map(lambda v: jnp.asarray(v)[None], out)

        smapped = compat.shard_map(body, mesh=mesh, in_specs=(),
                                out_specs=P(RANK_AXIS))
        run = jax.jit(smapped) if jit else smapped
        with compat.set_mesh(mesh):
            out = run()
        out = jax.tree.map(np.asarray, out)
        leaves = jax.tree.leaves(out)
        count = leaves[0].shape[0] if leaves else size
        return [jax.tree.map(lambda v: v[i], out) for i in range(count)]


def parallelize_func(fn: Callable, *, backend: str = "native",
                     timeout: float = 60.0,
                     segment_bytes: int | None = None,
                     trace: bool | None = None) -> ParallelClosure:
    """``sc.parallelizeFunc`` analogue. The closure takes the communicator
    as its only argument; other inputs arrive via python closure capture,
    exactly as in the paper's listings. ``segment_bytes`` tunes the
    segmented ring schedules per closure (None = $MPIGNITE_SEGMENT_BYTES,
    <= 0 disables the automatic segmented upgrade); ``trace`` enables
    runtime tracing for the message runtimes (None = $MPIGNITE_TRACE;
    the resulting ``obs.JobTrace`` lands on ``closure.last_trace``)."""
    return ParallelClosure(fn, backend=backend, timeout=timeout,
                           segment_bytes=segment_bytes, trace=trace)


class MPIgniteContext:
    """Small driver-side facade mirroring the SparkContext the listings use
    (``sc.parallelizeFunc(...)``)."""

    def __init__(self, *, default_mode: str = "local",
                 backend: str = "native"):
        self.default_mode = default_mode
        self.backend = backend

    def parallelize_func(self, fn: Callable) -> "_BoundClosure":
        return _BoundClosure(ParallelClosure(fn, backend=self.backend),
                             self.default_mode)

    parallelizeFunc = parallelize_func  # paper spelling


class _BoundClosure:
    def __init__(self, closure: ParallelClosure, mode: str):
        self._closure = closure
        self._mode = mode

    def execute(self, n: int | None = None, **kw) -> list:
        kw.setdefault("mode", self._mode)
        return self._closure.execute(n, **kw)

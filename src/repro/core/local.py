"""LocalComm -- the paper's "local deployment" mode, realized with threads.

MPIgnite runs unmodified Spark locally by sending tasks to worker threads;
messages go through in-process RPC endpoints with receiver-side buffering and
runtime (src, tag, context) matching, and ``receiveAsync`` returns a Scala
Future. This module reproduces those *runtime* semantics exactly -- dynamic
tag matching, arbitrary (any-python-object) payloads, futures, blocking and
non-blocking receive, and MPI_Comm_split performed with actual messages
through the root (as section 3.1 of the paper describes).

All of the matching and collective logic lives in the transport-agnostic
``matching.MessageComm``; this module contributes only the in-process
transport (a shared list of mailboxes) and the thread launcher. The
process-separated twin is ``cluster.ClusterComm`` (TCP frames through the
driver); both run the same closures, which is how the cross-mode
equivalence tests pin one deployment to the other.

It is the executable oracle for the SPMD ``PeerComm`` backends and the
engine behind ``ParallelClosure.execute(n, mode="local")``, which lets the
paper's listings run verbatim on this CPU container with any instance count.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

from .matching import Mailbox, MessageComm, ProgressEngine
from .obs.trace import JobTrace, Tracer, trace_enabled

# Backwards-compatible alias: the mailbox used to live here.
_Mailbox = Mailbox


class _World:
    """Shared state for one execute(): one mailbox (and one nonblocking
    progress engine -- thread started lazily on first use) per world rank.
    With ``trace=True`` each rank also gets an ``obs.Tracer`` wired into
    its mailbox and communicators."""

    def __init__(self, size: int, timeout: float = 30.0,
                 trace: bool = False):
        self.size = size
        self.timeout = timeout
        self.mailboxes = [Mailbox() for _ in range(size)]
        self.engines = [ProgressEngine(name=f"mpignite-progress-r{r}")
                        for r in range(size)]
        self.tracers: list[Tracer | None] = [None] * size
        if trace:
            self.tracers = [Tracer(r, size) for r in range(size)]
            for mb, tr in zip(self.mailboxes, self.tracers):
                mb.tracer = tr

    def close(self) -> None:
        """End-of-execute teardown: fail every leaked request and stop
        the progress threads (merging final runtime gauges into the
        tracers first, while the engines still exist)."""
        for r, (tr, mb, eng) in enumerate(
                zip(self.tracers, self.mailboxes, self.engines)):
            if tr is not None:
                tr.counters.update(
                    {f"mb.{k}": v for k, v in mb.health().items()})
                tr.counters.update(
                    {f"engine.{k}": v for k, v in eng.gauges().items()})
        for eng in self.engines:
            eng.close("world torn down with the request still pending")

    def job_trace(self) -> JobTrace | None:
        if self.tracers[0] is None:
            return None
        return JobTrace.from_tracers(self.tracers)


class LocalComm(MessageComm):
    """The user-facing communicator handed to a parallel closure (paper's
    ``SparkComm``), delivered over in-process mailboxes."""

    def __init__(self, world: _World, group: tuple[int, ...],
                 rank_in_group: int, ctx: int, epoch: tuple = (),
                 backend: str = "linear",
                 segment_bytes: int | None = None):
        super().__init__(group, rank_in_group, ctx, epoch, backend,
                         segment_bytes=segment_bytes)
        self._world = world
        self._obs = world.tracers[group[rank_in_group]]

    # -- transport ----------------------------------------------------------
    def _put(self, world_dst: int, ctx: int, tag: int, src_world: int,
             payload: Any) -> None:
        self._world.mailboxes[world_dst].put(ctx, tag, src_world, payload)

    def _get(self, ctx: int, tag: int, src_world: int) -> Any:
        me = self._group[self._rank]
        return self._world.mailboxes[me].get(ctx, tag, src_world,
                                             self._world.timeout)

    def _clone(self, group: tuple[int, ...], rank_in_group: int, ctx: int,
               epoch: tuple) -> "LocalComm":
        return LocalComm(self._world, group, rank_in_group, ctx, epoch,
                         self._backend, segment_bytes=self._segment_bytes)

    def _async_mailbox(self):
        me = self._group[self._rank]
        return self._world.mailboxes[me], self._world.timeout

    def _progress_engine(self):
        # split()/with_backend() clones share the rank's one engine
        return self._world.engines[self._group[self._rank]]


class ParallelFuncRDD:
    """Return type of ``parallelize_func`` in local mode -- mirrors the
    paper's RDD-of-a-function: ``.execute(n)`` runs n lockstep instances in
    threads and returns the list of per-rank results (the paper: 'an array
    of return values from each process')."""

    def __init__(self, fn: Callable[[LocalComm], Any], timeout: float = 60.0,
                 backend: str = "linear", segment_bytes: int | None = None,
                 trace: bool | None = None):
        self._fn = fn
        self._timeout = timeout
        self._backend = backend
        self._segment_bytes = segment_bytes
        self._trace = trace     # None = follow $MPIGNITE_TRACE
        #: ``obs.JobTrace`` of the most recent traced ``execute`` (None
        #: when tracing was off)
        self.last_trace: Any = None

    def execute(self, n: int) -> list:
        traced = trace_enabled() if self._trace is None else bool(self._trace)
        world = _World(n, timeout=self._timeout, trace=traced)
        results: list[Any] = [None] * n
        errors: list[BaseException | None] = [None] * n

        def run(rank: int):
            comm = LocalComm(world, tuple(range(n)), rank, ctx=0,
                             backend=self._backend,
                             segment_bytes=self._segment_bytes)
            try:
                results[rank] = self._fn(comm)
            except BaseException as e:  # noqa: BLE001
                errors[rank] = e

        threads = [threading.Thread(target=run, args=(r,), daemon=True)
                   for r in range(n)]
        try:
            for t in threads:
                t.start()
            for t in threads:
                t.join(self._timeout)
                if t.is_alive():
                    raise TimeoutError("parallel closure deadlocked "
                                       "(implicit barrier at closure end "
                                       "never reached)")
        finally:
            world.close()       # leaked requests die with the world
            self.last_trace = world.job_trace()
        for e in errors:
            if e is not None:
                raise e
        return results

"""LocalComm -- the paper's "local deployment" mode, realized with threads.

MPIgnite runs unmodified Spark locally by sending tasks to worker threads;
messages go through in-process RPC endpoints with receiver-side buffering and
runtime (src, tag, context) matching, and ``receiveAsync`` returns a Scala
Future. This module reproduces those *runtime* semantics exactly -- dynamic
tag matching, arbitrary (any-python-object) payloads, futures, blocking and
non-blocking receive, and MPI_Comm_split performed with actual messages
through the root (as section 3.1 of the paper describes).

It is the executable oracle for the SPMD ``PeerComm`` backends and the
engine behind ``ParallelClosure.execute(n, mode="local")``, which lets the
paper's listings run verbatim on this CPU container with any instance count.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from . import groups as G


@dataclass
class _Mailbox:
    """Receiver-side buffering: unmatched messages wait here (paper: 'we
    buffer messages on the receiving worker')."""
    lock: threading.Lock = field(default_factory=threading.Lock)
    cond: threading.Condition = None  # type: ignore[assignment]
    msgs: list[tuple[int, int, int, Any]] = field(default_factory=list)
    # each: (ctx, tag, src_world_rank, payload)

    def __post_init__(self):
        self.cond = threading.Condition(self.lock)

    def put(self, ctx: int, tag: int, src: int, payload: Any) -> None:
        with self.lock:
            self.msgs.append((ctx, tag, src, payload))
            self.cond.notify_all()

    def get(self, ctx: int, tag: int, src: int, timeout: float) -> Any:
        def match():
            for i, (c, t, s, _) in enumerate(self.msgs):
                if c == ctx and t == tag and s == src:
                    return i
            return None
        with self.lock:
            i = match()
            while i is None:
                if not self.cond.wait(timeout=timeout):
                    raise TimeoutError(
                        f"receive(src={src}, tag={tag}, ctx={ctx}) timed out")
                i = match()
            return self.msgs.pop(i)[3]


class _World:
    """Shared state for one execute(): mailboxes + collective scratchpads."""

    def __init__(self, size: int, timeout: float = 30.0):
        self.size = size
        self.timeout = timeout
        self.mailboxes = [_Mailbox() for _ in range(size)]
        self._barrier_lock = threading.Lock()
        self._barriers: dict[tuple, threading.Barrier] = {}
        self._scratch: dict[tuple, list] = {}

    def barrier_for(self, key: tuple, parties: int) -> threading.Barrier:
        with self._barrier_lock:
            if key not in self._barriers:
                self._barriers[key] = threading.Barrier(parties)
            return self._barriers[key]

    def scratch_for(self, key: tuple, parties: int) -> list:
        with self._barrier_lock:
            if key not in self._scratch:
                self._scratch[key] = [None] * parties
            return self._scratch[key]


class LocalComm:
    """The user-facing communicator handed to a parallel closure (paper's
    ``SparkComm``). Method names keep the paper's spelling alongside
    pythonic aliases used by the rest of the framework."""

    def __init__(self, world: _World, group: tuple[int, ...], rank_in_group: int,
                 ctx: int, epoch: tuple = ()):
        self._world = world
        self._group = group           # world ranks, ordered by comm rank
        self._rank = rank_in_group
        self._ctx = ctx
        # epoch disambiguates successive collectives on the same communicator
        # (each rank counts its own calls; SPMD => counts agree).
        self._calls = 0
        self._epoch = epoch

    # -- introspection ------------------------------------------------------
    def get_rank(self) -> int:
        return self._rank

    def get_size(self) -> int:
        return len(self._group)

    getRank = property(get_rank)   # paper spelling: world.getRank
    getSize = property(get_size)

    @property
    def context_id(self) -> int:
        return self._ctx

    # -- point to point -----------------------------------------------------
    def send(self, dst: int, tag: int, data: Any) -> None:
        """Always non-blocking (paper: 'sending in MPIgnite is always
        nonblocking'); buffered at the receiver."""
        world_dst = self._group[dst]
        self._world.mailboxes[world_dst].put(
            self._ctx, tag, self._group[self._rank], data)

    def receive(self, src: int, tag: int) -> Any:
        """Blocking receive ~ MPI_Recv."""
        world_src = self._group[src]
        me = self._group[self._rank]
        return self._world.mailboxes[me].get(
            self._ctx, tag, world_src, self._world.timeout)

    def receive_async(self, src: int, tag: int) -> Future:
        """Non-blocking receive ~ MPI_Irecv; returns a Future (Scala Future
        in the paper; ``Await.result`` ~ ``future.result()`` ~ MPI_Wait)."""
        fut: Future = Future()

        def run():
            try:
                fut.set_result(self.receive(src, tag))
            except BaseException as e:  # noqa: BLE001
                fut.set_exception(e)
        threading.Thread(target=run, daemon=True).start()
        return fut

    receiveAsync = receive_async  # paper spelling

    # -- collectives (composed from p2p through the root, exactly the
    #    phase-1 implementation the paper describes) -------------------------
    def _next_key(self) -> tuple:
        self._calls += 1
        return (*self._epoch, self._ctx, self._calls)

    def barrier(self) -> None:
        key = ("bar", *self._next_key())
        self._world.barrier_for(key, len(self._group)).wait(self._world.timeout)

    def broadcast(self, root: int, data: Any = None) -> Any:
        """comm.broadcast[T](root, data): only the root's payload matters."""
        tag = -2  # reserved collective tag space
        key = self._next_key()
        if self._rank == root:
            for r in range(len(self._group)):
                if r != root:
                    self._send_coll(r, tag, key, data)
            return data
        return self._recv_coll(root, tag, key)

    def allreduce(self, data: Any, f: Callable[[Any, Any], Any]) -> Any:
        """comm.allReduce[T](data, f) with an arbitrary reduction function
        (the paper's enhancement over MPI's fixed op set). Phase-1 algorithm:
        gather to rank 0, fold in comm-rank order, broadcast back."""
        tag = -3
        key = self._next_key()
        if self._rank == 0:
            acc = data
            for r in range(1, len(self._group)):
                acc = f(acc, self._recv_coll(r, tag, key))
            for r in range(1, len(self._group)):
                self._send_coll(r, tag, key, acc)
            return acc
        self._send_coll(0, tag, key, data)
        return self._recv_coll(0, tag, key)

    def allgather(self, data: Any) -> list:
        tag = -4
        key = self._next_key()
        if self._rank == 0:
            out = [None] * len(self._group)
            out[0] = data
            for r in range(1, len(self._group)):
                out[r] = self._recv_coll(r, tag, key)
            for r in range(1, len(self._group)):
                self._send_coll(r, tag, key, out)
            return out
        self._send_coll(0, tag, key, data)
        return self._recv_coll(0, tag, key)

    def reducescatter(self, chunks: Sequence[Any], f: Callable) -> Any:
        """Each rank contributes a list of P chunks; rank i gets the f-fold
        of everyone's chunk i."""
        if len(chunks) != len(self._group):
            raise ValueError("reducescatter needs one chunk per rank")
        gathered = self.allgather(list(chunks))
        mine = gathered[0][self._rank]
        for contrib in gathered[1:]:
            mine = f(mine, contrib[self._rank])
        return mine

    def reduce(self, root: int, data: Any, f: Callable[[Any, Any], Any]) -> Any:
        """MPI_Reduce: fold everyone's data at ``root`` (None elsewhere).
        One of the 'more methods' the paper's section 6 plans."""
        tag = -7
        key = self._next_key()
        if self._rank == root:
            acc = data
            for r in range(len(self._group)):
                if r != root:
                    acc = f(acc, self._recv_coll(r, tag, key))
            return acc
        self._send_coll(root, tag, key, data)
        return None

    def gather(self, root: int, data: Any) -> list | None:
        """MPI_Gather: rank-ordered list at ``root`` (None elsewhere)."""
        tag = -8
        key = self._next_key()
        if self._rank == root:
            out = [None] * len(self._group)
            out[root] = data
            for r in range(len(self._group)):
                if r != root:
                    out[r] = self._recv_coll(r, tag, key)
            return out
        self._send_coll(root, tag, key, data)
        return None

    def scan(self, data: Any, f: Callable[[Any, Any], Any]) -> Any:
        """MPI_Scan: inclusive prefix reduction -- rank r receives
        f(x_0, ..., x_r). Linear chain through the ranks."""
        tag = -9
        key = self._next_key()
        if self._rank == 0:
            acc = data
        else:
            acc = f(self._recv_coll(self._rank - 1, tag, key), data)
        if self._rank + 1 < len(self._group):
            self._send_coll(self._rank + 1, tag, key, acc)
        return acc

    def alltoall(self, chunks: Sequence[Any]) -> list:
        if len(chunks) != len(self._group):
            raise ValueError("alltoall needs one chunk per rank")
        tag = -5
        key = self._next_key()
        for r in range(len(self._group)):
            if r != self._rank:
                self._send_coll(r, tag, key, chunks[r])
        out = [None] * len(self._group)
        out[self._rank] = chunks[self._rank]
        for r in range(len(self._group)):
            if r != self._rank:
                out[r] = self._recv_coll(r, tag, key)
        return out

    def _send_coll(self, dst: int, tag: int, key: tuple, data: Any) -> None:
        world_dst = self._group[dst]
        self._world.mailboxes[world_dst].put(
            hash((self._ctx, tag, key)), tag, self._group[self._rank], data)

    def _recv_coll(self, src: int, tag: int, key: tuple) -> Any:
        me = self._group[self._rank]
        return self._world.mailboxes[me].get(
            hash((self._ctx, tag, key)), tag, self._group[src],
            self._world.timeout)

    # -- split (paper section 3.1: ranks send (global rank, key, color) to the
    #    lowest participating rank; it groups by color, sorts by key, and
    #    broadcasts the new rank mapping) ------------------------------------
    def split(self, color: int, key: int) -> "LocalComm":
        tag = -6
        ckey = self._next_key()
        root = 0
        if self._rank == root:
            triples = [(self._rank, key, color)]
            for r in range(1, len(self._group)):
                triples.append(self._recv_coll(r, tag, ckey))
            colors = {}
            for r, k, c in triples:
                colors.setdefault(c, []).append((k, r))
            mapping = {}
            for c, members in colors.items():
                members.sort()
                mapping[c] = tuple(r for _, r in members)
            for r in range(1, len(self._group)):
                self._send_coll(r, tag, ckey, mapping)
        else:
            self._send_coll(root, tag, ckey, (self._rank, key, color))
            mapping = self._recv_coll(root, tag, ckey)
        my_group_parent_ranks = mapping[color]
        new_group = tuple(self._group[r] for r in my_group_parent_ranks)
        new_rank = my_group_parent_ranks.index(self._rank)
        new_ctx = G.context_id((tuple(sorted(new_group)),), self._ctx) ^ hash(
            ("split", *ckey, color)) & 0xFFFFFFFF
        return LocalComm(self._world, new_group, new_rank, new_ctx,
                         epoch=(*self._epoch, "s", self._calls, color))


class ParallelFuncRDD:
    """Return type of ``parallelize_func`` in local mode -- mirrors the
    paper's RDD-of-a-function: ``.execute(n)`` runs n lockstep instances in
    threads and returns the list of per-rank results (the paper: 'an array
    of return values from each process')."""

    def __init__(self, fn: Callable[[LocalComm], Any], timeout: float = 60.0):
        self._fn = fn
        self._timeout = timeout

    def execute(self, n: int) -> list:
        world = _World(n, timeout=self._timeout)
        results: list[Any] = [None] * n
        errors: list[BaseException | None] = [None] * n

        def run(rank: int):
            comm = LocalComm(world, tuple(range(n)), rank, ctx=0)
            try:
                results[rank] = self._fn(comm)
            except BaseException as e:  # noqa: BLE001
                errors[rank] = e

        threads = [threading.Thread(target=run, args=(r,), daemon=True)
                   for r in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(self._timeout)
            if t.is_alive():
                raise TimeoutError("parallel closure deadlocked (implicit "
                                   "barrier at closure end never reached)")
        for e in errors:
            if e is not None:
                raise e
        return results

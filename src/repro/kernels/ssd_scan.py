"""Mamba-2 SSD chunked scan for TPU (pl.pallas_call + BlockSpec tiling).

TPU adaptation of the GPU SSD kernel (DESIGN.md section 7): the warp-level
scan becomes the matmul block decomposition -- per (batch, head) the
sequence is walked chunk by chunk on the innermost grid dimension; the
(P x N) inter-chunk state lives in VMEM scratch and persists across
chunks, while all intra-chunk work (decay matrix, C B^T scores, local
outputs) is dense (Q x Q)/(Q x N)/(Q x P) matmuls shaped for the MXU
(Q=128, N=64, P=64 for zamba2-2.7b).

Grid: (B, H, S/Q), chunk index innermost. Inputs arrive pre-discretized
exactly like models.ssm.ssd_chunked: x (B,S,H,P), dt (B,S,H) (softplus
applied), a_log (H,), Bm/Cm (B,S,N) (groups already broadcast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_ref, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)            # (Q,)
    a_h = -jnp.exp(alog_ref[0].astype(jnp.float32))     # scalar
    bm = b_ref[0].astype(jnp.float32)                   # (Q, N)
    cm = c_ref[0].astype(jnp.float32)                   # (Q, N)

    a = dt * a_h                                        # (Q,) log-decays
    cum = jnp.cumsum(a)                                 # inclusive
    xdt = x * dt[:, None]                               # (Q, P)

    # ---- intra-chunk (lower-triangular decay kernel) ----
    seg = cum[:, None] - cum[None, :]                   # l[i,j]=sum(j+1..i)
    Q = chunk
    tri = lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)               # (Q, Q)
    scores = lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = lax.dot_general(L * scores, xdt, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)

    # ---- inter-chunk contribution from carried state (N, P) ----
    cdecay = jnp.exp(cum)[:, None]                      # (Q, 1)
    y += cdecay * lax.dot_general(cm, state_ref[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # ---- state update to chunk end ----
    total = cum[-1]
    w = jnp.exp(total - cum)[:, None] * bm              # (Q, N)
    state_ref[...] = state_ref[...] * jnp.exp(total) + lax.dot_general(
        w, xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, Bm, Cm, *, chunk: int = 128,
             interpret: bool = False):
    """Returns (y, final_state (B,H,P,N)) matching models.ssm.ssd_chunked.
    Final state is recomputed by the XLA path when needed (prefill); the
    kernel emits y only (training hot path)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, "sequence must divide into SSD chunks"
    grid = (B, H, S // Q)

    y = pl.pallas_call(
        functools.partial(_kernel, chunk=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, Bm, Cm)
    return y

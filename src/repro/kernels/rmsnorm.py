"""Fused RMSNorm for TPU (row-tiled, feature-resident).

Memory-bound op: fusing the square-mean, rsqrt and scale into one pass
saves two HBM round-trips per block boundary. Rows are tiled (block_rows
x d) with the feature dimension resident in VMEM; fp32 statistics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    inv = lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * inv * w_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., d); w: (d,)."""
    shp = x.shape
    d = shp[-1]
    rows = 1
    for s in shp[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = -rows % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((rows + pad) // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(shp)

"""Pure-jnp oracles for every Pallas kernel (the reference each kernel's
shape/dtype sweep asserts against, and the source of custom_vjp backward
rules where the backward kernel is not hand-written)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """q: (B,Sq,Hq,D); k/v: (B,Sk,Hkv,D), Hq = gq*Hkv. fp32 softmax."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    gq = Hq // Hkv
    if gq > 1:
        k = jnp.repeat(k, gq, axis=2)
        v = jnp.repeat(v, gq, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * D ** -0.5,
                   k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)   # fully-masked rows
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_ref(x, dt, a_log, Bm, Cm):
    """Sequential (exact) SSD recurrence. x: (B,S,H,P); dt: (B,S,H);
    a_log: (H,); Bm/Cm: (B,S,N). Returns (y, final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    A = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp                     # (B,H,P),(B,H),(B,N)x2
        dec = jnp.exp(dt_t * A[None, :])              # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
        state = state * dec[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y_t

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state


def rmsnorm_ref(x, w, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * w.astype(jnp.float32)).astype(dt)

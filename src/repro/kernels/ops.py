"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the Pallas
body runs in Python for correctness validation); on TPU the same calls
compile to Mosaic. ``INTERPRET`` flips automatically.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash
from .rmsnorm import rmsnorm as _rmsnorm
from .ssd_scan import ssd_scan as _ssd

INTERPRET = jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128):
    return _flash(q, k, v, causal, window, q_offset, block_q, block_k,
                  INTERPRET)


def ssd_scan(x, dt, a_log, Bm, Cm, *, chunk: int = 128):
    """Training hot path: y only (prefill, which also needs the final
    state, uses the XLA chunk decomposition -- see models.ssm)."""
    return _ssd(x, dt, a_log, Bm, Cm, chunk=chunk, interpret=INTERPRET)


def rmsnorm(x, w, *, eps: float = 1e-5):
    return _rmsnorm(x, w, eps=eps, interpret=INTERPRET)

"""Flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Online-softmax attention with q/kv block tiling; causal, sliding-window
and bidirectional masking; GQA served *without materializing* repeated KV
heads -- the kv BlockSpec index_map divides the head index by the group
size, so each q-head block streams its kv head straight from HBM.

Grid: (B, Hq, Sq/bq, Sk/bk), kv innermost. The (acc, m, l) running
softmax state lives in VMEM scratch and persists across the innermost
grid dimension (standard TPU flash pattern: initialize at j==0, finalize
at j==last). Block sizes default to 128x128 (MXU-aligned); D is kept
whole per block (<= 256 for all assigned archs).

Backward is recompute-based via custom_vjp against the jnp oracle
(DESIGN.md: the training path's bwd FLOPs come from the XLA blockwise
implementation; the kernel targets the serving/prefill hot loop).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_q: int, block_k: int, sk: int, causal: bool,
            window: int, q_offset: int, scale: float):
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(2)
    q_pos = q_offset + i * block_q + lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # skip fully-masked kv blocks (causal upper triangle / outside window)
    q_last = q_offset + i * block_q + block_q - 1
    q_first = q_offset + i * block_q
    needed = jnp.bool_(True)
    if causal:
        needed &= (j * block_k) <= q_last
    if window:
        needed &= (j * block_k + block_k) > (q_first - window)

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < sk
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q: (B,Sq,Hq,D); k/v: (B,Sk,Hkv,D) with Hq % Hkv == 0."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    gq = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad sequences to block multiples
    pq = -Sq % block_q
    pk = -Sk % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    # layout: (B, H, S, D) blocks
    qp = qp.transpose(0, 2, 1, 3)
    kp = kp.transpose(0, 2, 1, 3)
    vp = vp.transpose(0, 2, 1, 3)
    grid = (B, Hq, (Sq + pq) // block_q, (Sk + pk) // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          sk=Sk, causal=causal, window=window,
                          q_offset=q_offset, scale=1.0 / math.sqrt(D)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, gq=gq: (b, h // gq, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, gq=gq: (b, h // gq, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq + pq, D), q.dtype),
        # (acc, m, l) running-softmax state: VMEM scratch persisting
        # across the innermost (kv) grid dimension
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :Sq] if pq else out


# ---------------------------------------------------------------------------
# custom_vjp: forward = kernel, backward = recompute via the jnp oracle
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal=True, window=0, q_offset=0,
                    block_q=128, block_k=128, interpret=False):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def _fwd(q, k, v, causal, window, q_offset, block_q, block_k, interpret):
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return out, (q, k, v)


def _bwd(causal, window, q_offset, block_q, block_k, interpret, res, g):
    from . import ref
    q, k, v = res
    def f(q, k, v):
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)

"""Attention cores (XLA path) + dispatch to the Pallas kernel (TPU path).

Shapes follow the local-shard contract: q is (B, Sq, Hq, D), k/v are
(B, Sk, Hkv, D) where Hq = gq * Hkv (GQA slots after layout padding --
see models.common.gqa_layout). All cores use online-softmax accumulation
in fp32 and never materialize an (Sq, Sk) matrix larger than one block row.

Three cores:
- ``attn_kv_scan``  : scan over KV blocks, full Sq resident. causal/bidir.
- ``attn_swa``      : scan over Q blocks; each gathers its KV window slice
                      (FLOPs scale with S*window, not S^2).
- ``attn_decode``   : single-query against a (ring-buffered) cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _expand_kv(k, gq: int):
    """(B, S, Hkv, D) -> (B, S, Hkv*gq, D) by repeating each kv head gq x."""
    if gq == 1:
        return k
    return jnp.repeat(k, gq, axis=2)


def attention(q, k, v, *, causal: bool, window: int = 0, q_offset=0,
              impl: str = "xla", block_q: int = 512, block_k: int = 512):
    """Unified entry. q_offset: absolute position of q[0] (chunked prefill)."""
    gq = q.shape[2] // k.shape[2]
    if impl == "pallas":
        from ..kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset)
    k = _expand_kv(k, gq)
    v = _expand_kv(v, gq)
    if window and q.shape[1] > 1:
        return attn_swa(q, k, v, window=window, q_offset=q_offset,
                        block_q=block_q)
    if q.shape[1] == 1:
        return attn_decode(q, k, v, kv_len=k.shape[1], causal=causal,
                           q_pos=q_offset)
    return attn_kv_scan(q, k, v, causal=causal, q_offset=q_offset,
                        block_k=block_k)


def attn_kv_scan(q, k, v, *, causal: bool, q_offset=0, block_k: int = 512):
    """Online-softmax over KV blocks. q: (B,Sq,H,D), k/v: (B,Sk,H,D)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    block_k = min(block_k, Sk)
    n_blk = -(-Sk // block_k)
    pad = n_blk * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = D ** -0.5
    qf = (q * scale).astype(q.dtype)
    q_pos = q_offset + jnp.arange(Sq)

    kb = k.reshape(B, n_blk, block_k, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blk, block_k, H, D).transpose(1, 0, 2, 3, 4)

    def step(carry, blk):
        acc, m, l = carry
        kc, vc, i = blk
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kc,
                       preferred_element_type=jnp.float32)
        k_pos = i * block_k + jnp.arange(block_k)
        mask = k_pos[None, :] < Sk
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = lax.scan(step, (acc0, m0, l0),
                              (kb, vb, jnp.arange(n_blk)))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attn_swa(q, k, v, *, window: int, q_offset=0, block_q: int = 512):
    """Sliding-window attention: scan over Q blocks; each q block attends to
    the KV slice [start, start + window + block_q) where start is clamped --
    compute is O(Sq * (window + block_q)) regardless of Sk."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, Sq)
    assert Sq % block_q == 0, "Sq must divide into q blocks"
    n_blk = Sq // block_q
    span = min(window + block_q, Sk)
    scale = D ** -0.5

    qb = (q * scale).reshape(B, n_blk, block_q, H, D).transpose(1, 0, 2, 3, 4)

    def step(_, blk):
        qc, i = blk
        q_start = q_offset + i * block_q
        start = jnp.clip(q_start + block_q - span, 0, Sk - span)
        kc = lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vc = lax.dynamic_slice_in_dim(v, start, span, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc,
                       preferred_element_type=jnp.float32)
        q_pos = q_start + jnp.arange(block_q)
        k_pos = start + jnp.arange(span)
        mask = (k_pos[None, :] <= q_pos[:, None]) & \
               (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhqk,bkhd->bqhd", (p / jnp.maximum(l, 1e-30)
                                           ).astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    _, out = lax.scan(step, None, (qb, jnp.arange(n_blk)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)


def attn_decode(q, k, v, *, kv_len, causal: bool = True, q_pos=None):
    """q: (B,1,Hq,D) against cache k/v: (B,Smax,Hkv,D), Hq = gq*Hkv.
    GQA is served by a grouped einsum -- the KV cache is *not* repeated
    (a materialized repeat doubles decode HBM traffic, the dominant term
    of the decode roofline). ``kv_len`` may be per-batch (B,)."""
    B, _, Hq, D = q.shape
    Smax, Hkv = k.shape[1], k.shape[2]
    gq = Hq // Hkv
    qg = (q[:, 0] * D ** -0.5).reshape(B, Hkv, gq, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(Smax)
    if jnp.ndim(kv_len) == 0:
        valid = pos[None, :] < kv_len
    else:
        valid = pos[None, :] < kv_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def attn_cross(q, k, v):
    """Dense bidirectional cross-attention (image tokens are few)."""
    D = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q * D ** -0.5, k,
                   preferred_element_type=jnp.float32)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)

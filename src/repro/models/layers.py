"""Elementary layers: norms, RoPE, vocab-parallel embedding & cross-entropy.

All functions take ``ops`` (ShardOps | GlobalOps) and obey the shape
contract of repro.parallel.ops: tensors are local shards on the mpignite
path and global arrays on the gspmd path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..parallel import axes as A
from ..parallel.ops import Ops


def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope_angles(positions, dh_rot: int, theta: float):
    """positions: int32 (...,); returns cos/sin of shape (..., dh_rot//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dh_rot, 2, dtype=jnp.float32) / dh_rot))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rope_pct: float = 1.0):
    """x: (B, S, H, D); cos/sin: (S, d_rot/2) or (B, S, d_rot/2)."""
    d = x.shape[-1]
    d_rot = int(d * rope_pct) // 2 * 2
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = jnp.split(xr, 2, axis=-1)
    if cos.ndim == 2:   # (S, d_rot/2) -> broadcast over batch & heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:               # (B, S, d_rot/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), xp], -1)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding / logits / cross-entropy (Megatron-style).
# The embedding table is (V_pad, d) sharded P(model, data); on the mpignite
# path each shard embeds only tokens inside its vocab slice, followed by a
# model-axis psum (fused into the sequence-parallel scatter when SP is on).
# ---------------------------------------------------------------------------

def embed(ops: Ops, table, tokens, v_pad: int, combine: str = "psum"):
    """tokens: (B, S) int32 -> (B, S, d) with table FSDP dim gathered.
    combine="none" returns the *partial* (vocab-shard-masked) embedding so
    the caller can fuse the model-axis reduction into a reduce-scatter
    (sequence-parallel entry)."""
    w = ops.weight(table, P(A.MODEL_AXIS, A.DATA_AXIS))   # (V_loc, d)
    v_loc = w.shape[0]
    if v_loc == v_pad:                                     # global path / tp=1
        return jnp.take(w, tokens, axis=0)
    start = ops.tp_index() * v_loc
    local = tokens - start
    inside = (local >= 0) & (local < v_loc)
    local = jnp.clip(local, 0, v_loc - 1)
    out = jnp.take(w, local, axis=0)
    out = jnp.where(inside[..., None], out, jnp.zeros_like(out))
    return out if combine == "none" else ops.tp_psum(out)


def logits_and_xent(ops: Ops, head_w, x, labels, valid, v_pad: int, vocab: int):
    """Fused LM head + cross-entropy, numerically stable, vocab-parallel.

    x: (..., d) activations (full d); head_w: (d, V_pad) sharded col-parallel;
    labels: int32 (...,); valid: bool/float mask (...,).
    Returns (sum_nll, n_valid) -- both *local* to this shard's batch slice;
    callers finish with dp reductions.
    """
    w = ops.weight(head_w, P(A.DATA_AXIS, A.MODEL_AXIS))   # (d, V_loc)
    v_loc = w.shape[1]
    logits = (x @ w).astype(jnp.float32)                   # (..., V_loc)
    start = ops.tp_index() * v_loc
    # mask padded vocab entries (only the last shard can own them)
    col = start + jnp.arange(v_loc)
    logits = jnp.where(col < vocab, logits, -jnp.inf)

    m_loc = jnp.max(logits, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m_loc), m_loc, jnp.finfo(jnp.float32).min)
    # the stabilizer is gradient-free (standard softmax trick) -- and pmax
    # has no AD rule, so stop_gradient is also required for correctness
    m_glob = _tp_max(ops, lax.stop_gradient(m_safe))
    z = jnp.sum(jnp.exp(logits - m_glob[..., None]), axis=-1)
    z = ops.tp_psum(z)
    lse = jnp.log(z) + m_glob

    lab_local = labels - start
    inside = (lab_local >= 0) & (lab_local < v_loc)
    lab_safe = jnp.clip(lab_local, 0, v_loc - 1)
    picked = jnp.take_along_axis(logits, lab_safe[..., None], axis=-1)[..., 0]
    picked = jnp.where(inside, picked, 0.0)
    picked = ops.tp_psum(picked)

    nll = (lse - picked) * valid.astype(jnp.float32)
    return jnp.sum(nll), jnp.sum(valid.astype(jnp.float32))


def _tp_max(ops: Ops, x):
    if ops.tp <= 1:
        return x
    # PeerComm supports arbitrary reductions (the paper's allReduce(data, f));
    # native backend fast-paths to lax.pmax.
    if hasattr(ops, "comm_model"):
        return ops.comm_model.allreduce(x, "max")
    return x  # GlobalOps: logits are global already


def logits_only(ops: Ops, head_w, x, v_pad: int, vocab: int):
    """Full (gathered) logits for decode steps: (..., vocab)."""
    w = ops.weight(head_w, P(A.DATA_AXIS, A.MODEL_AXIS))
    logits = (x @ w).astype(jnp.float32)
    logits = ops.tp_all_gather(logits, dim=logits.ndim - 1)
    return logits[..., :vocab]

from .common import ModelConfig, gqa_layout
from .model import Model

__all__ = ["ModelConfig", "Model", "gqa_layout"]

"""Shared model-configuration & parameter machinery for all 10 architectures.

One ``ModelConfig`` covers the dense / MoE / hybrid-SSM / xLSTM / VLM / audio
families; per-arch files in ``repro/configs`` fill it in. Parameters are
described by ``ParamSpec`` (global padded shape + PartitionSpec + init rule),
from which each distribution path derives what it needs: GSPMD shardings,
shard_map in_specs, local shard shapes, and dry-run ShapeDtypeStructs.

GQA head layout under TP
------------------------
Query heads are padded *per KV group* so that (a) every model shard holds an
equal number of heads and (b) each query head's KV head lives on the same
shard (no cross-shard attention reductions). KV heads are replicated to
``kv_eff = replicated_kv_heads(kv, tp)``; each effective KV head serves
``gq = ceil(n_q / kv_eff)`` query-head slots, of which the trailing ones may
be padding (zero-initialized, zero-masked). See ``gqa_layout``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..parallel import axes as A


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                       # dense | moe | hybrid | xlstm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # --- attention ---
    head_dim: int = 0               # 0 => d_model // n_heads
    causal: bool = True             # False => encoder-only (hubert)
    window: int = 0                 # sliding-window size; 0 => full attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0           # fraction of head_dim that is rotated
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0               # per-expert hidden size
    dense_residual: bool = False    # arctic: dense FFN in parallel with MoE
    first_dense_layers: int = 0     # deepseek: leading dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- hybrid (zamba2-style Mamba2 + shared attention) ---
    ssm_state: int = 0              # N (d_state)
    ssm_head_dim: int = 64          # P (head dim of SSD)
    ssm_expand: int = 2
    ssm_chunk: int = 128
    attn_every: int = 0             # one shared attn+MLP block per this many layers
    # --- xLSTM ---
    slstm_every: int = 0            # every k-th layer is sLSTM (0 => none)
    proj_factor: float = 2.0        # mLSTM up-projection factor
    # --- VLM ---
    cross_attn_every: int = 0       # a cross-attn layer per this many layers
    n_image_tokens: int = 0
    vision_d: int = 0
    # --- frontend ---
    input_mode: str = "tokens"      # tokens | frames (precomputed embeddings stub)
    # --- misc ---
    act: str = "swiglu"             # swiglu | gelu
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    attn_impl: str = "xla"          # xla | pallas
    long_context_ok: bool = False   # may run the long_500k shape
    init_std: float = 0.02

    # ---- derived ----
    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def validate(self) -> "ModelConfig":
        if self.kind == "moe":
            assert self.n_experts > 0 and self.top_k > 0 and self.moe_d_ff > 0
        if self.kind == "hybrid":
            assert self.ssm_state > 0 and self.attn_every > 0
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        return self


@dataclasses.dataclass(frozen=True)
class GQALayout:
    """Head bookkeeping under a given TP degree (see module docstring)."""
    n_q: int            # true query heads
    n_kv: int           # true KV heads
    n_q_pad: int        # stored query-head slots (multiple of tp)
    kv_eff: int         # stored KV heads incl. replication (multiple of tp)
    gq: int             # query-head slots per effective KV head
    rep: int            # replication factor kv_eff / ceil-padded kv

    def q_real_mask(self) -> np.ndarray:
        """(n_q_pad,) bool -- which stored query-head slots are real."""
        gq0 = self.n_q // self.n_kv           # true q heads per true kv head
        mask = np.zeros(self.n_q_pad, bool)
        for j in range(self.kv_eff):          # effective kv head j
            orig = j // self.rep
            if orig >= self.n_kv:
                continue                      # padded kv head: all slots dead
            start_in_group = (j % self.rep) * self.gq
            n_real = min(max(gq0 - start_in_group, 0), self.gq)
            mask[j * self.gq:j * self.gq + n_real] = True
        return mask

    def kv_source(self) -> np.ndarray:
        """(kv_eff,) -> original kv head index feeding each stored head
        (padded kv heads point at head 0 but their q slots are dead)."""
        return np.minimum(np.arange(self.kv_eff) // self.rep, self.n_kv - 1)


def gqa_layout(n_q: int, n_kv: int, tp: int) -> GQALayout:
    kv_eff = A.replicated_kv_heads(n_kv, tp)
    rep = max(kv_eff // n_kv, 1) if n_kv < kv_eff else 1
    # when n_kv >= tp, kv_eff == pad_to(n_kv, tp) and rep == 1
    if n_kv >= tp:
        rep = 1
    gq = max(math.ceil(n_q / kv_eff), 1)
    n_q_pad = kv_eff * gq
    assert n_q_pad % tp == 0 and kv_eff % tp == 0
    return GQALayout(n_q, n_kv, n_q_pad, kv_eff, gq, rep)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    pspec: P = P()
    init: str = "normal"      # normal | zeros | ones | scaled
    fan_in: int = 0           # for init == "scaled": std = init_std/sqrt(2L)
    col_mask: np.ndarray | None = None  # zero-mask applied to the last dim
    row_mask: np.ndarray | None = None  # zero-mask applied to dim -2
    dtype: Any = None         # None => the model compute dtype

    def instantiate(self, key, std: float, dtype) -> jax.Array:
        dtype = self.dtype or dtype
        if self.init == "zeros":
            w = jnp.zeros(self.shape, dtype)
        elif self.init == "ones":
            w = jnp.ones(self.shape, dtype)
        else:
            s = std if self.init == "normal" else std / math.sqrt(
                2.0 * max(self.fan_in, 1))
            w = (jax.random.normal(key, self.shape, jnp.float32) * s
                 ).astype(dtype)
        if self.col_mask is not None:
            w = w * jnp.asarray(self.col_mask, dtype)
        if self.row_mask is not None:
            m = jnp.asarray(self.row_mask, dtype)
            w = w * m[..., :, None]
        return w


def head_mask(layout: GQALayout, dh: int) -> np.ndarray:
    """(n_q_pad*dh,) column mask zeroing padded query-head slots."""
    return np.repeat(layout.q_real_mask(), dh).astype(np.float32)


def tree_instantiate(specs, key, std: float, dtype):
    """Materialize a full (global) parameter pytree from ParamSpecs."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [s.instantiate(k, std, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def tree_pspecs(specs):
    return jax.tree.map(lambda s: s.pspec, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_shapes(specs, axes: A.MeshAxes | None = None, dtype=jnp.bfloat16):
    """ShapeDtypeStructs (global shapes) for dry-run lowering; if ``axes`` is
    given, shapes are validated to shard evenly."""
    def leaf(s: ParamSpec):
        if axes is not None:
            A.local_shape(s.shape, s.pspec, axes)  # raises if indivisible
        return jax.ShapeDtypeStruct(s.shape, s.dtype or dtype)
    return jax.tree.map(leaf, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_local_shapes(specs, axes: A.MeshAxes):
    return jax.tree.map(
        lambda s: A.local_shape(s.shape, s.pspec, axes), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


# Convenience constructors -----------------------------------------------------

def dense_col(d_in: int, d_out: int, *, mask=None) -> ParamSpec:
    """Column-parallel weight (out dim sharded over model, FSDP on in dim)."""
    return ParamSpec((d_in, d_out), P(A.DATA_AXIS, A.MODEL_AXIS),
                     col_mask=mask)


def dense_row(d_in: int, d_out: int, *, fan_in: int = 0, mask=None) -> ParamSpec:
    """Row-parallel weight (in dim sharded over model, FSDP on out dim)."""
    return ParamSpec((d_in, d_out), P(A.MODEL_AXIS, A.DATA_AXIS),
                     init="scaled" if fan_in else "normal", fan_in=fan_in,
                     row_mask=mask)


def replicated(*shape, init="ones") -> ParamSpec:
    return ParamSpec(tuple(shape), P(), init=init)


def stacked(n: int, spec: ParamSpec) -> ParamSpec:
    """Prepend an unsharded layer dimension for lax.scan stacking."""
    return dataclasses.replace(
        spec, shape=(n,) + spec.shape, pspec=P(None, *spec.pspec))

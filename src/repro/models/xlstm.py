"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory) + sequential sLSTM.

TPU adaptation notes (DESIGN.md section Arch-applicability): with only 4
heads, head-sharding over a 16-way model axis is degenerate, so the xLSTM
mixers are *replicated* over `model` (FSDP over `data` still applies) -- the
model axis is idle inside these blocks. The mLSTM uses the same
chunk-decomposition trick as SSD: intra-chunk work is dense matmuls with a
log-space stabilized decay matrix; only (C, n, m) state crosses chunks.

mLSTM recurrence (per head): C_t = f_t C_{t-1} + i_t k_t v_t^T,
n_t = f_t n_{t-1} + i_t k_t, h_t = (q_t C_t) / max(|q_t n_t|, e^{-m_t}),
with running stabilizer m_t; states are stored pre-scaled by e^{-m_t}.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..parallel import axes as A
from ..parallel.ops import Ops
from .common import ModelConfig, ParamSpec
from .layers import rmsnorm

NEG = -1e30


def _headnorm(x, w, eps):
    """Per-head RMS norm: x (..., H, Dv), w (H*Dv,)."""
    shp = x.shape
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x.reshape(*shp[:-2], -1) * w).astype(dt).reshape(shp)


def mlstm_chunked(q, k, v, ilog, flog, chunk: int, state=None):
    """q,k,v: (B,S,H,D); ilog/flog: (B,S,H) log input/forget gates.
    Returns h: (B,S,H,D) and final (C, n, m) state.
    state: optional (C (B,H,D,D), n (B,H,D), m (B,H)) to resume from."""
    B, S, H, D = q.shape
    Q = min(chunk, S)
    pad = -S % Q
    S_orig = S
    if pad:
        # pad tail with ilog=-inf (no input) and flog=0 (no decay): the
        # padded steps leave (C, n, m) untouched and emit discarded rows.
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                               [(0, 0)] * (t.ndim - 2))
        q, k, v, flog = zp(q), zp(k), zp(v), zp(flog)
        ilog = jnp.pad(ilog, ((0, 0), (0, pad), (0, 0)),
                       constant_values=NEG)
        S = S + pad
    nc = S // Q
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    qc = qf.reshape(B, nc, Q, H, D)
    kc = kf.reshape(B, nc, Q, H, D)
    vc = vf.reshape(B, nc, Q, H, D)
    ic = ilog.astype(jnp.float32).reshape(B, nc, Q, H)
    fc = flog.astype(jnp.float32).reshape(B, nc, Q, H)

    b = jnp.cumsum(fc, axis=2)                     # (B,nc,Q,H) within-chunk
    total = b[:, :, -1, :]                         # (B,nc,H)

    # intra-chunk log weights d[q,j] = b_q - b_j + ilog_j   (j <= q)
    dmat = (b[:, :, :, None, :] - b[:, :, None, :, :]
            + ic[:, :, None, :, :])                # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    dmat = jnp.where(tri[None, None, :, :, None], dmat, NEG)
    m_intra = jnp.max(dmat, axis=3)                # (B,nc,Q,H)

    # end-of-chunk state weights g_j = total - b_j + ilog_j
    g = total[:, :, None, :] - b + ic              # (B,nc,Q,H)
    g_max = jnp.max(g, axis=2)                     # (B,nc,H)

    def chunk_step(carry, inp):
        C, n, m = carry                            # (B,H,D,D),(B,H,D),(B,H)
        qk, kk, vk, bk, tot, dk, mi, gk, gm = inp
        # per-position stabilizer: inter term uses b_q + m_prev
        m_pos = jnp.maximum(bk + m[:, None, :], mi)        # (B,Q,H)
        inter_w = jnp.exp(bk + m[:, None, :] - m_pos)      # (B,Q,H)
        dstab = jnp.exp(dk - m_pos[:, :, None, :])         # (B,Q,Q,H)
        s = jnp.einsum("bqhd,bjhd->bqjh", qk, kk)          # (B,Q,Q,H)
        num = jnp.einsum("bqjh,bqjh,bjhd->bqhd", s, dstab, vk)
        num = num + inter_w[..., None] * jnp.einsum("bqhd,bhde->bqhe", qk, C)
        den = jnp.einsum("bqjh,bqjh->bqh", s, dstab)
        den = den + inter_w * jnp.einsum("bqhd,bhd->bqh", qk, n)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_pos))
        h = num / den[..., None]                           # (B,Q,H,D)
        # state update to chunk end
        m_new = jnp.maximum(tot + m, gm)                   # (B,H)
        cdec = jnp.exp(tot + m - m_new)
        gw = jnp.exp(gk - m_new[:, None, :])               # (B,Q,H)
        C = C * cdec[..., None, None] + jnp.einsum(
            "bjhd,bjh,bjhe->bhde", kk, gw, vk)
        n = n * cdec[..., None] + jnp.einsum("bjhd,bjh->bhd", kk, gw)
        return (C, n, m_new), h

    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), 0.0, jnp.float32)
    else:
        C0, n0, m0 = state
    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), b.transpose(1, 0, 2, 3),
          total.transpose(1, 0, 2), dmat.transpose(1, 0, 2, 3, 4),
          m_intra.transpose(1, 0, 2, 3), g.transpose(1, 0, 2, 3),
          g_max.transpose(1, 0, 2))
    (C, n, m), hs = lax.scan(chunk_step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)[:, :S_orig]
    return h.astype(q.dtype), (C, n, m)


def mlstm_decode_step(state, q, k, v, ilog, flog):
    """One token. q,k,v: (B,H,D); ilog/flog: (B,H). state: (C,n,m)."""
    C, n, m = state
    D = q.shape[-1]
    qf = q.astype(jnp.float32) / math.sqrt(D)
    m_new = jnp.maximum(flog + m, ilog)
    fdec = jnp.exp(flog + m - m_new)
    iw = jnp.exp(ilog - m_new)
    C = C * fdec[..., None, None] + iw[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    n = n * fdec[..., None] + iw[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_new))
    return (num / den[..., None]).astype(q.dtype), (C, n, m_new)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def mlstm_param_specs(cfg: ModelConfig):
    d = cfg.d_model
    d_in = int(cfg.proj_factor * d)
    H = cfg.n_heads
    K = 4
    fsdp = lambda *s: ParamSpec(s, P(A.DATA_AXIS, *([None] * (len(s) - 1))))
    return {
        "w_up": fsdp(d, 2 * d_in),
        "conv": ParamSpec((K, d_in), P()),
        "w_q": fsdp(d_in, d_in),
        "w_k": fsdp(d_in, d_in),
        "w_v": fsdp(d_in, d_in),
        "w_if": fsdp(d_in, 2 * H),
        "if_bias": ParamSpec((2 * H,), P(), init="zeros"),
        "gn": ParamSpec((d_in,), P(), init="ones"),
        "w_down": fsdp(d_in, d),
    }


def mlstm_block(ops: Ops, p, x, cfg: ModelConfig, cache=None,
                mode: str = "train"):
    """x: (B,S,d). Returns (y, new_cache). Mixer replicated over model."""
    from .ssm import _causal_conv, _tail_pad
    B, S, d = x.shape
    d_in = int(cfg.proj_factor * d)
    H = cfg.n_heads
    D = d_in // H
    up = x @ ops.weight(p["w_up"], P(A.DATA_AXIS, None))
    left, right = jnp.split(up, 2, axis=-1)
    if mode == "decode":
        lc, conv_cache = _causal_conv(left, p["conv"], cache["conv"])
    else:
        lc = _causal_conv(left, p["conv"])
        conv_cache = _tail_pad(left, p["conv"].shape[0] - 1)
    lc = jax.nn.silu(lc)
    q = (lc @ ops.weight(p["w_q"], P(A.DATA_AXIS, None))).reshape(B, S, H, D)
    k = (lc @ ops.weight(p["w_k"], P(A.DATA_AXIS, None))).reshape(B, S, H, D)
    v = (left @ ops.weight(p["w_v"], P(A.DATA_AXIS, None))).reshape(B, S, H, D)
    gates = lc @ ops.weight(p["w_if"], P(A.DATA_AXIS, None)) + p["if_bias"]
    ilog, flog = jnp.split(gates.astype(jnp.float32), 2, axis=-1)  # (B,S,H)
    flog = jax.nn.log_sigmoid(flog)
    if mode == "decode":
        h_t, st = mlstm_decode_step(
            (cache["C"], cache["n"], cache["m"]),
            q[:, 0], k[:, 0], v[:, 0], ilog[:, 0], flog[:, 0])
        h = h_t[:, None]
        new_cache = {"conv": conv_cache, "C": st[0], "n": st[1], "m": st[2]}
    else:
        h, st = mlstm_chunked(q, k, v, ilog, flog, chunk=cfg.ssm_chunk or 64)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": conv_cache, "C": st[0], "n": st[1],
                         "m": st[2]}
    h = _headnorm(h, p["gn"], cfg.norm_eps)
    h = h.reshape(B, S, d_in) * jax.nn.silu(right)
    return h @ ops.weight(p["w_down"], P(A.DATA_AXIS, None)), new_cache


def slstm_param_specs(cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    fsdp = lambda *s: ParamSpec(s, P(A.DATA_AXIS, *([None] * (len(s) - 1))))
    return {
        "w": fsdp(d, 4 * d),
        "r": ParamSpec((H, dh, 4 * dh), P()),
        "bias": ParamSpec((4 * d,), P(), init="zeros"),
        "gn": ParamSpec((d,), P(), init="ones"),
    }


def slstm_block(ops: Ops, p, x, cfg: ModelConfig, cache=None,
                mode: str = "train"):
    """Sequential sLSTM. x: (B,S,d). cache: (c,n,h,m) each (B,H,dh)."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    w = ops.weight(p["w"], P(A.DATA_AXIS, None))
    pre_all = x @ w + p["bias"]                       # (B,S,4d)
    r = p["r"]                                        # (H, dh, 4dh)

    def cell(carry, pre_t):
        c, n, h, m = carry                            # (B,H,dh) x3, (B,H)
        rec = jnp.einsum("bhd,hde->bhe", h, r)        # (B,H,4dh)
        z = pre_t.reshape(B, H, 4 * dh) + rec
        zi, zf, zz, zo = jnp.split(z.astype(jnp.float32), 4, axis=-1)
        ilog = jnp.mean(zi, -1)                       # scalar gates per head
        flog = jax.nn.log_sigmoid(jnp.mean(zf, -1))
        m_new = jnp.maximum(flog + m, ilog)
        c = c * jnp.exp(flog + m - m_new)[..., None] + \
            jnp.exp(ilog - m_new)[..., None] * jnp.tanh(zz)
        n = n * jnp.exp(flog + m - m_new)[..., None] + \
            jnp.exp(ilog - m_new)[..., None]
        h_new = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1e-6)
        return (c, n, h_new, m_new), h_new

    if mode == "decode":
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        c0 = jnp.zeros((B, H, dh), jnp.float32)
        carry = (c0, c0, jnp.zeros((B, H, dh), jnp.float32),
                 jnp.zeros((B, H), jnp.float32))
    carry, hs = lax.scan(cell, carry, pre_all.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    y = rmsnorm(y, p["gn"], cfg.norm_eps)
    new_cache = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return y, (new_cache if mode != "train" else None)


def mlstm_cache_specs(cfg: ModelConfig, batch: int, bspec=A.DATA_AXIS):
    d_in = int(cfg.proj_factor * cfg.d_model)
    H = cfg.n_heads
    D = d_in // H
    z = lambda *s: ParamSpec(s, P(bspec, *([None] * (len(s) - 1))),
                             init="zeros", dtype=jnp.float32)
    zb = lambda *s: ParamSpec(s, P(bspec, *([None] * (len(s) - 1))),
                              init="zeros")
    return {"conv": zb(batch, 3, d_in), "C": z(batch, H, D, D),
            "n": z(batch, H, D), "m": z(batch, H)}


def slstm_cache_specs(cfg: ModelConfig, batch: int, bspec=A.DATA_AXIS):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = lambda *s: ParamSpec(s, P(bspec, *([None] * (len(s) - 1))),
                             init="zeros", dtype=jnp.float32)
    return {"c": z(batch, H, dh), "n": z(batch, H, dh),
            "h": z(batch, H, dh), "m": z(batch, H)}

"""Unified layer stack: dense / MoE / hybrid-SSM / xLSTM / VLM / encoder.

An architecture is compiled into a list of ``Segment``s; each segment is a
homogeneous run of layers whose stacked parameters are swept with
``lax.scan`` (keeping HLO size and 512-way SPMD compile time bounded).
Heterogeneous interleavings (zamba2's shared attention every 6 Mamba
layers, llama-vision's cross-attention every 5th layer, xLSTM's sLSTM
positions) become *grouped* segments: outer scan over groups, inner scan
over the group's homogeneous run, with the odd block applied per group.

Sequence-parallel layout: between blocks, activations are (B, S_loc, d)
(sharded over `model`); norms act per-token on shards; attention gathers
the sequence (``seq_unshard``), output projections reduce-scatter back
(``seq_shard``). All communication goes through ``Ops`` -> ``PeerComm``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..parallel import axes as A
from ..parallel.ops import Ops, ShardOps
from . import attention as ATT
from . import moe as MOE
from . import ssm as SSM
from . import xlstm as XL
from .common import (GQALayout, ModelConfig, ParamSpec, dense_col, dense_row,
                     head_mask, replicated, stacked)
from .layers import apply_rope, rmsnorm


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    kind: str          # attn_mlp | attn_moe | zamba_group | mlstm | slstm | vlm_group
    count: int         # outer scan length
    inner: int = 1     # homogeneous layers per group (grouped kinds)


def build_schedule(cfg: ModelConfig) -> list[Segment]:
    L = cfg.n_layers
    if cfg.kind == "hybrid":
        groups = L // cfg.attn_every
        assert groups * cfg.attn_every == L
        return [Segment("seg0", "zamba_group", groups, inner=cfg.attn_every)]
    if cfg.kind == "xlstm":
        pos_s = {k for k in range(L)
                 if cfg.slstm_every and (k + 1) % cfg.slstm_every == 0}
        out: list[Segment] = []
        start = 0
        for k in range(L + 1):
            if k == L or k in pos_s:
                if k > start:
                    out.append(Segment(f"seg{len(out)}", "mlstm", k - start))
                if k < L:
                    out.append(Segment(f"seg{len(out)}", "slstm", 1))
                start = k + 1
        return out
    if cfg.cross_attn_every:
        inner = cfg.cross_attn_every - 1
        groups = L // cfg.cross_attn_every
        assert groups * cfg.cross_attn_every == L
        return [Segment("seg0", "vlm_group", groups, inner=inner)]
    if cfg.kind == "moe":
        segs = []
        if cfg.first_dense_layers:
            segs.append(Segment("seg0", "attn_mlp", cfg.first_dense_layers))
        segs.append(Segment(f"seg{len(segs)}", "attn_moe",
                            L - cfg.first_dense_layers))
        return segs
    return [Segment("seg0", "attn_mlp", L)]


# ---------------------------------------------------------------------------
# Per-kind parameter specs (single layer; caller stacks)
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig, layout: GQALayout) -> dict:
    d, dh = cfg.d_model, cfg.dh
    qm = head_mask(layout, dh)
    sp = {
        "ln1": replicated(d),
        "wq": dense_col(d, layout.n_q_pad * dh, mask=qm),
        "wk": dense_col(d, layout.kv_eff * dh),
        "wv": dense_col(d, layout.kv_eff * dh),
        "wo": dense_row(layout.n_q_pad * dh, d, fan_in=cfg.n_layers,
                        mask=layout.q_real_mask().repeat(dh)),
    }
    if cfg.qk_norm:
        sp["q_norm"] = replicated(dh)
        sp["k_norm"] = replicated(dh)
    return sp


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    sp = {"ln2": replicated(d),
          "w_up": dense_col(d, f),
          "w_down": dense_row(f, d, fan_in=cfg.n_layers)}
    if cfg.act == "swiglu":
        sp["w_gate"] = dense_col(d, f)
    return sp


def layer_specs(cfg: ModelConfig, layout: GQALayout, kind: str) -> dict:
    if kind == "attn_mlp":
        return {**attn_specs(cfg, layout), **mlp_specs(cfg)}
    if kind == "attn_moe":
        sp = {**attn_specs(cfg, layout), "ln2": replicated(cfg.d_model)}
        sp["moe"] = MOE.moe_param_specs(cfg)
        pd = cfg.n_shared_experts * cfg.moe_d_ff
        if cfg.dense_residual:
            pd = cfg.d_ff
        if pd:
            m = mlp_specs(cfg, pd)
            m.pop("ln2")
            sp["par"] = m
        return sp
    if kind == "mamba":
        return {"ln1": replicated(cfg.d_model),
                **SSM.mamba2_param_specs(cfg, 0)}
    if kind == "mlstm":
        return {"ln1": replicated(cfg.d_model), **XL.mlstm_param_specs(cfg)}
    if kind == "slstm":
        return {"ln1": replicated(cfg.d_model), **XL.slstm_param_specs(cfg)}
    if kind == "cross_attn":
        d, dh = cfg.d_model, cfg.dh
        qm = head_mask(layout, dh)
        return {"ln": replicated(d),
                "wq": dense_col(d, layout.n_q_pad * dh, mask=qm),
                "wk": dense_col(d, layout.kv_eff * dh),
                "wv": dense_col(d, layout.kv_eff * dh),
                "wo": dense_row(layout.n_q_pad * dh, d, fan_in=cfg.n_layers,
                                mask=layout.q_real_mask().repeat(dh)),
                "gate": ParamSpec((), P(), init="zeros"),
                **mlp_specs(cfg)}
    raise ValueError(kind)


def _stack_tree(n: int, tree):
    return jax.tree.map(lambda s: stacked(n, s), tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def segment_specs(cfg: ModelConfig, layout: GQALayout, seg: Segment):
    if seg.kind == "zamba_group":
        return _stack_tree(seg.count, _stack_tree(
            seg.inner, layer_specs(cfg, layout, "mamba")))
    if seg.kind == "vlm_group":
        return {"self": _stack_tree(seg.count, _stack_tree(
                    seg.inner, layer_specs(cfg, layout, "attn_mlp"))),
                "cross": _stack_tree(seg.count,
                                     layer_specs(cfg, layout, "cross_attn"))}
    return _stack_tree(seg.count, layer_specs(cfg, layout, seg.kind))


# ---------------------------------------------------------------------------
# Blocks (forward)
# ---------------------------------------------------------------------------

def _qkv(ops: Ops, p, hf, cfg: ModelConfig, rope, pos=None, prefix=""):
    """hf: (B,S,d) full-seq -> q (B,S,nq_l,dh), k,v (B,S,kv_l,dh)."""
    B, S, d = hf.shape
    dh = cfg.dh
    q = hf @ ops.weight(p[prefix + "wq"], P(A.DATA_AXIS, A.MODEL_AXIS))
    k = hf @ ops.weight(p[prefix + "wk"], P(A.DATA_AXIS, A.MODEL_AXIS))
    v = hf @ ops.weight(p[prefix + "wv"], P(A.DATA_AXIS, A.MODEL_AXIS))
    q = q.reshape(B, S, -1, dh)
    k = k.reshape(B, S, -1, dh)
    v = v.reshape(B, S, -1, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin, cfg.rope_pct)
        k = apply_rope(k, cos, sin, cfg.rope_pct)
    return q, k, v


def _mlp(ops: Ops, p, hf, cfg: ModelConfig):
    wu = ops.weight(p["w_up"], P(A.DATA_AXIS, A.MODEL_AXIS))
    u = hf @ wu
    if cfg.act == "swiglu":
        g = hf @ ops.weight(p["w_gate"], P(A.DATA_AXIS, A.MODEL_AXIS))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(u)
    return h @ ops.weight(p["w_down"], P(A.MODEL_AXIS, A.DATA_AXIS))


def block_attn(ops: Ops, p, x, cfg: ModelConfig, rope, cache=None, pos=None,
               mode: str = "train", s_max: int = 0):
    """Self-attention sub-block. x: (B,S_loc,d) sharded / (B,S,d)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    hf = ops.seq_unshard(h)
    q, k, v = _qkv(ops, p, hf, cfg, rope)
    if mode == "decode":
        o, new_cache = _cached_attn(q, k, v, cfg, cache, pos)
    else:
        o = ATT.attention(q, k, v, causal=cfg.causal, window=cfg.window,
                          impl=cfg.attn_impl)
        new_cache = (_prefill_cache(k, v, cfg, s_max)
                     if mode == "prefill" else None)
    B, S = hf.shape[:2]
    o = o.reshape(B, S, -1)
    o = o @ ops.weight(p["wo"], P(A.MODEL_AXIS, A.DATA_AXIS))
    return x + ops.seq_shard(o), new_cache


def _prefill_cache(k, v, cfg: ModelConfig, s_max: int):
    """Lay out prefill K/V for decode: ring buffer of `window` slots for
    SWA (slot = abs_pos % window), else right-padded to s_max."""
    B, S = k.shape[:2]
    if cfg.window:
        W = min(cfg.window, s_max) if s_max else cfg.window
        idx = jnp.arange(W) + max(S - W, 0)        # last W absolute positions
        idx = jnp.minimum(idx, S - 1)
        kc = jnp.zeros((B, W) + k.shape[2:], k.dtype)
        kc = kc.at[:, idx % W].set(k[:, idx])
        vc = jnp.zeros((B, W) + v.shape[2:], v.dtype)
        vc = vc.at[:, idx % W].set(v[:, idx])
        return {"k": kc, "v": vc}
    pad = ((0, 0), (0, s_max - S), (0, 0), (0, 0))
    return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}


def _cached_attn(q, k, v, cfg: ModelConfig, cache, pos):
    """Decode-mode attention against a (ring) cache. q/k/v: (B,1,h,dh);
    cache: {k,v: (B,Smax,kv_l,dh)}; pos: (B,) absolute positions."""
    B = q.shape[0]
    Smax = cache["k"].shape[1]
    slot = pos % Smax if cfg.window else jnp.minimum(pos, Smax - 1)
    bidx = jnp.arange(B)
    kc = cache["k"].at[bidx, slot].set(k[:, 0])
    vc = cache["v"].at[bidx, slot].set(v[:, 0])
    kv_len = jnp.minimum(pos + 1, Smax)
    o = ATT.attn_decode(q, kc, vc, kv_len=kv_len)   # grouped: no KV repeat
    return o, {"k": kc, "v": vc}


def block_mlp(ops: Ops, p, x, cfg: ModelConfig):
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    hf = ops.seq_unshard(h)
    return x + ops.seq_shard(_mlp(ops, p, hf, cfg))


def block_moe(ops: Ops, p, x, cfg: ModelConfig):
    """MoE sub-block (+ optional parallel dense branch). Returns (x, aux).

    Token layout cases (mpignite path): sequence-parallel training hands
    each model shard its own token slice (all-to-all dispatch); without SP
    we slice the replicated sequence when it divides tp, else (decode:
    S=1) fall back to replicated dispatch + local experts + psum."""
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    shard = isinstance(ops, ShardOps) and ops.tp > 1
    sliced = False
    h_tok = h
    if shard and not ops.pcfg.sequence_parallel:
        Bs, Ss, d = h.shape
        if Ss % ops.tp == 0:
            s_loc = Ss // ops.tp
            h_tok = lax.dynamic_slice_in_dim(h, ops.tp_index() * s_loc,
                                             s_loc, 1)
            sliced = True
    replicated = shard and not ops.pcfg.sequence_parallel and not sliced
    Bh, Sh, d = h_tok.shape
    routed, aux = MOE.moe_ffn(ops, p["moe"], h_tok.reshape(-1, d), cfg,
                              tokens_replicated=replicated)
    routed = routed.reshape(Bh, Sh, d)
    if sliced:
        routed = ops.tp_all_gather(routed, dim=1)
    out = routed
    if "par" in p:
        hf = ops.seq_unshard(h)
        out = out + ops.seq_shard(_mlp(ops, p["par"], hf, cfg))
    return x + out, aux


def block_mamba(ops: Ops, p, x, cfg: ModelConfig, cache=None,
                mode: str = "train"):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    hf = ops.seq_unshard(h)
    y, new_cache = SSM.mamba2_mixer(ops, p, hf, cfg, cache, mode)
    return x + ops.seq_shard(y), new_cache


def block_mlstm(ops: Ops, p, x, cfg: ModelConfig, cache=None,
                mode: str = "train"):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    hf = ops.seq_unshard(h)
    y, new_cache = XL.mlstm_block(ops, p, hf, cfg, cache, mode)
    return x + ops.seq_slice(y), new_cache


def block_slstm(ops: Ops, p, x, cfg: ModelConfig, cache=None,
                mode: str = "train"):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    hf = ops.seq_unshard(h)
    y, new_cache = XL.slstm_block(ops, p, hf, cfg, cache, mode)
    return x + ops.seq_slice(y), new_cache


def cross_kv(ops: Ops, p, img, cfg: ModelConfig):
    """Project image embeddings to this cross layer's K/V: (B,n_img,kv_l,dh)."""
    B, T = img.shape[:2]
    dh = cfg.dh
    ik = (img @ ops.weight(p["wk"], P(A.DATA_AXIS, A.MODEL_AXIS))
          ).reshape(B, T, -1, dh)
    iv = (img @ ops.weight(p["wv"], P(A.DATA_AXIS, A.MODEL_AXIS))
          ).reshape(B, T, -1, dh)
    return ik, iv


def block_cross(ops: Ops, p, x, cfg: ModelConfig, img=None, cache=None,
                mode: str = "train"):
    """Cross-attention + MLP (llama-vision style, tanh-gated).
    ``img``: (B, n_img, d) projected image embeddings (train/prefill);
    decode reads K/V from ``cache``."""
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    hf = ops.seq_unshard(h)
    B, S, d = hf.shape
    dh = cfg.dh
    q = (hf @ ops.weight(p["wq"], P(A.DATA_AXIS, A.MODEL_AXIS))
         ).reshape(B, S, -1, dh)
    if mode == "decode":
        ik, iv = cache["ik"], cache["iv"]
    else:
        ik, iv = cross_kv(ops, p, img, cfg)
    gq = q.shape[2] // ik.shape[2]
    o = ATT.attn_cross(q, jnp.repeat(ik, gq, 2) if gq > 1 else ik,
                       jnp.repeat(iv, gq, 2) if gq > 1 else iv)
    o = o.reshape(B, S, -1) @ ops.weight(p["wo"], P(A.MODEL_AXIS, A.DATA_AXIS))
    x = x + jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * \
        ops.seq_shard(o)
    x = block_mlp(ops, p, x, cfg)
    new_cache = {"ik": ik, "iv": iv} if mode != "train" else None
    return x, new_cache

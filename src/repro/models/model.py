"""Model facade: specs/init + loss / prefill / decode over the segment
schedule, for any of the 10 architectures, on either distribution path.

Everything that must agree between the training step, the serving steps,
the dry-run lowering and the checkpointer (shapes, PartitionSpecs, layer
schedule, cache layout) is derived from this one class.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.comm import cost_scope
from ..parallel import axes as A
from ..parallel.ops import Ops, ParallelConfig, ShardOps, remat_wrap
from . import transformer as T
from .common import (ModelConfig, ParamSpec, gqa_layout, replicated, stacked,
                     tree_instantiate, tree_pspecs, tree_shapes)
from .layers import embed, logits_and_xent, logits_only, rmsnorm, rope_angles
from .ssm import mamba2_cache_specs
from .xlstm import mlstm_cache_specs, slstm_cache_specs


def _strip_axis(specs, axis_name: str):
    def leaf(s: ParamSpec):
        entries = []
        for e in s.pspec:
            if isinstance(e, tuple):
                e = tuple(n for n in e if n != axis_name) or None
                if e is not None and len(e) == 1:
                    e = e[0]
            elif e == axis_name:
                e = None
            entries.append(e)
        return dataclasses.replace(s, pspec=P(*entries))
    return jax.tree.map(leaf, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


class Model:
    def __init__(self, cfg: ModelConfig, axes: A.MeshAxes,
                 pcfg: ParallelConfig):
        self.cfg = cfg.validate()
        self.axes = axes
        self.pcfg = pcfg
        self.layout = gqa_layout(cfg.n_heads, max(cfg.n_kv_heads, 1),
                                 axes.model)
        self.v_pad = A.padded_vocab(cfg.vocab, axes.model)
        self.schedule = T.build_schedule(cfg)
        self.specs = self._build_specs()
        if not pcfg.fsdp:
            # resident-weight layout (serving): strip the FSDP (`data`)
            # axis from every parameter spec -- weights replicate across
            # data rows and are never re-gathered per step.
            self.specs = _strip_axis(self.specs, A.DATA_AXIS)
        self.pspecs = tree_pspecs(self.specs)

    # ------------------------------------------------------------------ specs
    def _build_specs(self):
        cfg, lay = self.cfg, self.layout
        d = cfg.d_model
        blocks = {seg.name: T.segment_specs(cfg, lay, seg)
                  for seg in self.schedule}
        if cfg.kind == "hybrid":   # zamba2 shared attention + MLP block
            blocks["shared"] = {**T.attn_specs(cfg, lay),
                                **T.mlp_specs(cfg)}
        sp: dict[str, Any] = {"blocks": blocks, "final_norm": replicated(d)}
        if cfg.input_mode == "tokens":
            sp["embed"] = ParamSpec((self.v_pad, d),
                                    P(A.MODEL_AXIS, A.DATA_AXIS))
        else:                      # audio frames stub frontend projector
            sp["frontend"] = ParamSpec((d, d), P(A.DATA_AXIS, None))
        if cfg.cross_attn_every:
            sp["img_proj"] = ParamSpec((cfg.vision_d, d),
                                       P(A.DATA_AXIS, None))
            sp["embed"] = ParamSpec((self.v_pad, d),
                                    P(A.MODEL_AXIS, A.DATA_AXIS))
        sp["head"] = ParamSpec((d, self.v_pad), P(A.DATA_AXIS, A.MODEL_AXIS))
        return sp

    def init(self, key, dtype=None):
        return tree_instantiate(self.specs, key, self.cfg.init_std,
                                dtype or self.cfg.dtype)

    def param_shapes(self, dtype=None):
        return tree_shapes(self.specs, self.axes, dtype or self.cfg.dtype)

    # -------------------------------------------------------------- counting
    def n_params(self, active_only: bool = False) -> int:
        """Total (or per-token-active) parameter count, *excluding* head
        padding and KV replication waste (i.e. the 'useful' N in 6ND)."""
        cfg, lay = self.cfg, self.layout
        total = 0
        leaves, _ = jax.tree_util.tree_flatten_with_path(
            self.specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        qfrac = lay.n_q / lay.n_q_pad
        kvfrac = cfg.n_kv_heads / lay.kv_eff if cfg.n_kv_heads else 1.0
        shared_mult = (cfg.n_layers // cfg.attn_every
                       if cfg.kind == "hybrid" else 1)
        for path, spec in leaves:
            keys = [str(getattr(k, "key", k)) for k in path]
            name = keys[-1]
            n = float(np.prod(spec.shape))
            if name in ("wq", "wo"):
                n *= qfrac
            elif name in ("wk", "wv") and "moe" not in keys:
                n *= kvfrac
            if name == "embed":
                n = cfg.vocab * cfg.d_model
                if active_only:
                    n = 0.0        # table gather, not matmul FLOPs
            elif name == "head":
                n = cfg.d_model * cfg.vocab
            if active_only and "moe" in keys and name in ("wg", "wu", "wd"):
                n *= cfg.top_k / cfg.n_experts
            if active_only and "shared" in keys:
                n *= shared_mult   # zamba2 shared block applied per group
            total += n
        return int(total)

    def model_flops(self, n_tokens: int, train: bool = True) -> float:
        """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference)."""
        mult = 6.0 if train else 2.0
        return mult * self.n_params(active_only=True) * n_tokens

    # --------------------------------------------------------------- forward
    def _embed_in(self, ops: Ops, params, batch):
        cfg = self.cfg
        img = None
        if cfg.input_mode == "frames":
            w = ops.weight(params["frontend"], P(A.DATA_AXIS, None))
            x = batch["frames"].astype(cfg.dtype) @ w
            x = ops.seq_slice(x)
        else:
            x = embed(ops, params["embed"], batch["tokens"], self.v_pad,
                      combine="none")
            x = ops.seq_shard(x)
        if cfg.cross_attn_every and "image_emb" in batch:
            wi = ops.weight(params["img_proj"], P(A.DATA_AXIS, None))
            img = batch["image_emb"].astype(cfg.dtype) @ wi
        return x, img

    def _rope(self, positions):
        cfg = self.cfg
        d_rot = int(cfg.dh * cfg.rope_pct) // 2 * 2
        if d_rot == 0:
            return None
        return rope_angles(positions, d_rot, cfg.rope_theta)

    def forward(self, ops: Ops, params, x, rope, img, mode: str,
                caches=None, pos=None, s_max: int = 0):
        """Run all segments. Returns (x, aux_sum, new_caches)."""
        aux_total = jnp.float32(0.0)
        new_caches = {}
        for seg in self.schedule:
            c = None if caches is None else caches[seg.name]
            x, aux, nc = self._run_seg(ops, seg, params, x, rope, img,
                                       mode, c, pos, s_max)
            aux_total = aux_total + aux
            new_caches[seg.name] = nc
        return x, aux_total, new_caches

    def _run_seg(self, ops: Ops, seg, params, x, rope, img, mode,
                 cache, pos, s_max):
        cfg = self.cfg
        p_seg = params["blocks"][seg.name]

        if seg.kind in ("attn_mlp", "attn_moe"):
            def body(xc, inp):
                p, c = inp
                xc, kvc = T.block_attn(ops, p, xc, cfg, rope, cache=c,
                                       pos=pos, mode=mode, s_max=s_max)
                if seg.kind == "attn_moe":
                    xc, aux = T.block_moe(ops, p, xc, cfg)
                else:
                    xc = T.block_mlp(ops, p, xc, cfg)
                    aux = jnp.float32(0.0)
                return xc, ((kvc if kvc is not None else {}), aux)
            return self._scan(body, x, p_seg, cache, seg.count, mode)

        if seg.kind == "zamba_group":
            shared_p = params["blocks"]["shared"]

            def body(xc, inp):
                p, c = inp
                mc = None if c is None else c["mamba"]

                def inner(xi, iinp):
                    pi, ci = iinp
                    xi, mcache = T.block_mamba(ops, pi, xi, cfg, ci, mode)
                    return xi, (mcache if mcache is not None else {})
                xc, mcaches = self._scan_inner(inner, xc, p, mc, seg.inner,
                                               mode)
                xc, kvc = T.block_attn(ops, shared_p, xc, cfg, rope,
                                       cache=None if c is None
                                       else c["shared"],
                                       pos=pos, mode=mode, s_max=s_max)
                xc = T.block_mlp(ops, shared_p, xc, cfg)
                nc = {"mamba": mcaches,
                      "shared": kvc if kvc is not None else {}}
                return xc, (nc, jnp.float32(0.0))
            return self._scan(body, x, p_seg, cache, seg.count, mode,
                              grouped=True)

        if seg.kind == "vlm_group":
            def body(xc, inp):
                p, c = inp
                sc = None if c is None else c["self"]

                def inner(xi, iinp):
                    pi, ci = iinp
                    xi, kvc = T.block_attn(ops, pi, xi, cfg, rope, cache=ci,
                                           pos=pos, mode=mode, s_max=s_max)
                    xi = T.block_mlp(ops, pi, xi, cfg)
                    return xi, (kvc if kvc is not None else {})
                xc, scaches = self._scan_inner(inner, xc, p["self"], sc,
                                               seg.inner, mode)
                xc, ccache = T.block_cross(ops, p["cross"], xc, cfg, img,
                                           None if c is None else c["cross"],
                                           mode)
                nc = {"self": scaches,
                      "cross": ccache if ccache is not None else {}}
                return xc, (nc, jnp.float32(0.0))
            return self._scan(body, x, p_seg, cache, seg.count, mode,
                              grouped=True)

        if seg.kind in ("mlstm", "slstm"):
            blk = T.block_mlstm if seg.kind == "mlstm" else T.block_slstm

            def body(xc, inp):
                p, c = inp
                xc, sc = blk(ops, p, xc, cfg, c, mode)
                return xc, ((sc if sc is not None else {}), jnp.float32(0.0))
            return self._scan(body, x, p_seg, cache, seg.count, mode)

        raise ValueError(seg.kind)

    def _scan(self, body, x, p_seg, cache, count, mode, grouped=False):
        """Outer layer scan: body(x, (p_slice, cache_slice)) ->
        (x, (cache_out, aux))."""
        if mode == "train" and self.pcfg.remat != "none":
            body = remat_wrap(body, self.pcfg.remat)
        if cache is None:
            # feed a dummy None-free structure: replicate body signature
            def wrapped(c, p):
                return body(c, (p, None))
            with cost_scope(count):
                x, (caches, auxs) = lax.scan(wrapped, x, p_seg)
        else:
            with cost_scope(count):
                x, (caches, auxs) = lax.scan(body, x, (p_seg, cache))
        return x, jnp.sum(auxs), (caches if mode != "train" else None)

    def _scan_inner(self, inner, x, p_inner, cache_inner, count, mode):
        if mode == "train" and self.pcfg.remat != "none":
            inner = remat_wrap(inner, self.pcfg.remat)
        if cache_inner is None:
            def wrapped(c, p):
                return inner(c, (p, None))
            with cost_scope(count):
                x, caches = lax.scan(wrapped, x, p_inner)
        else:
            with cost_scope(count):
                x, caches = lax.scan(inner, x, (p_inner, cache_inner))
        return x, caches

    # ------------------------------------------------------------------ loss
    def loss(self, ops: Ops, params, batch):
        """Training objective. Returns (scalar_loss, metrics). The scalar is
        the *global-mean* objective from this shard's perspective; gradient
        correctness across shards is completed by ops.sync_grads."""
        cfg = self.cfg
        x, img = self._embed_in(ops, params, batch)
        if cfg.input_mode == "frames":
            S = batch["frames"].shape[1]
        else:
            S = batch["tokens"].shape[1]
        rope = self._rope(jnp.arange(S))
        x, aux, _ = self.forward(ops, params, x, rope, img, "train")
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        xf = ops.seq_unshard(x)                       # (B, S, d)

        if cfg.is_encoder:
            hidden, labels = xf, batch["labels"]
        else:
            hidden = xf[:, :-1]
            labels = batch["tokens"][:, 1:]
        valid = jnp.ones(labels.shape, jnp.float32)
        nll_sum, n_valid = logits_and_xent(ops, params["head"], hidden,
                                           labels, valid, self.v_pad,
                                           cfg.vocab)
        is_shard = isinstance(ops, ShardOps)
        shards = ops.dp * ops.tp if is_shard else 1
        # shard_map reverse-AD seeds every device's loss copy: the
        # differentiated objective is the SUM over all dp*tp program
        # instances (psum transposes to psum). Scaling by 1/(dp*tp) makes
        # that sum the global mean -- verified grad-identical to the
        # gspmd path in tests/_dist_checks.py.
        loss = nll_sum / (n_valid * shards)
        if cfg.kind == "moe":
            loss = loss + cfg.router_aux_coef * aux / shards
        metrics = {"nll_sum": nll_sum, "n_valid": n_valid, "aux": aux}
        return loss, metrics

    # --------------------------------------------------------------- serving
    def prefill(self, ops: Ops, params, batch, s_max: int):
        """Forward + cache build. Returns (last_token_logits, caches)."""
        cfg = self.cfg
        x, img = self._embed_in(ops, params, batch)
        S = (batch["tokens"] if cfg.input_mode == "tokens"
             else batch["frames"]).shape[1]
        rope = self._rope(jnp.arange(S))
        x, _, caches = self.forward(ops, params, x, rope, img, "prefill",
                                    s_max=s_max)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        xf = ops.seq_unshard(x)
        logits = logits_only(ops, params["head"], xf[:, -1:], self.v_pad,
                             cfg.vocab)
        return logits[:, 0], caches

    def decode(self, ops: Ops, params, caches, tokens, pos):
        """One decode step. tokens: (B, 1) int32; pos: (B,) absolute
        positions of these tokens. Returns (logits (B, vocab), caches)."""
        cfg = self.cfg
        x, _ = self._embed_in(ops, params, {"tokens": tokens})
        rope = self._rope(pos[:, None])               # (B,1,d_rot/2)
        x, _, new_caches = self.forward(ops, params, x, rope, None,
                                        "decode", caches=caches, pos=pos)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = logits_only(ops, params["head"], x, self.v_pad, cfg.vocab)
        return logits[:, 0], new_caches

    # ----------------------------------------------------------- cache specs
    def cache_specs(self, batch: int, s_max: int):
        """ParamSpec pytree describing the decode cache."""
        cfg, lay = self.cfg, self.layout
        dh = cfg.dh
        bsp = self._bspec(batch)
        s_kv = min(cfg.window, s_max) if cfg.window else s_max

        def kv(count):
            shp = (count, batch, s_kv, lay.kv_eff, dh)
            return {"k": ParamSpec(shp, P(None, bsp, None, A.MODEL_AXIS,
                                          None), init="zeros"),
                    "v": ParamSpec(shp, P(None, bsp, None, A.MODEL_AXIS,
                                          None), init="zeros")}

        out = {}
        for seg in self.schedule:
            if seg.kind in ("attn_mlp", "attn_moe"):
                out[seg.name] = kv(seg.count)
            elif seg.kind == "zamba_group":
                mc = mamba2_cache_specs(cfg, batch, self.axes.model,
                                        bspec=bsp)
                mc = {k: stacked(seg.count, stacked(seg.inner, v))
                      for k, v in mc.items()}
                shp = (seg.count, batch, s_max, lay.kv_eff, dh)
                out[seg.name] = {
                    "mamba": mc,
                    "shared": {"k": ParamSpec(shp, P(None, bsp, None,
                                                     A.MODEL_AXIS, None),
                                              init="zeros"),
                               "v": ParamSpec(shp, P(None, bsp, None,
                                                     A.MODEL_AXIS, None),
                                              init="zeros")}}
            elif seg.kind == "vlm_group":
                ishp = (seg.count, batch, cfg.n_image_tokens, lay.kv_eff, dh)
                sshp = (seg.count, seg.inner, batch, s_kv, lay.kv_eff, dh)
                out[seg.name] = {
                    "self": {"k": ParamSpec(sshp, P(None, None, bsp, None,
                                                    A.MODEL_AXIS, None),
                                            init="zeros"),
                             "v": ParamSpec(sshp, P(None, None, bsp, None,
                                                    A.MODEL_AXIS, None),
                                            init="zeros")},
                    "cross": {"ik": ParamSpec(ishp, P(None, bsp, None,
                                                      A.MODEL_AXIS, None),
                                              init="zeros"),
                              "iv": ParamSpec(ishp, P(None, bsp, None,
                                                      A.MODEL_AXIS, None),
                                              init="zeros")}}
            elif seg.kind == "mlstm":
                out[seg.name] = {k: stacked(seg.count, v) for k, v in
                                 mlstm_cache_specs(cfg, batch,
                                                   bspec=bsp).items()}
            elif seg.kind == "slstm":
                out[seg.name] = {k: stacked(seg.count, v) for k, v in
                                 slstm_cache_specs(cfg, batch,
                                                   bspec=bsp).items()}
        return out

    def _bspec(self, batch: int):
        dp = self.axes.dp_total
        if batch % dp == 0 and dp > 1:
            return ((A.POD_AXIS, A.DATA_AXIS) if self.axes.pod > 1
                    else A.DATA_AXIS)
        return None

    # ------------------------------------------------------------ batch spec
    def batch_specs(self, global_batch: int, seq: int):
        """(ShapeDtypeStruct tree, PartitionSpec tree) for a training batch."""
        cfg = self.cfg
        bsp = self._bspec(global_batch)
        tree, specs = {}, {}
        if cfg.input_mode == "frames":
            tree["frames"] = jax.ShapeDtypeStruct(
                (global_batch, seq, cfg.d_model), jnp.bfloat16)
            specs["frames"] = P(bsp, None, None)
            tree["labels"] = jax.ShapeDtypeStruct((global_batch, seq),
                                                  jnp.int32)
            specs["labels"] = P(bsp, None)
        else:
            tree["tokens"] = jax.ShapeDtypeStruct((global_batch, seq),
                                                  jnp.int32)
            specs["tokens"] = P(bsp, None)
        if cfg.cross_attn_every:
            tree["image_emb"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.n_image_tokens, cfg.vision_d),
                jnp.bfloat16)
            specs["image_emb"] = P(bsp, None, None)
        return tree, specs

"""Mamba2 (SSD) mixer -- chunked matmul form, TPU-friendly.

The GPU reference implementation is a fused warp-level scan; per DESIGN.md
the TPU adaptation recasts SSD as the Mamba-2 paper's block-decomposition:
intra-chunk work is dense matmuls (MXU-shaped), and only the O(S/Q) chunk
boundary states are carried through a ``lax.scan`` (the Pallas ``ssd_scan``
kernel implements the same decomposition with VMEM-resident state).

Head sharding: SSD heads are sharded over the `model` axis; the (small)
B/C group projections are replicated per shard (G=1 for zamba2).

Shapes (local): x (B,S,Hl,P), dt (B,S,Hl), A (Hl,), Bm/Cm (B,S,N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..parallel import axes as A
from ..parallel.ops import Ops
from .common import ModelConfig, ParamSpec
from .layers import rmsnorm


def segsum(a):
    """(..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums:
    out[i, j] = sum_{l=j+1..i} a[l] for i >= j, -inf otherwise."""
    Q = a.shape[-1]
    c = jnp.cumsum(a, axis=-1)
    out = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, a_log, Bm, Cm, chunk: int, impl: str = "xla"):
    """SSD scan. x: (B,S,H,P) f32-able, dt: (B,S,H) (post-softplus),
    a_log: (H,) (A = -exp(a_log)), Bm/Cm: (B,S,N). Returns y: (B,S,H,P)
    and the final state (B,H,P,N)."""
    if impl == "pallas":
        from ..kernels import ops as kops
        y = kops.ssd_scan(x, dt, a_log, Bm, Cm, chunk=chunk)
        return y, None   # train path; prefill uses impl="xla" for the state
    B, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = -S % Q
    S_orig = S
    if pad:
        # zero-pad the tail: dt=0 => decay exp(0)=1 and zero update, so
        # real-position outputs and the final state stay exact.
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                               [(0, 0)] * (t.ndim - 2))
        x, dt, Bm, Cm = zp(x), zp(dt), zp(Bm), zp(Cm)
        S = S + pad
    nc = S // Q
    A_h = -jnp.exp(a_log.astype(jnp.float32))                  # (H,)
    a = dt.astype(jnp.float32) * A_h[None, None, :]            # (B,S,H)
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])

    # chunked views: (B, nc, Q, ...)
    ac = a.reshape(B, nc, Q, H)
    xc = xdt.reshape(B, nc, Q, H, Pd)
    Bc = Bm.astype(jnp.float32).reshape(B, nc, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(B, nc, Q, N)

    # ---- intra-chunk (diagonal) term ---------------------------------------
    L = jnp.exp(segsum(ac.transpose(0, 1, 3, 2)))              # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)             # (B,nc,Q,Q)
    y_diag = jnp.einsum("bchqk,bcqk,bckhp->bcqhp",
                        L, scores, xc)

    # ---- chunk states + inter-chunk recurrence ------------------------------
    cum = jnp.cumsum(ac, axis=2)                               # (B,nc,Q,H)
    total = cum[:, :, -1:, :]                                  # (B,nc,1,H)
    decay_in = jnp.exp(total - cum)                            # weight to chunk end
    states = jnp.einsum("bckn,bckh,bckhp->bchnp",
                        Bc, decay_in, xc)                      # (B,nc,H,N,P)
    chunk_decay = jnp.exp(total[:, :, 0, :])                   # (B,nc,H)

    def step(s_prev, inp):
        st, dec = inp                                          # (B,H,N,P),(B,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((B, H, N, Pd), jnp.float32)
    s_final, s_before = lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    s_before = s_before.transpose(1, 0, 2, 3, 4)               # (B,nc,H,N,P)

    decay_out = jnp.exp(cum)                                   # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                       Cc, decay_out, s_before)

    y = (y_diag + y_off).reshape(B, S, H, Pd)[:, :S_orig]
    return y.astype(x.dtype), s_final.transpose(0, 1, 3, 2)    # (B,H,P,N)


def ssd_decode_step(state, x_t, dt_t, a_log, B_t, C_t):
    """One-token recurrence. state: (B,H,P,N); x_t: (B,H,P); dt_t: (B,H);
    B_t/C_t: (B,N). Returns (y_t, new_state)."""
    A_h = -jnp.exp(a_log.astype(jnp.float32))
    dec = jnp.exp(dt_t.astype(jnp.float32) * A_h[None, :])     # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn",
                     x_t.astype(jnp.float32) * dt_t[..., None], B_t.astype(jnp.float32))
    new = state * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new


# ---------------------------------------------------------------------------
# Mamba2 block (projections + depthwise conv + SSD + gated norm + out proj)
# ---------------------------------------------------------------------------

def mamba2_param_specs(cfg: ModelConfig, tp: int):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    K = 4  # conv width
    return {
        "w_zx": ParamSpec((d, 2 * d_in), P(A.DATA_AXIS, A.MODEL_AXIS)),
        "w_bc": ParamSpec((d, 2 * N), P(A.DATA_AXIS, None)),
        "w_dt": ParamSpec((d, H), P(A.DATA_AXIS, A.MODEL_AXIS)),
        "dt_bias": ParamSpec((H,), P(A.MODEL_AXIS), init="zeros"),
        "a_log": ParamSpec((H,), P(A.MODEL_AXIS), init="zeros"),
        "skip_d": ParamSpec((H,), P(A.MODEL_AXIS), init="ones"),
        "conv_x": ParamSpec((K, d_in), P(None, A.MODEL_AXIS)),
        "conv_bc": ParamSpec((K, 2 * N), P()),
        "gnorm": ParamSpec((d_in,), P(A.MODEL_AXIS), init="ones"),
        "w_out": ParamSpec((d_in, d), P(A.MODEL_AXIS, A.DATA_AXIS),
                           init="scaled", fan_in=cfg.n_layers),
    }


def _tail_pad(x, n: int):
    """Last n positions of x (B,S,C), left-zero-padded if S < n."""
    S = x.shape[1]
    if S >= n:
        return x[:, S - n:, :]
    return jnp.pad(x, ((0, 0), (n - S, 0), (0, 0)))


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). If ``state`` (B,K-1,C)
    is given, operates in streaming mode and returns (y, new_state)."""
    K = w.shape[0]
    if state is not None:
        xx = jnp.concatenate([state, x], axis=1)
        new_state = xx[:, -(K - 1):, :]
    else:
        xx = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = None
    y = sum(xx[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return (y, new_state) if state is not None else y


def mamba2_mixer(ops: Ops, p, x, cfg: ModelConfig, cache=None,
                 mode: str = "train"):
    """x: (B, S, d) full-seq activations (already seq-gathered).
    mode: "train" | "prefill" (build cache) | "decode" (consume ``cache``).
    Returns (y, new_cache)."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    Pd = cfg.ssm_head_dim
    K = p["conv_x"].shape[0]

    w_zx = ops.weight(p["w_zx"], P(A.DATA_AXIS, A.MODEL_AXIS))
    w_bc = ops.weight(p["w_bc"], P(A.DATA_AXIS, None))
    w_dt = ops.weight(p["w_dt"], P(A.DATA_AXIS, A.MODEL_AXIS))
    zx = x @ w_zx                                      # (B,S,2*d_in_loc)
    z, xs = jnp.split(zx, 2, axis=-1)
    bc = x @ w_bc                                      # (B,S,2N) replicated
    dt_raw = x @ w_dt                                  # (B,S,H_loc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    h_loc = xs.shape[-1] // Pd

    xs_raw, bc_raw = xs, bc
    if mode == "decode":
        xs, cx = _causal_conv(xs, p["conv_x"], cache["conv_x"])
        bc, cbc = _causal_conv(bc, p["conv_bc"], cache["conv_bc"])
    else:
        xs = _causal_conv(xs, p["conv_x"])
        bc = _causal_conv(bc, p["conv_bc"])
    xs = jax.nn.silu(xs)
    bc = jax.nn.silu(bc)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                 # (B,S,N) each

    xh = xs.reshape(B, S, h_loc, Pd)
    if mode == "decode":
        y_t, s_new = ssd_decode_step(cache["ssd"], xh[:, 0], dt[:, 0],
                                     p["a_log"], Bm[:, 0], Cm[:, 0])
        y = y_t[:, None]
        new_cache = {"conv_x": cx, "conv_bc": cbc, "ssd": s_new}
    else:
        impl = ("pallas" if cfg.attn_impl == "pallas" and mode == "train"
                else "xla")
        y, s_final = ssd_chunked(xh, dt, p["a_log"], Bm, Cm,
                                 chunk=cfg.ssm_chunk, impl=impl)
        new_cache = None
        if mode == "prefill":
            tail = lambda t: _tail_pad(t, K - 1)
            new_cache = {"conv_x": tail(xs_raw), "conv_bc": tail(bc_raw),
                         "ssd": s_final}

    y = y + xs.reshape(B, S, h_loc, Pd) * p["skip_d"][None, None, :, None]
    y = y.reshape(B, S, h_loc * Pd)
    y = rmsnorm(y * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)  # gated norm
    w_out = ops.weight(p["w_out"], P(A.MODEL_AXIS, A.DATA_AXIS))
    out = y @ w_out                                    # partial over model
    return out, new_cache


def mamba2_cache_specs(cfg: ModelConfig, batch: int, tp: int,
                       bspec=A.DATA_AXIS):
    """Decode-cache ParamSpecs (per layer; caller stacks)."""
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    K = 4
    import jax.numpy as _jnp
    return {
        "conv_x": ParamSpec((batch, K - 1, d_in),
                            P(bspec, None, A.MODEL_AXIS), init="zeros"),
        "conv_bc": ParamSpec((batch, K - 1, 2 * N),
                             P(bspec, None, None), init="zeros"),
        "ssd": ParamSpec((batch, H, cfg.ssm_head_dim, N),
                         P(bspec, A.MODEL_AXIS, None, None), init="zeros",
                         dtype=_jnp.float32),
    }
